//! `ocl-lint` — the repo's concurrency-invariant source pass
//! (DESIGN.md §11), run by the CI `lint` job and `make lint`.
//!
//! Zero-dependency by construction (plain `std::fs` + a small
//! string-aware scanner; no `syn`, no proc-macro machinery), because
//! the crate's contract is a fully-offline build. Five rules over
//! `rust/src`, non-test code only:
//!
//! * **`sync-funnel`** — no direct `std::sync` / `std::thread` paths
//!   outside `crate::sync` (`rust/src/sync.rs`). The funnel is what
//!   keeps every lock, atomic, channel, and spawn on the serve path
//!   swappable for a model-checked implementation in one file.
//! * **`unwrap`** — no `.unwrap()` / `.expect(` under `rust/src/serve/`.
//!   A panic on the serve path kills a router or worker thread in
//!   production; every intentional panic site must carry a justified
//!   marker (see below).
//! * **`determinism`** — no wall-clock (`Instant::now`,
//!   `SystemTime::now`) or entropy-seeded RNG construction in the
//!   deterministic replay/checkpoint paths (`serve/ckpt.rs`,
//!   `serve/stage.rs`, `serve/reshard.rs`, `serve/scale.rs`,
//!   `codec/`). Checkpoint parity (DESIGN.md §10), the pipelined stage
//!   queues (§13), resharding, and the autoscale hysteresis (§14)
//!   depend on those paths being pure functions of their inputs.
//! * **`raw-write`** — in `serve/net.rs`, every `.write_all(` must be
//!   fed by `encode(`, the single site that enforces the `MAX_FRAME`
//!   wire bound; raw socket writes bypass it.
//! * **`hot-alloc`** — no heap allocation (`Vec::new`, `with_capacity`,
//!   `.to_vec()`, `.clone()`, `vec!`) on the host-kernel hot paths:
//!   all of `hostmodel/tensor.rs`, and the `predict*` / `score*` /
//!   `features*` / `forward_batch*` bodies in
//!   `hostmodel/{tfm,lr,mlp}.rs`. Steady-state batched inference is
//!   zero-alloc by contract (`tests/test_alloc.rs` proves it with a
//!   counting allocator); per-sample compat wrappers carry justified
//!   markers.
//!
//! Suppression: a site is allowed by a marker comment on the same
//! line, or in the comment block directly above its statement:
//!
//! ```text
//! // lint: allow(unwrap) — <why this site cannot fail / is supervised>
//! ```
//!
//! A marker **without** a justification after the rule name is itself
//! a violation (`marker`), so allows stay auditable. `--json <path>`
//! writes a machine-readable report (uploaded as a CI artifact);
//! exit status is nonzero iff any violation was found.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use ocl::codec::json::Json;

/// Rule names a marker may reference.
const RULES: [&str; 5] = ["sync-funnel", "unwrap", "determinism", "raw-write", "hot-alloc"];

/// How far above a violating line the marker scan walks (comment
/// block + continuation lines of the same statement).
const MARKER_SCAN_LINES: usize = 12;

#[derive(Debug, Clone)]
struct Violation {
    file: String,
    line: usize, // 1-based
    rule: &'static str,
    text: String,
}

#[derive(Debug, Clone)]
struct Marker {
    file: String,
    line: usize, // 1-based
    rule: String,
    justification: String,
}

fn main() {
    let mut json_out: Option<PathBuf> = None;
    let mut root = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => die("--json requires a path"),
            },
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => die("--root requires a directory"),
            },
            other => die(&format!("unknown argument '{other}' (usage: ocl_lint [--root <src-dir>] [--json <report-path>])")),
        }
    }

    let mut files = Vec::new();
    collect_rs(&root, &mut files);
    files.sort();
    if files.is_empty() {
        die(&format!("no .rs files under {}", root.display()));
    }

    let mut violations = Vec::new();
    let mut markers = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root.parent().unwrap_or(&root))
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => die(&format!("read {}: {e}", path.display())),
        };
        scan_file(&rel, &src, &mut violations, &mut markers);
    }

    for v in &violations {
        println!("{}:{} [{}] {}", v.file, v.line, v.rule, v.text);
    }
    println!(
        "ocl-lint: {} files scanned, {} markers, {} violations",
        files.len(),
        markers.len(),
        violations.len()
    );

    if let Some(out) = json_out {
        let report = report_json(files.len(), &violations, &markers);
        if let Err(e) = fs::write(&out, report.to_string_pretty()) {
            die(&format!("write {}: {e}", out.display()));
        }
        println!("ocl-lint: report written to {}", out.display());
    }

    if !violations.is_empty() {
        std::process::exit(1);
    }
}

fn die(msg: &str) -> ! {
    eprintln!("ocl-lint: {msg}");
    std::process::exit(2);
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => die(&format!("read dir {}: {e}", dir.display())),
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Files the rules never apply to: the funnel itself, and this linter
/// (whose pattern literals and marker examples would self-flag).
fn exempt(rel: &str) -> bool {
    rel.ends_with("src/sync.rs") || rel.ends_with("src/bin/ocl_lint.rs")
}

fn scan_file(rel: &str, src: &str, violations: &mut Vec<Violation>, markers: &mut Vec<Marker>) {
    let orig: Vec<&str> = src.lines().collect();
    let stripped = strip_source(&orig);
    let in_test = test_regions(&stripped);

    // Marker inventory + well-formedness (S5: a justification-less
    // marker fails the lint even if nothing relies on it).
    if !exempt(rel) {
        for (i, line) in orig.iter().enumerate() {
            if let Some((rule, justification)) = parse_marker(line) {
                if !RULES.contains(&rule.as_str()) {
                    violations.push(Violation {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "marker",
                        text: format!("unknown rule '{rule}' in lint marker"),
                    });
                } else if justification.is_empty() {
                    violations.push(Violation {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "marker",
                        text: format!(
                            "marker 'lint: allow({rule})' has no justification — \
                             say why this site is safe"
                        ),
                    });
                } else {
                    markers.push(Marker {
                        file: rel.to_string(),
                        line: i + 1,
                        rule,
                        justification,
                    });
                }
            }
        }
    }

    if exempt(rel) {
        return;
    }
    let serve = rel.contains("src/serve/");
    let deterministic = rel.ends_with("src/serve/ckpt.rs")
        || rel.ends_with("src/serve/stage.rs")
        || rel.ends_with("src/serve/reshard.rs")
        || rel.ends_with("src/serve/scale.rs")
        || rel.contains("src/codec/");
    let net = rel.ends_with("src/serve/net.rs");
    // hot-alloc scope: the kernel file is hot wall-to-wall; the model
    // files are hot only inside their inference-path function bodies
    // (constructors, training, and (de)serialization may allocate).
    let hot_file = rel.ends_with("src/hostmodel/tensor.rs");
    let hot_model = rel.ends_with("src/hostmodel/tfm.rs")
        || rel.ends_with("src/hostmodel/lr.rs")
        || rel.ends_with("src/hostmodel/mlp.rs");
    let in_hot = if hot_model { hot_fn_regions(&stripped) } else { Vec::new() };

    // Patterns assembled at runtime so the source of *other* tools
    // grepping this file stays quiet; strings in scanned files are
    // stripped anyway.
    let p_sync = ["std", "::sync"].concat();
    let p_thread = ["std", "::thread"].concat();
    let p_unwrap = [".unwrap", "()"].concat();
    let p_expect = [".expect", "("].concat();
    let det_patterns =
        ["Instant::now", "SystemTime::now", "from_entropy", "thread_rng", "from_os_rng"];
    let alloc_patterns = [
        ["Vec:", ":new("].concat(),
        ["with_", "capacity("].concat(),
        [".to_", "vec()"].concat(),
        [".clone", "()"].concat(),
        ["vec", "!"].concat(),
    ];

    for (i, s) in stripped.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        let mut flag = |rule: &'static str, text: String| {
            if !suppressed(&orig, i, rule) {
                violations.push(Violation { file: rel.to_string(), line: i + 1, rule, text });
            }
        };
        if s.contains(&p_sync) || s.contains(&p_thread) {
            flag(
                "sync-funnel",
                "direct std sync/thread path — import through crate::sync instead".to_string(),
            );
        }
        if serve && (s.contains(&p_unwrap) || s.contains(&p_expect)) {
            flag(
                "unwrap",
                "panic site on the serve path — handle the error or justify with a marker"
                    .to_string(),
            );
        }
        if deterministic {
            for p in det_patterns {
                if s.contains(p) {
                    flag(
                        "determinism",
                        format!("{p} in a deterministic replay/checkpoint path"),
                    );
                }
            }
        }
        if net && s.contains(".write_all(") && !s.contains("encode(") {
            flag(
                "raw-write",
                "socket write not fed by encode() — bypasses the MAX_FRAME bound".to_string(),
            );
        }
        if hot_file || (hot_model && in_hot[i]) {
            for p in &alloc_patterns {
                if s.contains(p.as_str()) {
                    flag(
                        "hot-alloc",
                        format!(
                            "heap allocation ('{p}') on a host-kernel hot path — \
                             reuse a Scratch buffer or justify with a marker"
                        ),
                    );
                }
            }
        }
    }
}

/// Per-line map of hot inference-path function bodies in the hostmodel
/// files: `fn predict*`, `fn score*`, `fn features*`,
/// `fn forward_batch*`, brace-tracked on string-stripped text. The
/// hot-alloc rule applies only inside them, so constructors, training
/// steps, and flat-weight (de)serialization may still allocate.
fn hot_fn_regions(stripped: &[String]) -> Vec<bool> {
    const HOT_PREFIXES: [&str; 4] = ["predict", "score", "features", "forward_batch"];
    let mut hot = vec![false; stripped.len()];
    let mut i = 0;
    while i < stripped.len() {
        let line = &stripped[i];
        let is_hot_fn = line.find("fn ").is_some_and(|p| {
            let boundary =
                p == 0 || !line[..p].ends_with(|c: char| c.is_alphanumeric() || c == '_');
            boundary && {
                let name = &line[p + 3..];
                HOT_PREFIXES.iter().any(|pre| name.starts_with(pre))
            }
        });
        if is_hot_fn {
            // Walk to the opening brace of the fn body, then track
            // depth until it closes; everything inside is hot.
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut j = i;
            while j < stripped.len() {
                hot[j] = true;
                for c in stripped[j].chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    hot
}

/// Is the violation at `idx` allowed by a marker on the same line or
/// in the comment block directly above its statement? The upward walk
/// crosses comment lines and unterminated continuation lines of the
/// same statement, and stops at the previous terminated statement.
fn suppressed(orig: &[&str], idx: usize, rule: &str) -> bool {
    let marker = format!("lint: allow({rule})");
    if orig[idx].contains(&marker) {
        return true;
    }
    let mut i = idx;
    for _ in 0..MARKER_SCAN_LINES {
        if i == 0 {
            return false;
        }
        i -= 1;
        let t = orig[i].trim();
        if t.starts_with("//") {
            if t.contains(&marker) {
                return true;
            }
            continue;
        }
        if t.is_empty() || t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
            return false;
        }
        // otherwise: a continuation line of the same statement — keep
        // walking up toward its leading comment block.
    }
    false
}

/// Parse `lint: allow(<rule>)<justification>` out of a line, if present.
fn parse_marker(line: &str) -> Option<(String, String)> {
    let tag = ["lint: ", "allow("].concat();
    let start = line.find(&tag)?;
    let rest = &line[start + tag.len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let justification = rest[close + 1..]
        .trim_start_matches(|c: char| !c.is_alphanumeric())
        .trim()
        .to_string();
    Some((rule, justification))
}

/// Per-line map of `#[cfg(test)]` item bodies (brace-tracked on
/// string-stripped text), so test code is out of scope for the rules.
fn test_regions(stripped: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; stripped.len()];
    let mut i = 0;
    while i < stripped.len() {
        if stripped[i].contains("#[cfg(test)]") {
            // Walk to the opening brace of the gated item, then track
            // depth until it closes; everything inside is test code.
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut j = i;
            while j < stripped.len() {
                in_test[j] = true;
                for c in stripped[j].chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    in_test
}

/// Replace string/char-literal and comment contents with spaces so
/// pattern matching only sees code. Handles line comments, nested
/// block comments, raw strings, and lifetime-vs-char-literal
/// disambiguation — line-by-line, with block/raw state carried across
/// lines.
fn strip_source(orig: &[&str]) -> Vec<String> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        Block(u32),
        Str,
        RawStr(u32),
    }
    let mut st = St::Code;
    let mut out = Vec::with_capacity(orig.len());
    for line in orig {
        let b: Vec<char> = line.chars().collect();
        let mut s = String::with_capacity(b.len());
        let mut k = 0;
        while k < b.len() {
            match st {
                St::Code => {
                    let c = b[k];
                    if c == '/' && b.get(k + 1) == Some(&'/') {
                        break; // line comment: drop the rest
                    } else if c == '/' && b.get(k + 1) == Some(&'*') {
                        st = St::Block(1);
                        s.push(' ');
                        s.push(' ');
                        k += 2;
                    } else if c == '"' {
                        st = St::Str;
                        s.push(' ');
                        k += 1;
                    } else if c == 'r'
                        && matches!(b.get(k + 1), Some(&'"') | Some(&'#'))
                        && !b
                            .get(k.wrapping_sub(1))
                            .is_some_and(|p| p.is_alphanumeric() || *p == '_')
                    {
                        let mut hashes = 0u32;
                        let mut j = k + 1;
                        while b.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if b.get(j) == Some(&'"') {
                            st = St::RawStr(hashes);
                            for _ in k..=j {
                                s.push(' ');
                            }
                            k = j + 1;
                        } else {
                            s.push(c);
                            k += 1;
                        }
                    } else if c == '\'' {
                        // Char literal vs lifetime: a literal closes
                        // with ' after one (possibly escaped) char.
                        if b.get(k + 1) == Some(&'\\') {
                            let mut j = k + 2;
                            while j < b.len() && b[j] != '\'' {
                                j += 1;
                            }
                            for _ in k..=j.min(b.len() - 1) {
                                s.push(' ');
                            }
                            k = j + 1;
                        } else if b.get(k + 2) == Some(&'\'') {
                            s.push(' ');
                            s.push(' ');
                            s.push(' ');
                            k += 3;
                        } else {
                            s.push(c); // lifetime tick
                            k += 1;
                        }
                    } else {
                        s.push(c);
                        k += 1;
                    }
                }
                St::Block(depth) => {
                    if b[k] == '*' && b.get(k + 1) == Some(&'/') {
                        st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                        s.push(' ');
                        s.push(' ');
                        k += 2;
                    } else if b[k] == '/' && b.get(k + 1) == Some(&'*') {
                        st = St::Block(depth + 1);
                        s.push(' ');
                        s.push(' ');
                        k += 2;
                    } else {
                        s.push(' ');
                        k += 1;
                    }
                }
                St::Str => {
                    if b[k] == '\\' {
                        s.push(' ');
                        if k + 1 < b.len() {
                            s.push(' ');
                        }
                        k += 2;
                    } else if b[k] == '"' {
                        st = St::Code;
                        s.push(' ');
                        k += 1;
                    } else {
                        s.push(' ');
                        k += 1;
                    }
                }
                St::RawStr(hashes) => {
                    if b[k] == '"' {
                        let mut ok = true;
                        for h in 0..hashes {
                            if b.get(k + 1 + h as usize) != Some(&'#') {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            st = St::Code;
                            for _ in 0..=hashes {
                                s.push(' ');
                            }
                            k += 1 + hashes as usize;
                        } else {
                            s.push(' ');
                            k += 1;
                        }
                    } else {
                        s.push(' ');
                        k += 1;
                    }
                }
            }
        }
        // An unterminated line comment state resets at the newline; a
        // string that legally spans lines keeps its state.
        out.push(s);
    }
    out
}

fn report_json(files: usize, violations: &[Violation], markers: &[Marker]) -> Json {
    let vio: Vec<Json> = violations
        .iter()
        .map(|v| {
            Json::obj(vec![
                ("file", Json::Str(v.file.clone())),
                ("line", Json::Num(v.line as f64)),
                ("rule", Json::Str(v.rule.to_string())),
                ("text", Json::Str(v.text.clone())),
            ])
        })
        .collect();
    let mks: Vec<Json> = markers
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("file", Json::Str(m.file.clone())),
                ("line", Json::Num(m.line as f64)),
                ("rule", Json::Str(m.rule.clone())),
                ("justification", Json::Str(m.justification.clone())),
            ])
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("tool".to_string(), Json::Str("ocl-lint".to_string()));
    top.insert("files_scanned".to_string(), Json::Num(files as f64));
    top.insert("clean".to_string(), Json::Bool(violations.is_empty()));
    top.insert("violations".to_string(), Json::Arr(vio));
    top.insert("markers".to_string(), Json::Arr(mks));
    Json::Obj(top)
}
