//! MDP formalization (paper §2): episode costs, `J(π, T)` (Eq. 1), and
//! empirical regret accounting against best-fixed-policy-in-hindsight
//! (Def. A.1) — the machinery behind the no-regret property test.

use crate::config::CascadeConfig;

/// Cost parameters of the episodic MDP.
#[derive(Clone, Debug)]
pub struct CostParams {
    /// Cost weighting factor μ.
    pub mu: f64,
    /// Deferral penalties `c_{i+1}` for each hop (levels then expert).
    pub defer_costs: Vec<f64>,
}

impl CostParams {
    /// Extract from a cascade config: hop i's penalty is level i's
    /// `model_cost` (the "Model Cost" column of Tables 3–4).
    pub fn from_config(cfg: &CascadeConfig) -> Self {
        CostParams {
            mu: cfg.mu,
            defer_costs: cfg.levels.iter().map(|l| l.model_cost).collect(),
        }
    }

    /// Immediate cost of one episode's trajectory: `exit_level` hops of
    /// deferral penalties, then the prediction loss at the exit.
    ///
    /// `exit_level` ∈ [0, N-1]; N-1 = the expert level (never wrong in
    /// the MDP's view of its own labels, but we charge the *measured*
    /// loss so noisy experts are accounted honestly).
    pub fn episode_cost(&self, exit_level: usize, prediction_loss: f64) -> f64 {
        let hops: f64 = self.defer_costs[..exit_level.min(self.defer_costs.len())]
            .iter()
            .sum();
        self.mu * hops + prediction_loss
    }
}

/// 0/1 prediction loss.
pub fn zero_one_loss(pred: usize, truth: usize) -> f64 {
    if pred == truth {
        0.0
    } else {
        1.0
    }
}

/// Running `J(π, T)` tracker plus the per-level hindsight costs needed
/// for the empirical-regret estimate.
#[derive(Clone, Debug)]
pub struct RegretTracker {
    params: CostParams,
    /// Σ episode costs of the learned policy.
    j_learned: f64,
    /// Σ episode costs for each *fixed* policy "always exit at level i".
    j_fixed: Vec<f64>,
    episodes: usize,
    /// Per-episode average-regret trace (sampled for plotting).
    pub trace: Vec<(usize, f64)>,
    trace_every: usize,
}

impl RegretTracker {
    /// Track regret for an N-level cascade (N-1 small levels + expert).
    pub fn new(params: CostParams, n_levels: usize, trace_every: usize) -> Self {
        RegretTracker {
            params,
            j_learned: 0.0,
            j_fixed: vec![0.0; n_levels],
            episodes: 0,
            trace: Vec::new(),
            trace_every: trace_every.max(1),
        }
    }

    /// Record one episode.
    ///
    /// * `exit_level`, `loss` — what the learned policy did.
    /// * `fixed_losses[i]` — the 0/1 loss the fixed policy "always exit
    ///   at level i" would have paid on this episode (level N-1 = the
    ///   expert's own loss).
    pub fn record(&mut self, exit_level: usize, loss: f64, fixed_losses: &[f64]) {
        debug_assert_eq!(fixed_losses.len(), self.j_fixed.len());
        self.j_learned += self.params.episode_cost(exit_level, loss);
        for (i, jf) in self.j_fixed.iter_mut().enumerate() {
            *jf += self.params.episode_cost(i, fixed_losses[i]);
        }
        self.episodes += 1;
        if self.episodes % self.trace_every == 0 {
            self.trace.push((self.episodes, self.average_regret()));
        }
    }

    /// Total cost of the learned policy so far.
    pub fn j_learned(&self) -> f64 {
        self.j_learned
    }

    /// Cost of the best fixed policy in hindsight.
    pub fn j_best_fixed(&self) -> f64 {
        self.j_fixed.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Which fixed exit level is best in hindsight.
    pub fn best_fixed_level(&self) -> usize {
        let best = self.j_best_fixed();
        self.j_fixed.iter().position(|&x| x == best).unwrap_or(0)
    }

    /// Empirical regret γ = J(learned) − min_fixed J.
    pub fn regret(&self) -> f64 {
        self.j_learned - self.j_best_fixed()
    }

    /// γ / T — must trend to ≤ 0 for the no-regret property.
    pub fn average_regret(&self) -> f64 {
        if self.episodes == 0 {
            0.0
        } else {
            self.regret() / self.episodes as f64
        }
    }

    /// Episodes recorded.
    pub fn episodes(&self) -> usize {
        self.episodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BenchmarkId, ExpertId};

    fn params() -> CostParams {
        CostParams { mu: 0.001, defer_costs: vec![1.0, 1182.0] }
    }

    #[test]
    fn episode_cost_decomposition() {
        let p = params();
        // exit at level 0: no hops, only loss
        assert_eq!(p.episode_cost(0, 1.0), 1.0);
        // exit at level 1: one hop
        assert!((p.episode_cost(1, 0.0) - 0.001).abs() < 1e-12);
        // exit at expert (level 2): both hops
        assert!((p.episode_cost(2, 0.0) - 0.001 * 1183.0).abs() < 1e-12);
    }

    #[test]
    fn from_config_reads_tables() {
        let cfg = crate::config::CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let p = CostParams::from_config(&cfg);
        assert_eq!(p.defer_costs, vec![1.0, 1182.0]);
    }

    #[test]
    fn regret_vs_best_fixed() {
        let mut t = RegretTracker::new(params(), 3, 10);
        // Learned policy always exits at level 0 with loss 0.3;
        // fixed level-1 policy has loss 0.1 → cheaper than learned.
        for _ in 0..100 {
            t.record(0, 0.3, &[0.3, 0.1, 0.0]);
        }
        assert_eq!(t.episodes(), 100);
        // fixed costs: L0 = 0.3; L1 = 0.001 + 0.1 = 0.101; L2 = 1.183
        assert_eq!(t.best_fixed_level(), 1);
        let want_regret = 100.0 * (0.3 - 0.101);
        assert!((t.regret() - want_regret).abs() < 1e-9);
        assert!(t.average_regret() > 0.0);
        assert_eq!(t.trace.len(), 10);
    }

    #[test]
    fn zero_regret_when_learned_matches_best() {
        let mut t = RegretTracker::new(params(), 2, 1);
        for _ in 0..50 {
            t.record(0, 0.0, &[0.0, 0.0]);
        }
        assert!(t.regret() <= 1e-12);
        assert!(t.average_regret() <= 1e-12);
    }

    #[test]
    fn zero_one() {
        assert_eq!(zero_one_loss(1, 1), 0.0);
        assert_eq!(zero_one_loss(0, 1), 1.0);
    }
}
