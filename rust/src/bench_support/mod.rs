//! Benchmark harness for `cargo bench` (no `criterion` offline).
//!
//! `[[bench]] harness = false` binaries build a [`Bench`] per paper
//! table/figure, register timed closures, and print a fixed-width
//! report with warmup, repetition statistics, and throughput. Also
//! hosts [`black_box`] to keep the optimizer honest.
//!
//! **Regression gating** (the ROADMAP "criterion-ize" item): the JSON
//! baseline ([`Bench::to_json`]) carries a median-of-medians statistic
//! per case — robust to the fat-tailed outliers shared CI runners
//! produce — and [`Bench::compare_baseline`] fails the run when a case
//! regresses more than a tolerance against a stored baseline file.
//! Bench binaries opt in with `--baseline <file>` (cargo forwards args
//! after `--`) or the `BENCH_BASELINE` env var; see
//! [`baseline_from_env`].

use std::hint::black_box as std_black_box;
use std::time::Instant;

use crate::codec::Json;
use crate::error::{Error, Result};
use crate::util::Percentiles;

/// Default allowed slowdown vs baseline, percent (generous: shared CI
/// runners; the gate is for order-of-magnitude regressions).
pub const DEFAULT_TOLERANCE_PCT: f64 = 50.0;

/// Re-exported optimizer barrier.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one timed case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    /// Case label.
    pub name: String,
    /// Per-iteration wall time, milliseconds.
    pub iters_ms: Vec<f64>,
    /// Optional items/iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl CaseResult {
    /// Mean ms/iteration.
    pub fn mean_ms(&self) -> f64 {
        self.iters_ms.iter().sum::<f64>() / self.iters_ms.len().max(1) as f64
    }

    /// Median-of-medians ms/iteration: the timings are split into up
    /// to 5 contiguous groups, each group's median taken, and the
    /// median of those returned. A single cold-cache or noisy-neighbor
    /// spike can move the mean by an unbounded amount but shifts at
    /// most one group median — this is the statistic the regression
    /// gate compares. With few iterations it degrades gracefully to
    /// the plain median.
    pub fn mom_ms(&self) -> f64 {
        fn median(xs: &[f64]) -> f64 {
            if xs.is_empty() {
                return 0.0;
            }
            let mut v = xs.to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).expect("nan timing"));
            v[v.len() / 2]
        }
        let n = self.iters_ms.len();
        if n == 0 {
            return 0.0;
        }
        let groups = n.min(5);
        let meds: Vec<f64> = (0..groups)
            .map(|g| {
                let lo = g * n / groups;
                let hi = ((g + 1) * n / groups).max(lo + 1).min(n);
                median(&self.iters_ms[lo..hi])
            })
            .collect();
        median(&meds)
    }
}

/// A named group of timed cases (≈ one paper table/figure).
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    results: Vec<CaseResult>,
}

impl Bench {
    /// New bench group. `warmup` untimed + `iters` timed repetitions.
    pub fn new(name: &str, warmup: usize, iters: usize) -> Self {
        assert!(iters > 0);
        Bench { name: name.to_string(), warmup, iters, results: Vec::new() }
    }

    /// Time `f` (called once per iteration).
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &CaseResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut iters_ms = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            iters_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        self.results.push(CaseResult { name: name.to_string(), iters_ms, items_per_iter: None });
        self.results.last().expect("just pushed")
    }

    /// Time `f` processing `items` logical items per iteration
    /// (throughput reported as items/s).
    pub fn case_throughput<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) {
        self.case(name, &mut f);
        self.results.last_mut().expect("just pushed").items_per_iter = Some(items);
    }

    /// Render the report table.
    pub fn report(&self) -> String {
        let mut s = format!("\n== bench: {} ({} iters) ==\n", self.name, self.iters);
        s.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>14}\n",
            "case", "mean ms", "p50 ms", "p95 ms", "throughput"
        ));
        for r in &self.results {
            let mut p = Percentiles::new();
            for &x in &r.iters_ms {
                p.push(x);
            }
            let thr = match r.items_per_iter {
                Some(items) => format!("{:>11.0}/s", items / (r.mean_ms() / 1e3)),
                None => "-".to_string(),
            };
            s.push_str(&format!(
                "{:<44} {:>12.3} {:>12.3} {:>12.3} {:>14}\n",
                r.name,
                r.mean_ms(),
                p.pct(50.0),
                p.pct(95.0),
                thr
            ));
        }
        s
    }

    /// Print the report to stdout.
    pub fn print(&self) {
        print!("{}", self.report());
    }

    /// Access raw results (assertions in bench smoke tests).
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// JSON baseline encoding — the machine-readable twin of
    /// [`Bench::report`]. CI uploads these per-bench baselines as
    /// artifacts (`BENCH_*.json`) so perf trajectories can be diffed
    /// across commits without scraping the text tables.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("schema", Json::Num(1.0)),
            ("iters", Json::Num(self.iters as f64)),
            (
                "cases",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            let mut p = Percentiles::new();
                            for &x in &r.iters_ms {
                                p.push(x);
                            }
                            let q = p.pcts(&[50.0, 95.0, 99.0]);
                            Json::obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("mean_ms", Json::Num(r.mean_ms())),
                                ("mom_ms", Json::Num(r.mom_ms())),
                                ("p50_ms", Json::Num(q[0])),
                                ("p95_ms", Json::Num(q[1])),
                                ("p99_ms", Json::Num(q[2])),
                                (
                                    "items_per_sec",
                                    match r.items_per_iter {
                                        Some(items) => {
                                            Json::Num(items / (r.mean_ms() / 1e3))
                                        }
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Fail when any case regressed more than `tol_pct` percent vs the
    /// stored baseline (matching on case name; median-of-medians, with
    /// mean as the fallback for pre-`mom_ms` baselines). Cases absent
    /// from the baseline pass — a new case has nothing to regress
    /// against. The error is [`Error::Slo`]: a perf bound is a service
    /// objective like any latency bound.
    pub fn compare_baseline(&self, baseline: &Json, tol_pct: f64) -> Result<()> {
        // Accept either a bare Bench::to_json value or a wrapper
        // object that carries one under "harness" (bench_serve's
        // composite baseline).
        let base = baseline.get("harness").unwrap_or(baseline);
        let cases = base
            .get("cases")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| Error::Config("baseline has no 'cases' array".into()))?;
        let mut failures = Vec::new();
        for r in &self.results {
            let Some(prev) = cases.iter().find(|c| {
                c.get("name").and_then(|n| n.as_str()) == Some(r.name.as_str())
            }) else {
                continue;
            };
            let Some(prev_ms) = prev
                .get("mom_ms")
                .or_else(|| prev.get("mean_ms"))
                .and_then(|v| v.as_f64())
            else {
                continue;
            };
            let now_ms = r.mom_ms();
            if prev_ms > 0.0 && now_ms > prev_ms * (1.0 + tol_pct / 100.0) {
                failures.push(format!(
                    "{}: {:.3} ms vs baseline {:.3} ms (+{:.0}% > {:.0}%)",
                    r.name,
                    now_ms,
                    prev_ms,
                    (now_ms / prev_ms - 1.0) * 100.0,
                    tol_pct
                ));
            }
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(Error::Slo(format!("perf regression: {}", failures.join("; "))))
        }
    }
}

/// Baseline-gate opt-in for `harness = false` bench binaries: reads
/// `--baseline <file>` (and optional `--baseline-tol <pct>`) from the
/// process args (cargo forwards everything after `--`), falling back
/// to the `BENCH_BASELINE` / `BENCH_BASELINE_TOL` env vars. Returns
/// the baseline path and tolerance, or `None` when no gate was asked
/// for.
pub fn baseline_from_env() -> Option<(String, f64)> {
    let args: Vec<String> = std::env::args().collect();
    let flag = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let path = flag("--baseline").or_else(|| std::env::var("BENCH_BASELINE").ok())?;
    let tol = flag("--baseline-tol")
        .or_else(|| std::env::var("BENCH_BASELINE_TOL").ok())
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_TOLERANCE_PCT);
    Some((path, tol))
}

/// Load a baseline file and gate `bench` against it at `tol_pct`
/// (convenience wrapper bench mains call once after printing).
pub fn check_baseline_file(bench: &Bench, path: &str, tol_pct: f64) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("baseline '{path}': {e}")))?;
    let json = crate::codec::parse(&text)?;
    bench.compare_baseline(&json, tol_pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_and_reports() {
        let mut b = Bench::new("demo", 1, 3);
        let mut n = 0u64;
        b.case("spin", || {
            for i in 0..10_000u64 {
                n = n.wrapping_add(black_box(i));
            }
        });
        b.case_throughput("items", 100.0, || {
            crate::sync::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert_eq!(b.results().len(), 2);
        assert_eq!(b.results()[0].iters_ms.len(), 3);
        let rep = b.report();
        assert!(rep.contains("spin"));
        assert!(rep.contains("/s"));
        assert!(b.results()[1].mean_ms() >= 0.2);
    }

    #[test]
    fn median_of_medians_shrugs_off_outliers() {
        let spiky = CaseResult {
            name: "spiky".into(),
            // 14 honest ~1ms timings + one 1000ms noisy-neighbor spike
            iters_ms: (0..14).map(|i| 1.0 + (i as f64) * 0.01).chain([1000.0]).collect(),
            items_per_iter: None,
        };
        assert!(spiky.mean_ms() > 60.0, "mean is wrecked: {}", spiky.mean_ms());
        assert!(spiky.mom_ms() < 1.2, "mom must hold: {}", spiky.mom_ms());
        // degenerate sizes
        let one = CaseResult { name: "one".into(), iters_ms: vec![3.0], items_per_iter: None };
        assert_eq!(one.mom_ms(), 3.0);
        let none = CaseResult { name: "none".into(), iters_ms: vec![], items_per_iter: None };
        assert_eq!(none.mom_ms(), 0.0);
    }

    #[test]
    fn baseline_gate_fails_only_on_regression() {
        let mut b = Bench::new("gate", 0, 3);
        b.case("work", || {
            crate::sync::thread::sleep(std::time::Duration::from_micros(300));
        });
        let now = b.results()[0].mom_ms();
        // Baseline much slower than now → pass; much faster → fail.
        let mk = |ms: f64| {
            crate::codec::parse(&format!(
                r#"{{"bench":"gate","cases":[{{"name":"work","mom_ms":{ms}}}]}}"#
            ))
            .unwrap()
        };
        b.compare_baseline(&mk(now * 10.0), 25.0).unwrap();
        let err = b.compare_baseline(&mk(now / 10.0), 25.0).unwrap_err();
        assert!(err.to_string().contains("perf regression"), "{err}");
        // wrapper form ({"harness": ...}) and unknown-case tolerance
        let wrapped = crate::codec::parse(&format!(
            r#"{{"harness":{{"cases":[{{"name":"work","mom_ms":{}}}]}},"serve":[]}}"#,
            now * 10.0
        ))
        .unwrap();
        b.compare_baseline(&wrapped, 25.0).unwrap();
        let other = crate::codec::parse(
            r#"{"cases":[{"name":"someone-else","mom_ms":0.0001}]}"#,
        )
        .unwrap();
        b.compare_baseline(&other, 25.0).unwrap();
        // mean_ms fallback for pre-mom baselines
        let legacy = crate::codec::parse(
            r#"{"cases":[{"name":"work","mean_ms":0.000001}]}"#,
        )
        .unwrap();
        assert!(b.compare_baseline(&legacy, 25.0).is_err());
    }

    #[test]
    fn json_baseline_round_trips() {
        let mut b = Bench::new("jsondemo", 0, 2);
        b.case_throughput("c1", 10.0, || {
            black_box(1 + 1);
        });
        b.case("c2", || {
            black_box(2 + 2);
        });
        let v = crate::codec::parse(&b.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("jsondemo"));
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("name").unwrap().as_str(), Some("c1"));
        assert!(cases[0].get("items_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(cases[1].get("items_per_sec"), Some(&crate::codec::Json::Null));
        assert!(cases[1].get("p99_ms").unwrap().as_f64().is_some());
    }
}
