//! Benchmark harness for `cargo bench` (no `criterion` offline).
//!
//! `[[bench]] harness = false` binaries build a [`Bench`] per paper
//! table/figure, register timed closures, and print a fixed-width
//! report with warmup, repetition statistics, and throughput. Also
//! hosts [`black_box`] to keep the optimizer honest.

use std::hint::black_box as std_black_box;
use std::time::Instant;

use crate::util::Percentiles;

/// Re-exported optimizer barrier.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one timed case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    /// Case label.
    pub name: String,
    /// Per-iteration wall time, milliseconds.
    pub iters_ms: Vec<f64>,
    /// Optional items/iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl CaseResult {
    /// Mean ms/iteration.
    pub fn mean_ms(&self) -> f64 {
        self.iters_ms.iter().sum::<f64>() / self.iters_ms.len().max(1) as f64
    }
}

/// A named group of timed cases (≈ one paper table/figure).
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    results: Vec<CaseResult>,
}

impl Bench {
    /// New bench group. `warmup` untimed + `iters` timed repetitions.
    pub fn new(name: &str, warmup: usize, iters: usize) -> Self {
        assert!(iters > 0);
        Bench { name: name.to_string(), warmup, iters, results: Vec::new() }
    }

    /// Time `f` (called once per iteration).
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &CaseResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut iters_ms = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            iters_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
        self.results.push(CaseResult { name: name.to_string(), iters_ms, items_per_iter: None });
        self.results.last().expect("just pushed")
    }

    /// Time `f` processing `items` logical items per iteration
    /// (throughput reported as items/s).
    pub fn case_throughput<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) {
        self.case(name, &mut f);
        self.results.last_mut().expect("just pushed").items_per_iter = Some(items);
    }

    /// Render the report table.
    pub fn report(&self) -> String {
        let mut s = format!("\n== bench: {} ({} iters) ==\n", self.name, self.iters);
        s.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>14}\n",
            "case", "mean ms", "p50 ms", "p95 ms", "throughput"
        ));
        for r in &self.results {
            let mut p = Percentiles::new();
            for &x in &r.iters_ms {
                p.push(x);
            }
            let thr = match r.items_per_iter {
                Some(items) => format!("{:>11.0}/s", items / (r.mean_ms() / 1e3)),
                None => "-".to_string(),
            };
            s.push_str(&format!(
                "{:<44} {:>12.3} {:>12.3} {:>12.3} {:>14}\n",
                r.name,
                r.mean_ms(),
                p.pct(50.0),
                p.pct(95.0),
                thr
            ));
        }
        s
    }

    /// Print the report to stdout.
    pub fn print(&self) {
        print!("{}", self.report());
    }

    /// Access raw results (assertions in bench smoke tests).
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// JSON baseline encoding — the machine-readable twin of
    /// [`Bench::report`]. CI uploads these per-bench baselines as
    /// artifacts (`BENCH_*.json`) so perf trajectories can be diffed
    /// across commits without scraping the text tables.
    pub fn to_json(&self) -> crate::codec::Json {
        use crate::codec::Json;
        Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("schema", Json::Num(1.0)),
            ("iters", Json::Num(self.iters as f64)),
            (
                "cases",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            let mut p = Percentiles::new();
                            for &x in &r.iters_ms {
                                p.push(x);
                            }
                            let q = p.pcts(&[50.0, 95.0, 99.0]);
                            Json::obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("mean_ms", Json::Num(r.mean_ms())),
                                ("p50_ms", Json::Num(q[0])),
                                ("p95_ms", Json::Num(q[1])),
                                ("p99_ms", Json::Num(q[2])),
                                (
                                    "items_per_sec",
                                    match r.items_per_iter {
                                        Some(items) => {
                                            Json::Num(items / (r.mean_ms() / 1e3))
                                        }
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_and_reports() {
        let mut b = Bench::new("demo", 1, 3);
        let mut n = 0u64;
        b.case("spin", || {
            for i in 0..10_000u64 {
                n = n.wrapping_add(black_box(i));
            }
        });
        b.case_throughput("items", 100.0, || {
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert_eq!(b.results().len(), 2);
        assert_eq!(b.results()[0].iters_ms.len(), 3);
        let rep = b.report();
        assert!(rep.contains("spin"));
        assert!(rep.contains("/s"));
        assert!(b.results()[1].mean_ms() >= 0.2);
    }

    #[test]
    fn json_baseline_round_trips() {
        let mut b = Bench::new("jsondemo", 0, 2);
        b.case_throughput("c1", 10.0, || {
            black_box(1 + 1);
        });
        b.case("c2", || {
            black_box(2 + 2);
        });
        let v = crate::codec::parse(&b.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("jsondemo"));
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("name").unwrap().as_str(), Some("c1"));
        assert!(cases[0].get("items_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(cases[1].get("items_per_sec"), Some(&crate::codec::Json::Null));
        assert!(cases[1].get("p99_ms").unwrap().as_f64().is_some());
    }
}
