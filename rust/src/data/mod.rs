//! Benchmark streams: materialized sample sets, orderings, and the
//! §5.4 distribution-shift transforms.

use crate::codec::Json;
use crate::config::BenchmarkId;
use crate::error::{Error, Result};
use crate::prng::Rng;
use crate::text::{Doc, Generator, Stratum};

/// One stream element, fully featurization-ready.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sample {
    /// Stable id (position in the generated set).
    pub id: usize,
    /// Document text.
    pub text: String,
    /// Ground-truth label (held by the harness for *metrics only* —
    /// Algorithm 1 never reads it; the expert simulator holds its own
    /// noisy view).
    pub label: usize,
    /// Difficulty stratum (metrics/debugging only).
    pub stratum: Stratum,
    /// Topic/genre category.
    pub category: usize,
    /// Document token length.
    pub len: usize,
}

impl Sample {
    /// JSON encoding (wire protocol: `serve::net` request frames).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            ("label", Json::Num(self.label as f64)),
            ("stratum", Json::Str(self.stratum.name().to_string())),
            ("category", Json::Num(self.category as f64)),
            ("len", Json::Num(self.len as f64)),
        ])
    }

    /// Inverse of [`Sample::to_json`].
    pub fn from_json(v: &Json) -> Result<Self> {
        let field = |k: &str| {
            v.get(k)
                .ok_or_else(|| Error::Wire(format!("sample missing field '{k}'")))
        };
        let num = |k: &str| {
            field(k)?
                .as_usize()
                .ok_or_else(|| Error::Wire(format!("sample field '{k}' not a usize")))
        };
        let stratum_name = field("stratum")?
            .as_str()
            .ok_or_else(|| Error::Wire("sample stratum not a string".into()))?;
        Ok(Sample {
            id: num("id")?,
            text: field("text")?
                .as_str()
                .ok_or_else(|| Error::Wire("sample text not a string".into()))?
                .to_string(),
            label: num("label")?,
            stratum: Stratum::from_name(stratum_name).ok_or_else(|| {
                Error::Wire(format!("unknown sample stratum '{stratum_name}'"))
            })?,
            category: num("category")?,
            len: num("len")?,
        })
    }
}

/// A materialized benchmark: samples + metadata.
#[derive(Clone, Debug)]
pub struct Benchmark {
    /// Which paper benchmark this instantiates.
    pub id: BenchmarkId,
    /// Number of classes.
    pub classes: usize,
    /// The sample set in generation order.
    pub samples: Vec<Sample>,
}

impl Benchmark {
    /// Generate the full-size benchmark (paper stream lengths).
    pub fn build(id: BenchmarkId, seed: u64) -> Self {
        Benchmark::build_sized(id, seed, id.stream_len())
    }

    /// Generate with an explicit size (tests / quick sweeps).
    pub fn build_sized(id: BenchmarkId, seed: u64, n: usize) -> Self {
        let mut g = Generator::new(id, seed);
        let samples = (0..n)
            .map(|i| {
                let Doc { text, label, stratum, category, len } = g.sample();
                Sample { id: i, text, label, stratum, category, len }
            })
            .collect();
        Benchmark { id, classes: id.classes(), samples }
    }

    /// Stream in generation order.
    pub fn stream(&self) -> Vec<&Sample> {
        self.samples.iter().collect()
    }

    /// Stream under a [`StreamOrder`] transform.
    pub fn stream_ordered(&self, order: StreamOrder, seed: u64) -> Vec<&Sample> {
        let mut idx: Vec<usize> = (0..self.samples.len()).collect();
        match order {
            StreamOrder::Natural => {}
            StreamOrder::Shuffled => {
                Rng::new(seed ^ 0x5805FF1E).shuffle(&mut idx);
            }
            StreamOrder::LengthAscending => {
                idx.sort_by_key(|&i| (self.samples[i].len, i));
            }
            StreamOrder::CategoryHoldout(cat) => {
                // §5.4: all documents of `cat` moved to the end of the
                // stream (the system never sees the category until the
                // final segment — "comedy reviews last").
                let (rest, held): (Vec<usize>, Vec<usize>) =
                    idx.into_iter().partition(|&i| self.samples[i].category != cat);
                idx = rest;
                idx.extend(held);
            }
        }
        idx.into_iter().map(|i| &self.samples[i]).collect()
    }

    /// Fraction of samples in each stratum (diagnostics).
    pub fn strata_fractions(&self) -> (f64, f64, f64) {
        let n = self.samples.len().max(1) as f64;
        let mut e = 0.0;
        let mut m = 0.0;
        let mut h = 0.0;
        for s in &self.samples {
            match s.stratum {
                Stratum::Easy => e += 1.0,
                Stratum::Medium => m += 1.0,
                Stratum::Hard => h += 1.0,
            }
        }
        (e / n, m / n, h / n)
    }
}

/// Stream ordering transforms (§5.4 robustness experiments).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamOrder {
    /// Generation order (i.i.d. stream — the default setting).
    Natural,
    /// Uniform shuffle (control).
    Shuffled,
    /// Length-ascending — the paper's input-length distribution shift.
    LengthAscending,
    /// All documents of one category moved to the end — the paper's
    /// input-category distribution shift ("comedy last").
    CategoryHoldout(usize),
}

/// The paper's category-shift scenario on IMDB holds out roughly 1/3 of
/// the stream (8 140 / 25 000 comedy reviews). With 10 uniform synthetic
/// categories, holding out 3 of them reproduces the fraction; we fold
/// them into one reported category by convention (category 0..2 → "comedy").
pub const IMDB_HELDOUT_CATEGORY: usize = 0;

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Benchmark {
        Benchmark::build_sized(BenchmarkId::Imdb, 11, 400)
    }

    #[test]
    fn build_is_deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.samples.len(), 400);
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn natural_order_is_identity() {
        let b = small();
        let s = b.stream_ordered(StreamOrder::Natural, 0);
        assert!(s.iter().enumerate().all(|(i, x)| x.id == i));
    }

    #[test]
    fn shuffle_is_permutation() {
        let b = small();
        let s = b.stream_ordered(StreamOrder::Shuffled, 3);
        let mut ids: Vec<usize> = s.iter().map(|x| x.id).collect();
        assert_ne!(ids, (0..400).collect::<Vec<_>>());
        ids.sort_unstable();
        assert_eq!(ids, (0..400).collect::<Vec<_>>());
    }

    #[test]
    fn length_ascending_sorts() {
        let b = small();
        let s = b.stream_ordered(StreamOrder::LengthAscending, 0);
        assert!(s.windows(2).all(|w| w[0].len <= w[1].len));
    }

    #[test]
    fn category_holdout_moves_category_to_tail() {
        let b = small();
        let s = b.stream_ordered(StreamOrder::CategoryHoldout(2), 0);
        let first_held = s.iter().position(|x| x.category == 2).unwrap();
        assert!(s[first_held..].iter().all(|x| x.category == 2));
        assert_eq!(s.len(), 400);
    }

    #[test]
    fn sample_json_roundtrips_exactly() {
        let b = small();
        for s in b.samples.iter().take(16) {
            let text = s.to_json().to_string_compact();
            let v = crate::codec::parse(&text).unwrap();
            assert_eq!(&Sample::from_json(&v).unwrap(), s);
        }
        assert!(Sample::from_json(&Json::Null).is_err());
        let mut v = crate::codec::parse(
            &b.samples[0].to_json().to_string_compact(),
        )
        .unwrap();
        if let Json::Obj(m) = &mut v {
            m.insert("stratum".into(), Json::Str("impossible".into()));
        }
        assert!(Sample::from_json(&v).is_err(), "unknown stratum must be rejected");
    }

    #[test]
    fn strata_fractions_sum_to_one() {
        let (e, m, h) = small().strata_fractions();
        assert!((e + m + h - 1.0).abs() < 1e-9);
        assert!(e > m && e > h); // imdb preset is easy-dominated
    }
}
