//! Small shared utilities: statistics, ring buffers, timing, math.

use std::time::Instant;

/// Online summary statistics over f64 samples (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Count of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a stored sample set (for latency reporting).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    /// Empty sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    /// Number of observations.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when no observations recorded.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// p in [0,100]; nearest-rank. Returns 0.0 when empty.
    pub fn pct(&self, p: f64) -> f64 {
        self.pcts(&[p])[0]
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    /// Largest observation (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(0.0)
    }

    /// Absorb another distribution's observations (cross-shard report
    /// aggregation: percentiles over the union, not averages of
    /// per-shard percentiles).
    pub fn merge(&mut self, other: &Percentiles) {
        self.xs.extend_from_slice(&other.xs);
    }

    /// Several percentiles with a single sort (SLO checks, JSON
    /// baselines) — one entry per requested `p`, same semantics as
    /// [`Percentiles::pct`].
    pub fn pcts(&self, ps: &[f64]) -> Vec<f64> {
        if self.xs.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut v = self.xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).expect("nan percentile"));
        ps.iter()
            .map(|&p| {
                let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
                v[rank.min(v.len() - 1)]
            })
            .collect()
    }
}

/// Fixed-capacity FIFO ring buffer — the annotation caches of
/// Algorithm 1 ("Cache Size" in the paper's Tables 3–4).
#[derive(Clone, Debug)]
pub struct Ring<T> {
    buf: Vec<T>,
    cap: usize,
    head: usize,
    len: usize,
}

impl<T: Clone> Ring<T> {
    /// Ring with capacity `cap` (> 0).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring capacity must be positive");
        Ring { buf: Vec::with_capacity(cap), cap, head: 0, len: 0 }
    }

    /// Append, evicting the oldest item when full.
    pub fn push(&mut self, x: T) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            self.len = self.buf.len();
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
            self.len = self.cap;
        }
    }

    /// Number of live items.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no items stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when at capacity.
    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// Capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let (a, b) = self.buf.split_at(self.head.min(self.buf.len()));
        b.iter().chain(a.iter())
    }

    /// Snapshot oldest → newest.
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }

    /// Drop all items.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }
}

/// Wall-clock timer for perf logs.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// argmax over a float slice (first max wins). Empty slices return 0.
#[inline]
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// Shannon entropy of a probability vector, normalized to [0, 1].
pub fn normalized_entropy(p: &[f32]) -> f32 {
    if p.len() <= 1 {
        return 0.0;
    }
    let mut h = 0.0f32;
    for &x in p {
        if x > 1e-9 {
            h -= x * x.ln();
        }
    }
    h / (p.len() as f32).ln()
}

/// Numerically-stable softmax into a new vec.
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(50.0) - 50.0).abs() <= 1.0);
        assert!((p.mean() - 50.5).abs() < 1e-9);
        assert_eq!(p.max(), 100.0);
        let many = p.pcts(&[0.0, 50.0, 100.0]);
        assert_eq!(many[0], p.pct(0.0));
        assert_eq!(many[1], p.pct(50.0));
        assert_eq!(many[2], p.pct(100.0));
        assert_eq!(Percentiles::new().max(), 0.0);
        assert_eq!(Percentiles::new().pcts(&[50.0]), vec![0.0]);
    }

    #[test]
    fn ring_eviction_order() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.to_vec(), vec![2, 3, 4]);
        assert!(r.is_full());
        r.clear();
        assert!(r.is_empty());
    }

    #[test]
    fn ring_partial() {
        let mut r = Ring::new(4);
        r.push("a");
        r.push("b");
        assert_eq!(r.len(), 2);
        assert_eq!(r.to_vec(), vec!["a", "b"]);
    }

    #[test]
    fn argmax_and_entropy() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[]), 0);
        assert!(normalized_entropy(&[0.5, 0.5]) > 0.99);
        assert!(normalized_entropy(&[1.0, 0.0]) < 0.01);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // stability under huge logits
        let p = softmax(&[1000.0, 1001.0]);
        assert!(p.iter().all(|x| x.is_finite()));
    }
}
