//! Experiment registry: seed-pinned run specifications.
//!
//! Every regenerable experiment — a Table 1 cell, a curve point, a
//! shift cell — is named by a [`RunSpec`]. `ocl reproduce`, the `eval`
//! regenerators, and the bench harnesses all *execute the same specs*,
//! so a number in DESIGN.md §10, a line in a `reports/` file, and a
//! bench timing always refer to the same workload. Budgets are stated
//! the way the paper states them (absolute calls at full stream size,
//! or a stream fraction) and resolved against a [`Harness`]'s scale so
//! the budget *fraction* axis matches the paper at any scale.

use crate::config::{BenchmarkId, ExpertId, ModelKind};
use crate::data::{StreamOrder, IMDB_HELDOUT_CATEGORY};
use crate::error::Result;
use crate::eval::{table1_budgets, Harness, RunResult};

/// Budget-sweep fractions of the Figs 3/4/10/11 cost–accuracy curves.
pub const CURVE_FRACS: [f64; 7] = [0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.8];

/// Budget fractions of the §5.4 shift experiments (Fig 9 / Table 2).
pub const SHIFT_FRACS: [f64; 4] = [0.1, 0.2, 0.3, 0.5];

/// Which method a spec runs (the Table 1 row set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Online cascade learning (the paper's method), small cascade.
    Ocl,
    /// Online cascade learning with the 4-level cascade (§5.3).
    OclLarge,
    /// Online-ensemble baseline.
    OnlineEnsemble,
    /// Offline distillation into logistic regression.
    DistillLr,
    /// Offline distillation into the BERT-base surrogate.
    DistillBert,
}

impl Method {
    /// The Table 1 method rows, in the paper's row order.
    pub const TABLE1: [Method; 4] =
        [Method::DistillLr, Method::DistillBert, Method::OnlineEnsemble, Method::Ocl];

    /// Canonical id fragment (spec names, bench case labels).
    pub fn name(self) -> &'static str {
        match self {
            Method::Ocl => "ocl",
            Method::OclLarge => "ocl-large",
            Method::OnlineEnsemble => "oel",
            Method::DistillLr => "distill-lr",
            Method::DistillBert => "distill-bert",
        }
    }

    /// Display name (Table 1 row labels).
    pub fn display(self) -> &'static str {
        match self {
            Method::Ocl => "Online Cascade (ours)",
            Method::OclLarge => "Online Cascade (large)",
            Method::OnlineEnsemble => "Online Ensemble",
            Method::DistillLr => "Distilled LR",
            Method::DistillBert => "Distilled BERT-base",
        }
    }
}

/// How a spec's expert-call budget 𝒩 is stated.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BudgetSpec {
    /// No cap on expert calls.
    Unlimited,
    /// Absolute calls at the paper's full stream size (Table 1 𝒩),
    /// rescaled by the harness so the budget fraction stays exact.
    PaperCalls(usize),
    /// Fraction of the (scaled) stream length.
    Fraction(f64),
}

/// One deterministic, seed-pinned experiment run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Stable id, e.g. `table1/imdb/gpt35/ocl/b1`.
    pub name: String,
    /// Benchmark stream.
    pub bench: BenchmarkId,
    /// LLM expert profile.
    pub expert: ExpertId,
    /// Method under test.
    pub method: Method,
    /// Expert-call budget.
    pub budget: BudgetSpec,
    /// Stream ordering (distribution-shift scenarios).
    pub order: StreamOrder,
}

impl RunSpec {
    /// Resolve the budget to absolute calls at the harness's scale.
    pub fn budget_calls(&self, h: &Harness) -> Option<u64> {
        match self.budget {
            BudgetSpec::Unlimited => None,
            BudgetSpec::PaperCalls(n) => Some(h.scaled_budget(self.bench, n)),
            BudgetSpec::Fraction(f) => {
                Some(((h.stream_len(self.bench) as f64) * f).round() as u64)
            }
        }
    }

    /// Execute under the Table-1 split protocol (learning and budget
    /// span the whole stream; accuracy is measured on the second half,
    /// identical to the distillation test set — see [`Harness`]).
    ///
    /// The baselines take their budget as a hard number, so
    /// [`BudgetSpec::Unlimited`] resolves to the full stream length for
    /// them — an every-sample annotation budget *is* "uncapped" for
    /// methods whose spend is proportional to their cap.
    pub fn execute(&self, h: &Harness) -> Result<RunResult> {
        let budget = self.budget_calls(h);
        let capped = budget.unwrap_or(h.stream_len(self.bench) as u64);
        match self.method {
            Method::Ocl => {
                h.run_ocl_split(self.bench, self.expert, budget, false, self.order)
            }
            Method::OclLarge => {
                h.run_ocl_split(self.bench, self.expert, budget, true, self.order)
            }
            Method::OnlineEnsemble => {
                h.run_oel_split(self.bench, self.expert, capped, self.order)
            }
            Method::DistillLr => {
                h.run_distill(self.bench, self.expert, ModelKind::Lr, capped)
            }
            Method::DistillBert => {
                h.run_distill(self.bench, self.expert, ModelKind::TfmBase, capped)
            }
        }
    }
}

/// The spec for one Table 1 cell: (benchmark, method, budget column).
/// `budget_idx` indexes [`table1_budgets`] (0 = low, 1 = mid, 2 = high).
pub fn table1_spec(
    bench: BenchmarkId,
    expert: ExpertId,
    method: Method,
    budget_idx: usize,
) -> RunSpec {
    RunSpec {
        name: format!(
            "table1/{}/{}/{}/b{budget_idx}",
            bench.name(),
            expert.name(),
            method.name()
        ),
        bench,
        expert,
        method,
        budget: BudgetSpec::PaperCalls(table1_budgets(bench)[budget_idx]),
        order: StreamOrder::Natural,
    }
}

/// Every Table 1 cell for one benchmark (budget columns × method rows).
pub fn table1_specs(bench: BenchmarkId, expert: ExpertId) -> Vec<RunSpec> {
    let mut v = Vec::new();
    for bi in 0..table1_budgets(bench).len() {
        for m in Method::TABLE1 {
            v.push(table1_spec(bench, expert, m, bi));
        }
    }
    v
}

/// One cost–accuracy curve point (Figs 3/4/10/11) at a budget fraction.
pub fn curve_spec(bench: BenchmarkId, expert: ExpertId, method: Method, frac: f64) -> RunSpec {
    RunSpec {
        name: format!(
            "curves/{}/{}/{}/{:.0}pct",
            bench.name(),
            expert.name(),
            method.name(),
            frac * 100.0
        ),
        bench,
        expert,
        method,
        budget: BudgetSpec::Fraction(frac),
        order: StreamOrder::Natural,
    }
}

/// The full curve sweep `eval::curves` regenerates: OCL (small or
/// large) plus the online-ensemble baseline at every [`CURVE_FRACS`]
/// point.
pub fn curve_specs(bench: BenchmarkId, expert: ExpertId, large: bool) -> Vec<RunSpec> {
    let ocl = if large { Method::OclLarge } else { Method::Ocl };
    CURVE_FRACS
        .iter()
        .flat_map(|&f| {
            [
                curve_spec(bench, expert, ocl, f),
                curve_spec(bench, expert, Method::OnlineEnsemble, f),
            ]
        })
        .collect()
}

/// The §5.4 shift scenarios: (name, stream ordering). Index 0 is the
/// natural-order control the shifted runs are compared against.
pub fn shift_scenarios() -> [(&'static str, StreamOrder); 3] {
    [
        ("natural", StreamOrder::Natural),
        ("length-sorted", StreamOrder::LengthAscending),
        ("category-holdout", StreamOrder::CategoryHoldout(IMDB_HELDOUT_CATEGORY)),
    ]
}

/// One shift cell (always IMDB — the paper's §5.4 setting).
pub fn shift_spec(
    expert: ExpertId,
    scenario: &str,
    order: StreamOrder,
    method: Method,
    frac: f64,
) -> RunSpec {
    RunSpec {
        name: format!(
            "shift/{scenario}/{}/{}/{:.0}pct",
            expert.name(),
            method.name(),
            frac * 100.0
        ),
        bench: BenchmarkId::Imdb,
        expert,
        method,
        budget: BudgetSpec::Fraction(frac),
        order,
    }
}

/// Every cell of one shift scenario: OCL + the online-ensemble
/// baseline at each [`SHIFT_FRACS`] budget fraction.
pub fn shift_specs(expert: ExpertId, scenario: &str, order: StreamOrder) -> Vec<RunSpec> {
    SHIFT_FRACS
        .iter()
        .flat_map(|&f| {
            [
                shift_spec(expert, scenario, order, Method::Ocl, f),
                shift_spec(expert, scenario, order, Method::OnlineEnsemble, f),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_names_are_stable_ids() {
        let s = table1_spec(BenchmarkId::Imdb, ExpertId::Gpt35, Method::Ocl, 1);
        assert_eq!(s.name, "table1/imdb/gpt35/ocl/b1");
        assert_eq!(s.budget, BudgetSpec::PaperCalls(3800));
        let c = curve_spec(BenchmarkId::Fever, ExpertId::Llama70b, Method::OclLarge, 0.3);
        assert_eq!(c.name, "curves/fever/llama70b/ocl-large/30pct");
        let f = shift_spec(
            ExpertId::Gpt35,
            "length-sorted",
            StreamOrder::LengthAscending,
            Method::OnlineEnsemble,
            0.5,
        );
        assert_eq!(f.name, "shift/length-sorted/gpt35/oel/50pct");
        assert_eq!(f.bench, BenchmarkId::Imdb);
    }

    #[test]
    fn budgets_resolve_at_harness_scale() {
        let h = Harness::new(0.02, 5);
        let s = table1_spec(BenchmarkId::Imdb, ExpertId::Gpt35, Method::Ocl, 0);
        // 1300/25000 at a 500-sample stream → 26 calls (matches
        // Harness::scaled_budget).
        assert_eq!(s.budget_calls(&h), Some(26));
        let c = curve_spec(BenchmarkId::Imdb, ExpertId::Gpt35, Method::Ocl, 0.1);
        assert_eq!(c.budget_calls(&h), Some(50));
        let u = RunSpec { budget: BudgetSpec::Unlimited, ..c };
        assert_eq!(u.budget_calls(&h), None);
    }

    #[test]
    fn registries_enumerate_the_paper_grids() {
        let t = table1_specs(BenchmarkId::Isear, ExpertId::Gpt35);
        assert_eq!(t.len(), 12); // 3 budgets × 4 methods
        assert_eq!(t[0].method, Method::DistillLr);
        assert_eq!(t[3].method, Method::Ocl);
        let c = curve_specs(BenchmarkId::Imdb, ExpertId::Gpt35, false);
        assert_eq!(c.len(), CURVE_FRACS.len() * 2);
        let c = curve_specs(BenchmarkId::Imdb, ExpertId::Gpt35, true);
        assert_eq!(c[0].method, Method::OclLarge);
        let sc = shift_scenarios();
        assert_eq!(sc[0].0, "natural");
        let sh = shift_specs(ExpertId::Gpt35, sc[1].0, sc[1].1);
        assert_eq!(sh.len(), SHIFT_FRACS.len() * 2);
    }

    #[test]
    fn tiny_spec_executes() {
        let h = Harness::new(0.02, 7);
        let r = table1_spec(BenchmarkId::Fever, ExpertId::Gpt35, Method::Ocl, 1)
            .execute(&h)
            .unwrap();
        assert!(r.accuracy > 0.0 && r.accuracy <= 1.0);
        assert!(r.llm_calls > 0);
    }
}
