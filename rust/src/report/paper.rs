//! Paper-reference operating points (Tables 1/2/5, Figs 2–4, App. B.1)
//! and the tolerance bands the reproduction is judged against.
//!
//! The numbers here are the *targets* `ocl reproduce` compares measured
//! values to. Two provenance classes:
//!
//! * **Paper-exact** — values the paper states directly: the expert
//!   zero-shot accuracies (shared with `sim::ExpertProfile`, which
//!   calibrates the simulator to the same numbers), the Table 1 budget
//!   columns (via `eval::table1_budgets`), the Table 5 length-bucket
//!   endpoints, and the App. B.1 latency anchors.
//! * **Chart-read** — per-budget OCL accuracies and shift deltas read
//!   off the paper's tables/figures at the featured operating points.
//!
//! The tolerance bands are deliberately wide where the benchmark
//! substitution (DESIGN.md §3) adds slack — the synthetic streams
//! preserve difficulty *composition*, not the exact text distribution —
//! and tight where the pipeline is analytic (App. B.1) or directly
//! calibrated (expert accuracy).

use crate::config::{BenchmarkId, ExpertId};
use crate::eval::table1_budgets;
use crate::sim::ExpertProfile;

/// Expert zero-shot accuracy (Table 1 LLM rows) — the same constants
/// `sim::expert` calibrates the simulator against.
pub fn expert_accuracy(bench: BenchmarkId, expert: ExpertId) -> f64 {
    ExpertProfile::for_pair(expert, bench).accuracy
}

/// Table 1 OCL accuracy at budget column `budget_idx` (0 = low,
/// 1 = mid, 2 = high — the columns of [`table1_budgets`]).
pub fn table1_ocl_accuracy(bench: BenchmarkId, expert: ExpertId, budget_idx: usize) -> f64 {
    let a: [f64; 3] = match (expert, bench) {
        (ExpertId::Gpt35, BenchmarkId::Imdb) => [0.9002, 0.9324, 0.9378],
        (ExpertId::Gpt35, BenchmarkId::HateSpeech) => [0.7423, 0.8088, 0.8316],
        (ExpertId::Gpt35, BenchmarkId::Isear) => [0.6412, 0.6631, 0.6905],
        (ExpertId::Gpt35, BenchmarkId::Fever) => [0.7101, 0.7716, 0.7940],
        (ExpertId::Llama70b, BenchmarkId::Imdb) => [0.8891, 0.9205, 0.9296],
        (ExpertId::Llama70b, BenchmarkId::HateSpeech) => [0.7056, 0.7598, 0.7754],
        (ExpertId::Llama70b, BenchmarkId::Isear) => [0.6130, 0.6397, 0.6718],
        (ExpertId::Llama70b, BenchmarkId::Fever) => [0.6893, 0.7442, 0.7659],
    };
    a[budget_idx]
}

/// Table 1 cost reduction at budget column `budget_idx`: the paper
/// charges the budget as spent, so the reference is `1 − 𝒩/T` — up to
/// 90% at the featured operating points (the abstract's headline).
pub fn table1_cost_reduction(bench: BenchmarkId, budget_idx: usize) -> f64 {
    1.0 - table1_budgets(bench)[budget_idx] as f64 / bench.stream_len() as f64
}

/// Budget fractions at which the record samples the Fig 3 curves.
pub const CURVE_POINT_FRACS: [f64; 2] = [0.1, 0.3];

/// Fig 3/4 OCL accuracy read at a featured budget fraction (`None`
/// where the paper plots no such point for the pair).
pub fn fig_curve_accuracy(bench: BenchmarkId, expert: ExpertId, frac: f64) -> Option<f64> {
    let pts: &[(f64, f64)] = match (expert, bench) {
        (ExpertId::Gpt35, BenchmarkId::Imdb) => &[(0.1, 0.9280), (0.3, 0.9360)],
        (ExpertId::Gpt35, BenchmarkId::HateSpeech) => &[(0.1, 0.7855), (0.3, 0.8189)],
        _ => &[],
    };
    pts.iter().find(|(f, _)| (f - frac).abs() < 1e-9).map(|&(_, a)| a)
}

/// Table 2 average-accuracy shift vs the natural order, in percentage
/// points (negative = drop), for a §5.4 scenario name.
pub fn table2_shift_drop_pts(expert: ExpertId, scenario: &str) -> Option<f64> {
    if expert != ExpertId::Gpt35 {
        return None; // Table 2 is reported for the GPT-3.5 expert only.
    }
    match scenario {
        "length-sorted" => Some(-1.1),
        "category-holdout" => Some(-2.4),
        _ => None,
    }
}

/// Table 5: expert accuracy on the shortest IMDB length quintile.
pub const TABLE5_SHORTEST: f64 = 0.955;
/// Table 5: expert accuracy on the longest IMDB length quintile.
pub const TABLE5_LONGEST: f64 = 0.924;

/// Band half-width for expert zero-shot accuracy (fraction): the
/// simulator is calibrated to the paper value, so this is tight.
pub const EXPERT_TOL: f64 = 0.02;
/// Band half-width for OCL accuracies (fraction): wide — the synthetic
/// streams preserve difficulty composition, not exact text statistics.
pub const OCL_ACC_TOL: f64 = 0.06;
/// Lower-bound slack for cost reduction (fraction): the paced budget
/// may legitimately under-spend (reduction above the reference always
/// passes), but must not overshoot the paper's spend by more than this.
pub const COST_TOL: f64 = 0.05;
/// Band half-width for Fig 3 curve operating points (fraction).
pub const CURVE_TOL: f64 = 0.06;
/// Band half-width for Table 2 shift deltas (percentage points).
pub const SHIFT_TOL_PTS: f64 = 5.0;
/// Band half-width for the Table 5 quintile endpoints (fraction).
pub const TABLE5_TOL: f64 = 0.04;
/// Upper bound on the final average regret γ/T (Theorem 3.2 says ≤ 0
/// asymptotically; finite streams get this much headroom).
pub const REGRET_TOL: f64 = 0.05;
/// Band half-width for the App. B.1 prefill latency (seconds).
pub const PREFILL_TOL_SECS: f64 = 0.2;
/// Intro arithmetic: servers needed for 1M docs/hour.
pub const SERVERS_1M: f64 = 1000.0;
/// Band half-width for the server count.
pub const SERVERS_TOL: f64 = 50.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn references_cover_every_pair_and_budget() {
        for expert in ExpertId::ALL {
            for bench in BenchmarkId::ALL {
                let e = expert_accuracy(bench, expert);
                assert!((0.5..1.0).contains(&e), "{e}");
                let mut last = 0.0;
                for bi in 0..3 {
                    let a = table1_ocl_accuracy(bench, expert, bi);
                    // More budget never hurts in the reference tables,
                    // and OCL parallels (never exceeds) the expert.
                    assert!(a >= last, "{bench:?} {expert:?} b{bi}");
                    assert!(a < e + 0.01, "{bench:?} {expert:?} b{bi}: {a} vs expert {e}");
                    last = a;
                }
            }
        }
    }

    #[test]
    fn cost_reduction_hits_the_headline() {
        // The abstract: "cutting down inference costs by as much as 90%".
        let max = BenchmarkId::ALL
            .iter()
            .map(|&b| table1_cost_reduction(b, 0))
            .fold(0.0, f64::max);
        assert!(max >= 0.90, "{max}");
        // Every reference reduction is a real saving.
        for bench in BenchmarkId::ALL {
            for bi in 0..3 {
                let r = table1_cost_reduction(bench, bi);
                assert!((0.2..1.0).contains(&r), "{bench:?} b{bi}: {r}");
            }
        }
    }

    #[test]
    fn chart_read_points_resolve() {
        for &f in &CURVE_POINT_FRACS {
            assert!(fig_curve_accuracy(BenchmarkId::Imdb, ExpertId::Gpt35, f).is_some());
        }
        assert!(fig_curve_accuracy(BenchmarkId::Fever, ExpertId::Gpt35, 0.1).is_none());
        assert!(fig_curve_accuracy(BenchmarkId::Imdb, ExpertId::Gpt35, 0.17).is_none());
        assert!(table2_shift_drop_pts(ExpertId::Gpt35, "length-sorted").is_some());
        assert!(table2_shift_drop_pts(ExpertId::Gpt35, "natural").is_none());
        assert!(table2_shift_drop_pts(ExpertId::Llama70b, "length-sorted").is_none());
        assert!(TABLE5_SHORTEST > TABLE5_LONGEST);
    }
}
