//! The reproduction record: paper-vs-measured reporting (`DESIGN.md §10`).
//!
//! This module turns the scattered eval entry points into **one
//! deterministic pipeline**. A [`registry`] of seed-pinned
//! [`registry::RunSpec`]s names every regenerable experiment; [`paper`]
//! carries the transcribed reference operating points of Tables 1/2/5
//! and Figs 2–4 plus the tolerance bands the reproduction is judged
//! against; and [`reproduce`] executes a multi-seed sweep and renders
//! the result as a machine-readable JSON record and a GitHub-markdown
//! table with paper/measured/Δ/band/status columns.
//!
//! Everything here is deterministic at a pinned `(scale, seeds)`: the
//! benchmark generators, the expert simulator, and the host models are
//! all seeded, no wall-clock value is ever emitted, and the JSON codec
//! prints shortest-round-trip decimals — so `ocl reproduce` regenerates
//! `reports/reproduce_<profile>.{json,md}` **byte-identically**, which
//! is what CI's `reproduce-quick` job checks (schema drift shows up as
//! a diff). `DESIGN.md §10` is the curated splice of the `full`
//! profile's tables.

pub mod paper;
pub mod registry;

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::cascade::Cascade;
use crate::codec::{self, Json};
use crate::config::{BenchmarkId, CascadeConfig, ExpertId};
use crate::error::{Error, Result};
use crate::eval::{self, table1_budgets, Harness};
use crate::sim::cost::LatencyModel;

/// Version stamp of the report JSON layout. Bump on any breaking shape
/// change; [`Report::from_json`] rejects mismatches, which is CI's
/// schema-drift gate.
pub const SCHEMA_VERSION: usize = 1;

/// Citation line embedded in every report.
pub const SOURCE: &str =
    "Nie et al., Online Cascade Learning for Efficient Inference over Streams (ICML 2024)";

// ---------------------------------------------------------------------------
// Data model
// ---------------------------------------------------------------------------

/// How a tolerance band judges the measured-minus-paper delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BandKind {
    /// Pass when `|Δ| ≤ tol` (reproduction should land *near* the paper).
    TwoSided,
    /// Pass when `Δ ≤ tol` (smaller/more negative is fine — e.g. the
    /// no-regret bound, where beating the best fixed policy is success).
    UpperBound,
    /// Pass when `Δ ≥ −tol` (larger is fine — e.g. cost reduction,
    /// where under-spending the expert budget is success).
    LowerBound,
}

impl BandKind {
    /// Canonical name (JSON encoding).
    pub fn name(self) -> &'static str {
        match self {
            BandKind::TwoSided => "two-sided",
            BandKind::UpperBound => "upper",
            BandKind::LowerBound => "lower",
        }
    }

    /// Parse a [`BandKind::name`] string.
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "two-sided" => Ok(BandKind::TwoSided),
            "upper" => Ok(BandKind::UpperBound),
            "lower" => Ok(BandKind::LowerBound),
            _ => Err(Error::Config(format!("unknown band kind '{s}'"))),
        }
    }
}

/// A pass/fail tolerance band around a paper reference value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Band {
    /// Which side(s) of the reference the band constrains.
    pub kind: BandKind,
    /// Half-width of the band, in the row's natural unit.
    pub tol: f64,
}

impl Band {
    /// Whether a measured-minus-paper `delta` falls inside the band.
    pub fn contains(&self, delta: f64) -> bool {
        match self.kind {
            BandKind::TwoSided => delta.abs() <= self.tol,
            BandKind::UpperBound => delta <= self.tol,
            BandKind::LowerBound => delta >= -self.tol,
        }
    }
}

/// Pass/fail/info verdict of one row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Measured value inside the tolerance band.
    Pass,
    /// Measured value outside the tolerance band.
    Fail,
    /// No paper reference (context row) — nothing to judge.
    Info,
}

impl Status {
    /// Canonical name (JSON encoding, markdown status column).
    pub fn name(self) -> &'static str {
        match self {
            Status::Pass => "pass",
            Status::Fail => "FAIL",
            Status::Info => "info",
        }
    }
}

/// A multi-seed aggregate: mean ± sample standard deviation over `n`
/// seeded runs.
#[derive(Clone, Debug, PartialEq)]
pub struct Measurement {
    /// Mean over seeds.
    pub mean: f64,
    /// Sample standard deviation (0 when `n < 2`).
    pub sd: f64,
    /// Number of seeded runs aggregated.
    pub n: usize,
}

impl Measurement {
    /// Aggregate raw per-seed values.
    pub fn from_samples(xs: &[f64]) -> Self {
        let n = xs.len();
        if n == 0 {
            return Measurement { mean: 0.0, sd: 0.0, n: 0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let sd = if n < 2 {
            0.0
        } else {
            let var =
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
            var.sqrt()
        };
        Measurement { mean, sd, n }
    }
}

/// One paper-vs-measured line of the record.
#[derive(Clone, Debug, PartialEq)]
pub struct Row {
    /// Metric label ("OCL accuracy @ N=3800 (15.2% of stream)").
    pub label: String,
    /// Display unit tag: `"%"` (fraction shown ×100), `"pts"`
    /// (percentage points), `"s"` (seconds), `"x"` (ratio), or `""`.
    pub unit: String,
    /// Paper reference value in the natural unit (`None` → info row).
    pub paper: Option<f64>,
    /// Tolerance band (`None` → info row).
    pub band: Option<Band>,
    /// Measured multi-seed aggregate.
    pub measured: Measurement,
}

impl Row {
    /// Measured-minus-paper delta (`None` without a reference).
    pub fn delta(&self) -> Option<f64> {
        self.paper.map(|p| self.measured.mean - p)
    }

    /// Verdict of this row under its band.
    pub fn status(&self) -> Status {
        match (self.delta(), self.band) {
            (Some(d), Some(b)) => {
                if b.contains(d) {
                    Status::Pass
                } else {
                    Status::Fail
                }
            }
            _ => Status::Info,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("unit", Json::Str(self.unit.clone())),
            (
                "paper",
                match self.paper {
                    Some(p) => Json::Num(p),
                    None => Json::Null,
                },
            ),
            (
                "band",
                match self.band {
                    Some(b) => Json::obj(vec![
                        ("kind", Json::Str(b.kind.name().to_string())),
                        ("tol", Json::Num(b.tol)),
                    ]),
                    None => Json::Null,
                },
            ),
            ("mean", Json::Num(self.measured.mean)),
            ("sd", Json::Num(self.measured.sd)),
            ("n", Json::Num(self.measured.n as f64)),
            (
                "delta",
                match self.delta() {
                    Some(d) => Json::Num(d),
                    None => Json::Null,
                },
            ),
            ("status", Json::Str(self.status().name().to_string())),
        ])
    }

    fn from_json(v: &Json) -> Result<Row> {
        let label = v
            .require("label")?
            .as_str()
            .ok_or_else(|| Error::Config("row label must be a string".into()))?
            .to_string();
        let unit = v
            .require("unit")?
            .as_str()
            .ok_or_else(|| Error::Config("row unit must be a string".into()))?
            .to_string();
        let paper = match v.require("paper")? {
            Json::Null => None,
            p => Some(
                p.as_f64()
                    .ok_or_else(|| Error::Config("row paper must be a number".into()))?,
            ),
        };
        let band = match v.require("band")? {
            Json::Null => None,
            b => Some(Band {
                kind: BandKind::from_name(
                    b.require("kind")?
                        .as_str()
                        .ok_or_else(|| Error::Config("band kind must be a string".into()))?,
                )?,
                tol: b
                    .require("tol")?
                    .as_f64()
                    .ok_or_else(|| Error::Config("band tol must be a number".into()))?,
            }),
        };
        let num = |key: &str| -> Result<f64> {
            v.require(key)?
                .as_f64()
                .ok_or_else(|| Error::Config(format!("row {key} must be a number")))
        };
        let row = Row {
            label,
            unit,
            paper,
            band,
            measured: Measurement {
                mean: num("mean")?,
                sd: num("sd")?,
                n: num("n")? as usize,
            },
        };
        // The stored derived fields must agree with what the loaded
        // values recompute — a hand-edited verdict cannot pass the gate.
        let stored_status = v
            .require("status")?
            .as_str()
            .ok_or_else(|| Error::Config("row status must be a string".into()))?;
        if stored_status != row.status().name() {
            return Err(Error::Config(format!(
                "row '{}': stored status '{stored_status}' disagrees with recomputed '{}'",
                row.label,
                row.status().name()
            )));
        }
        let stored_delta = match v.require("delta")? {
            Json::Null => None,
            d => Some(
                d.as_f64()
                    .ok_or_else(|| Error::Config("row delta must be a number".into()))?,
            ),
        };
        if stored_delta != row.delta() {
            return Err(Error::Config(format!(
                "row '{}': stored delta disagrees with mean - paper",
                row.label
            )));
        }
        Ok(row)
    }
}

/// A titled group of rows (≈ one paper table or figure).
#[derive(Clone, Debug, PartialEq)]
pub struct Section {
    /// Stable id ("table1-imdb", "shift", ...).
    pub id: String,
    /// Markdown heading.
    pub title: String,
    /// Paper-vs-measured rows.
    pub rows: Vec<Row>,
}

/// The full reproduction record of one `ocl reproduce` invocation.
#[derive(Clone, Debug, PartialEq)]
pub struct Report {
    /// Profile name ("quick", "full") — selects the output file names.
    pub profile: String,
    /// Stream scale relative to the paper's dataset sizes.
    pub scale: f64,
    /// Seeds aggregated (mean ± sd over these).
    pub seeds: Vec<u64>,
    /// Which LLM expert profile the runs used.
    pub expert: ExpertId,
    /// The record itself.
    pub sections: Vec<Section>,
}

impl Report {
    /// Total row count across sections.
    pub fn rows(&self) -> usize {
        self.sections.iter().map(|s| s.rows.len()).sum()
    }

    /// Whether every banded row passed its tolerance band.
    pub fn passed(&self) -> bool {
        self.sections
            .iter()
            .all(|s| s.rows.iter().all(|r| r.status() != Status::Fail))
    }

    /// JSON encoding (schema [`SCHEMA_VERSION`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Num(SCHEMA_VERSION as f64)),
            ("source", Json::Str(SOURCE.to_string())),
            ("profile", Json::Str(self.profile.clone())),
            ("scale", Json::Num(self.scale)),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("expert", Json::Str(self.expert.name().to_string())),
            (
                "sections",
                Json::Arr(
                    self.sections
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("id", Json::Str(s.id.clone())),
                                ("title", Json::Str(s.title.clone())),
                                (
                                    "rows",
                                    Json::Arr(s.rows.iter().map(Row::to_json).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Decode and schema-validate a [`Report::to_json`] value. Derived
    /// fields (delta, status) are recomputed, so a record whose stored
    /// verdicts disagree with its stored values cannot round-trip
    /// unnoticed.
    pub fn from_json(v: &Json) -> Result<Report> {
        let schema = v
            .require("schema")?
            .as_usize()
            .ok_or_else(|| Error::Config("schema must be an integer".into()))?;
        if schema != SCHEMA_VERSION {
            return Err(Error::Config(format!(
                "report schema v{schema} != supported v{SCHEMA_VERSION}"
            )));
        }
        let profile = v
            .require("profile")?
            .as_str()
            .ok_or_else(|| Error::Config("profile must be a string".into()))?
            .to_string();
        let scale = v
            .require("scale")?
            .as_f64()
            .ok_or_else(|| Error::Config("scale must be a number".into()))?;
        let seeds = v
            .require("seeds")?
            .as_arr()
            .ok_or_else(|| Error::Config("seeds must be an array".into()))?
            .iter()
            .map(|s| {
                s.as_f64()
                    .map(|x| x as u64)
                    .ok_or_else(|| Error::Config("seed must be a number".into()))
            })
            .collect::<Result<Vec<u64>>>()?;
        let expert = ExpertId::from_name(
            v.require("expert")?
                .as_str()
                .ok_or_else(|| Error::Config("expert must be a string".into()))?,
        )?;
        let mut sections = Vec::new();
        for s in v
            .require("sections")?
            .as_arr()
            .ok_or_else(|| Error::Config("sections must be an array".into()))?
        {
            let id = s
                .require("id")?
                .as_str()
                .ok_or_else(|| Error::Config("section id must be a string".into()))?
                .to_string();
            let title = s
                .require("title")?
                .as_str()
                .ok_or_else(|| Error::Config("section title must be a string".into()))?
                .to_string();
            let rows = s
                .require("rows")?
                .as_arr()
                .ok_or_else(|| Error::Config("section rows must be an array".into()))?
                .iter()
                .map(Row::from_json)
                .collect::<Result<Vec<Row>>>()?;
            sections.push(Section { id, title, rows });
        }
        Ok(Report { profile, scale, seeds, expert, sections })
    }

    /// Render the GitHub-markdown record. Deterministic: fixed column
    /// set, fixed decimal formatting, no timestamps or host details.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# Online Cascade Learning — reproduction record");
        let _ = writeln!(out);
        let seeds: Vec<String> = self.seeds.iter().map(|s| s.to_string()).collect();
        let _ = writeln!(
            out,
            "profile `{}` · stream scale {} · seeds {{{}}} (mean ± sd) · expert `{}` · schema v{}",
            self.profile,
            self.scale,
            seeds.join(", "),
            self.expert.name(),
            SCHEMA_VERSION
        );
        let _ = writeln!(out);
        let _ = writeln!(out, "Paper: {SOURCE}.");
        let _ = writeln!(
            out,
            "Benchmarks are the synthetic substitutes of DESIGN.md §3; budget \
             *fractions* match the paper exactly (§5–§6). Regenerate this file \
             byte-identically with `make reproduce-quick` / `make reproduce`."
        );
        for s in &self.sections {
            let _ = writeln!(out);
            let _ = writeln!(out, "## {}", s.title);
            let _ = writeln!(out);
            let _ = writeln!(out, "| metric | paper | measured | Δ | band | status |");
            let _ = writeln!(out, "|:--|--:|--:|--:|:--:|:--:|");
            for r in &s.rows {
                let paper = match r.paper {
                    Some(p) => fmt_val(&r.unit, p),
                    None => "-".to_string(),
                };
                let measured = format!(
                    "{} ± {} (n={})",
                    fmt_val(&r.unit, r.measured.mean),
                    fmt_sd(&r.unit, r.measured.sd),
                    r.measured.n
                );
                let delta = match r.delta() {
                    Some(d) => fmt_delta(&r.unit, d),
                    None => "-".to_string(),
                };
                let band = match r.band {
                    Some(b) => fmt_band(&r.unit, b),
                    None => "-".to_string(),
                };
                let _ = writeln!(
                    out,
                    "| {} | {} | {} | {} | {} | {} |",
                    r.label,
                    paper,
                    measured,
                    delta,
                    band,
                    r.status().name()
                );
            }
        }
        let _ = writeln!(out);
        let _ = writeln!(
            out,
            "Verdict: {} of {} banded rows pass.",
            self.sections
                .iter()
                .flat_map(|s| &s.rows)
                .filter(|r| r.status() == Status::Pass)
                .count(),
            self.sections
                .iter()
                .flat_map(|s| &s.rows)
                .filter(|r| r.status() != Status::Info)
                .count()
        );
        out
    }

    /// Write `reproduce_<profile>.json` + `.md` under `dir`; returns
    /// both paths.
    pub fn write(&self, dir: &str) -> Result<(PathBuf, PathBuf)> {
        std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.to_string(), e))?;
        let base = Path::new(dir);
        let jp = base.join(format!("reproduce_{}.json", self.profile));
        let mp = base.join(format!("reproduce_{}.md", self.profile));
        let mut js = self.to_json().to_string_pretty();
        js.push('\n');
        std::fs::write(&jp, js).map_err(|e| Error::io(jp.display().to_string(), e))?;
        std::fs::write(&mp, self.to_markdown())
            .map_err(|e| Error::io(mp.display().to_string(), e))?;
        Ok((jp, mp))
    }
}

/// Load and schema-validate a previously written report file (the CI
/// drift gate and `ocl reproduce --check`).
pub fn check_file(path: &Path) -> Result<Report> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(path.display().to_string(), e))?;
    Report::from_json(&codec::parse(&text)?)
}

fn fmt_val(unit: &str, v: f64) -> String {
    match unit {
        "%" => format!("{:.2}%", v * 100.0),
        "pts" => format!("{v:.2} pts"),
        "s" => format!("{v:.2} s"),
        "x" => format!("{v:.3}x"),
        _ => format!("{v:.4}"),
    }
}

fn fmt_sd(unit: &str, v: f64) -> String {
    match unit {
        "%" => format!("{:.2}", v * 100.0),
        _ => format!("{v:.2}"),
    }
}

fn fmt_delta(unit: &str, v: f64) -> String {
    match unit {
        "%" => format!("{:+.2} pts", v * 100.0),
        "pts" => format!("{v:+.2} pts"),
        "s" => format!("{v:+.2} s"),
        _ => format!("{v:+.4}"),
    }
}

fn fmt_band(unit: &str, b: Band) -> String {
    let tol = match unit {
        "%" => format!("{:.1} pts", b.tol * 100.0),
        "pts" => format!("{:.1} pts", b.tol),
        "s" => format!("{:.1} s", b.tol),
        _ => format!("{:.2}", b.tol),
    };
    match b.kind {
        BandKind::TwoSided => format!("± {tol}"),
        BandKind::UpperBound => format!("≤ +{tol}"),
        BandKind::LowerBound => format!("≥ -{tol}"),
    }
}

// ---------------------------------------------------------------------------
// The reproduce pipeline
// ---------------------------------------------------------------------------

/// What `ocl reproduce` runs: profile + scale + seeds + scope.
#[derive(Clone, Debug)]
pub struct ReproduceOpts {
    /// Profile name → output file names (`reproduce_<profile>.*`).
    pub profile: String,
    /// Stream scale vs the paper's dataset sizes.
    pub scale: f64,
    /// Seeds to aggregate over.
    pub seeds: Vec<u64>,
    /// Expert profile.
    pub expert: ExpertId,
    /// Benchmarks in scope (IMDB additionally triggers the curve,
    /// shift, Table-5, and no-regret sections).
    pub benches: Vec<BenchmarkId>,
}

impl ReproduceOpts {
    /// The CI smoke profile: tiny pinned scale, one seed.
    pub fn quick() -> Self {
        ReproduceOpts {
            profile: "quick".to_string(),
            scale: 0.02,
            seeds: vec![1],
            expert: ExpertId::Gpt35,
            benches: BenchmarkId::ALL.to_vec(),
        }
    }

    /// The pinned record profile behind `make reproduce` and the
    /// DESIGN.md §10 tables: scale 0.1, three seeds.
    pub fn full() -> Self {
        ReproduceOpts {
            profile: "full".to_string(),
            scale: 0.1,
            seeds: vec![1, 2, 3],
            expert: ExpertId::Gpt35,
            benches: BenchmarkId::ALL.to_vec(),
        }
    }

    /// Resolve a profile by name.
    pub fn for_profile(name: &str) -> Result<Self> {
        match name {
            "quick" => Ok(ReproduceOpts::quick()),
            "full" => Ok(ReproduceOpts::full()),
            _ => Err(Error::Usage(format!("unknown profile '{name}' (quick|full)"))),
        }
    }
}

/// Parse a comma-separated seed list ("1,2,3").
pub fn parse_seed_list(s: &str) -> Result<Vec<u64>> {
    let seeds = s
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<u64>()
                .map_err(|_| Error::Usage(format!("bad seed '{t}' in --seeds")))
        })
        .collect::<Result<Vec<u64>>>()?;
    if seeds.is_empty() {
        return Err(Error::Usage("--seeds must name at least one seed".into()));
    }
    Ok(seeds)
}

/// Run the full reproduction pipeline and assemble the record.
pub fn reproduce(opts: &ReproduceOpts) -> Result<Report> {
    let mut sections = Vec::new();
    for &bench in &opts.benches {
        sections.push(table1_section(opts, bench)?);
    }
    if opts.benches.contains(&BenchmarkId::Imdb) {
        sections.push(curves_section(opts)?);
        sections.push(shift_section(opts)?);
        sections.push(table5_section(opts)?);
        sections.push(noregret_section(opts)?);
    }
    sections.push(costmodel_section());
    Ok(Report {
        profile: opts.profile.clone(),
        scale: opts.scale,
        seeds: opts.seeds.clone(),
        expert: opts.expert,
        sections,
    })
}

/// Table 1 for one benchmark: expert zero-shot accuracy, then OCL
/// accuracy + cost reduction at each of the paper's three budgets.
fn table1_section(opts: &ReproduceOpts, bench: BenchmarkId) -> Result<Section> {
    let budgets = table1_budgets(bench);
    let mut zero_shot: Vec<f64> = Vec::new();
    let mut acc: Vec<Vec<f64>> = vec![Vec::new(); budgets.len()];
    let mut red: Vec<Vec<f64>> = vec![Vec::new(); budgets.len()];
    for &seed in &opts.seeds {
        let h = Harness::new(opts.scale, seed);
        for (bi, _) in budgets.iter().enumerate() {
            let spec = registry::table1_spec(bench, opts.expert, registry::Method::Ocl, bi);
            let r = spec.execute(&h)?;
            if bi == 0 {
                zero_shot.push(r.expert_accuracy);
            }
            acc[bi].push(r.accuracy);
            red[bi].push(1.0 - r.llm_calls as f64 / h.stream_len(bench) as f64);
        }
    }
    let mut rows = vec![Row {
        label: format!("{} zero-shot accuracy", expert_display(opts.expert)),
        unit: "%".to_string(),
        paper: Some(paper::expert_accuracy(bench, opts.expert)),
        band: Some(Band { kind: BandKind::TwoSided, tol: paper::EXPERT_TOL }),
        measured: Measurement::from_samples(&zero_shot),
    }];
    for (bi, &nb) in budgets.iter().enumerate() {
        let frac = nb as f64 / bench.stream_len() as f64;
        rows.push(Row {
            label: format!("OCL accuracy @ N={nb} ({:.1}% of stream)", frac * 100.0),
            unit: "%".to_string(),
            paper: Some(paper::table1_ocl_accuracy(bench, opts.expert, bi)),
            band: Some(Band { kind: BandKind::TwoSided, tol: paper::OCL_ACC_TOL }),
            measured: Measurement::from_samples(&acc[bi]),
        });
        rows.push(Row {
            label: format!("OCL cost reduction @ N={nb}"),
            unit: "%".to_string(),
            paper: Some(paper::table1_cost_reduction(bench, bi)),
            band: Some(Band { kind: BandKind::LowerBound, tol: paper::COST_TOL }),
            measured: Measurement::from_samples(&red[bi]),
        });
    }
    Ok(Section {
        id: format!("table1-{}", bench.name()),
        title: format!("Table 1 — {} ({} expert)", bench.name(), expert_display(opts.expert)),
        rows,
    })
}

/// Cost–accuracy curve operating points (Fig 3, IMDB).
fn curves_section(opts: &ReproduceOpts) -> Result<Section> {
    let bench = BenchmarkId::Imdb;
    let mut rows = Vec::new();
    for &frac in &paper::CURVE_POINT_FRACS {
        let mut acc = Vec::new();
        for &seed in &opts.seeds {
            let h = Harness::new(opts.scale, seed);
            let spec = registry::curve_spec(bench, opts.expert, registry::Method::Ocl, frac);
            acc.push(spec.execute(&h)?.accuracy);
        }
        rows.push(Row {
            label: format!("OCL accuracy @ budget {:.0}% of stream", frac * 100.0),
            unit: "%".to_string(),
            paper: paper::fig_curve_accuracy(bench, opts.expert, frac),
            band: paper::fig_curve_accuracy(bench, opts.expert, frac)
                .map(|_| Band { kind: BandKind::TwoSided, tol: paper::CURVE_TOL }),
            measured: Measurement::from_samples(&acc),
        });
    }
    Ok(Section {
        id: "curves-imdb".to_string(),
        title: "Fig 3 — cost–accuracy curve operating points (imdb)".to_string(),
        rows,
    })
}

/// §5.4 distribution-shift robustness (Fig 9 / Table 2, IMDB).
fn shift_section(opts: &ReproduceOpts) -> Result<Section> {
    let scenarios = registry::shift_scenarios();
    // Per scenario: per-seed average OCL accuracy across the budget fracs.
    let mut avgs: Vec<Vec<f64>> = vec![Vec::new(); scenarios.len()];
    for &seed in &opts.seeds {
        let h = Harness::new(opts.scale, seed);
        for (si, (name, order)) in scenarios.iter().enumerate() {
            let mut accs = Vec::new();
            for &frac in &registry::SHIFT_FRACS {
                let spec =
                    registry::shift_spec(opts.expert, name, *order, registry::Method::Ocl, frac);
                accs.push(spec.execute(&h)?.accuracy);
            }
            avgs[si].push(accs.iter().sum::<f64>() / accs.len() as f64);
        }
    }
    let mut rows = vec![Row {
        label: "OCL avg accuracy, natural order (across budgets)".to_string(),
        unit: "%".to_string(),
        paper: None,
        band: None,
        measured: Measurement::from_samples(&avgs[0]),
    }];
    for (si, (name, _)) in scenarios.iter().enumerate().skip(1) {
        // Drop vs natural, in percentage points, per seed.
        let drops: Vec<f64> = avgs[si]
            .iter()
            .zip(&avgs[0])
            .map(|(s, n)| (s - n) * 100.0)
            .collect();
        rows.push(Row {
            label: format!("accuracy shift under {name} (vs natural)"),
            unit: "pts".to_string(),
            paper: paper::table2_shift_drop_pts(opts.expert, name),
            band: paper::table2_shift_drop_pts(opts.expert, name)
                .map(|_| Band { kind: BandKind::TwoSided, tol: paper::SHIFT_TOL_PTS }),
            measured: Measurement::from_samples(&drops),
        });
    }
    Ok(Section {
        id: "shift".to_string(),
        title: "Fig 9 / Table 2 — §5.4 distribution-shift robustness (imdb)".to_string(),
        rows,
    })
}

/// Table 5: expert accuracy by document-length quintile (IMDB).
fn table5_section(opts: &ReproduceOpts) -> Result<Section> {
    let mut short: Vec<f64> = Vec::new();
    let mut long: Vec<f64> = Vec::new();
    for &seed in &opts.seeds {
        let h = Harness::new(opts.scale, seed);
        let (b, e) = h.setup(BenchmarkId::Imdb, opts.expert);
        let (sorted, q) = eval::length_quintiles(&b);
        let acc = |xs: &[&crate::data::Sample]| {
            xs.iter().filter(|s| e.peek(s, b.classes) == s.label).count() as f64
                / xs.len().max(1) as f64
        };
        short.push(acc(&sorted[..q]));
        long.push(acc(&sorted[4 * q..]));
    }
    let refs = if opts.expert == ExpertId::Gpt35 {
        (Some(paper::TABLE5_SHORTEST), Some(paper::TABLE5_LONGEST))
    } else {
        (None, None)
    };
    let band = |r: Option<f64>| {
        r.map(|_| Band { kind: BandKind::TwoSided, tol: paper::TABLE5_TOL })
    };
    Ok(Section {
        id: "table5".to_string(),
        title: "Table 5 — expert accuracy by document length (imdb)".to_string(),
        rows: vec![
            Row {
                label: "expert accuracy, shortest length quintile".to_string(),
                unit: "%".to_string(),
                paper: refs.0,
                band: band(refs.0),
                measured: Measurement::from_samples(&short),
            },
            Row {
                label: "expert accuracy, longest length quintile".to_string(),
                unit: "%".to_string(),
                paper: refs.1,
                band: band(refs.1),
                measured: Measurement::from_samples(&long),
            },
        ],
    })
}

/// Theorem 3.2's empirical no-regret property (the `no_regret` example,
/// summarized): final average regret γ/T vs the ≤ 0 bound.
fn noregret_section(opts: &ReproduceOpts) -> Result<Section> {
    let bench = BenchmarkId::Imdb;
    let mut avg_regret: Vec<f64> = Vec::new();
    let mut j_ratio: Vec<f64> = Vec::new();
    for &seed in &opts.seeds {
        let h = Harness::new(opts.scale, seed);
        let (b, e) = h.setup(bench, opts.expert);
        let mut cfg = CascadeConfig::small(bench, opts.expert);
        cfg.seed = seed;
        let mut c = Cascade::new(cfg, b.classes, e, None, usize::MAX / 2)?;
        c.set_threshold_scale(eval::BUDGETED_SCALE);
        c.enable_regret_tracking(200);
        let stream = b.stream();
        c.run_stream(&stream);
        let rt = c.regret.as_ref().ok_or_else(|| {
            Error::Config("regret tracking was enabled but produced no tracker".into())
        })?;
        avg_regret.push(rt.average_regret());
        let best = rt.j_best_fixed();
        j_ratio.push(if best > 0.0 { rt.j_learned() / best } else { 1.0 });
    }
    Ok(Section {
        id: "noregret".to_string(),
        title: "Theorem 3.2 — empirical no-regret (imdb, unbudgeted)".to_string(),
        rows: vec![
            Row {
                label: "final average regret γ/T (bound: ≤ 0 as T → ∞)".to_string(),
                unit: String::new(),
                paper: Some(0.0),
                band: Some(Band { kind: BandKind::UpperBound, tol: paper::REGRET_TOL }),
                measured: Measurement::from_samples(&avg_regret),
            },
            Row {
                label: "J(learned) / J(best fixed policy in hindsight)".to_string(),
                unit: "x".to_string(),
                paper: None,
                band: None,
                measured: Measurement::from_samples(&j_ratio),
            },
        ],
    })
}

/// App. B.1 prefill latency + intro server arithmetic (analytic — exact
/// by construction, kept in the record as an end-to-end sanity anchor).
fn costmodel_section() -> Section {
    Section {
        id: "costmodel".to_string(),
        title: "App. B.1 — prefill latency model".to_string(),
        rows: vec![
            Row {
                label: "first-token latency, 8192-token prompt".to_string(),
                unit: "s".to_string(),
                paper: Some(LatencyModel::PREFILL_SECS_8K),
                band: Some(Band { kind: BandKind::TwoSided, tol: paper::PREFILL_TOL_SECS }),
                measured: Measurement::from_samples(&[LatencyModel::prefill_secs(8192.0)]),
            },
            Row {
                label: "servers for 1M docs/hour".to_string(),
                unit: String::new(),
                paper: Some(paper::SERVERS_1M),
                band: Some(Band { kind: BandKind::TwoSided, tol: paper::SERVERS_TOL }),
                measured: Measurement::from_samples(&[LatencyModel::servers_needed(1e6)]),
            },
        ],
    }
}

fn expert_display(expert: ExpertId) -> &'static str {
    match expert {
        ExpertId::Gpt35 => "GPT-3.5",
        ExpertId::Llama70b => "Llama-2-70B",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_report() -> Report {
        Report {
            profile: "test".to_string(),
            scale: 0.02,
            seeds: vec![1, 2],
            expert: ExpertId::Gpt35,
            sections: vec![Section {
                id: "demo".to_string(),
                title: "Demo".to_string(),
                rows: vec![
                    Row {
                        label: "in-band".to_string(),
                        unit: "%".to_string(),
                        paper: Some(0.9),
                        band: Some(Band { kind: BandKind::TwoSided, tol: 0.05 }),
                        measured: Measurement { mean: 0.92, sd: 0.01, n: 2 },
                    },
                    Row {
                        label: "info".to_string(),
                        unit: String::new(),
                        paper: None,
                        band: None,
                        measured: Measurement { mean: 1.5, sd: 0.0, n: 2 },
                    },
                ],
            }],
        }
    }

    #[test]
    fn band_logic() {
        let two = Band { kind: BandKind::TwoSided, tol: 0.05 };
        assert!(two.contains(0.05) && two.contains(-0.05));
        assert!(!two.contains(0.051) && !two.contains(-0.051));
        let up = Band { kind: BandKind::UpperBound, tol: 0.02 };
        assert!(up.contains(-5.0) && up.contains(0.02));
        assert!(!up.contains(0.021));
        let low = Band { kind: BandKind::LowerBound, tol: 0.02 };
        assert!(low.contains(5.0) && low.contains(-0.02));
        assert!(!low.contains(-0.021));
    }

    #[test]
    fn measurement_aggregates() {
        let m = Measurement::from_samples(&[1.0, 3.0]);
        assert_eq!(m.mean, 2.0);
        assert_eq!(m.n, 2);
        assert!((m.sd - (2.0f64).sqrt()).abs() < 1e-12);
        let one = Measurement::from_samples(&[7.0]);
        assert_eq!((one.mean, one.sd, one.n), (7.0, 0.0, 1));
    }

    #[test]
    fn report_json_round_trips() {
        let rep = demo_report();
        let j = rep.to_json();
        let back = Report::from_json(&codec::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, rep);
        assert_eq!(back.to_json(), j);
    }

    #[test]
    fn schema_mismatch_rejected() {
        let mut j = rep_json_with_schema(99.0);
        assert!(Report::from_json(&j).is_err());
        j = rep_json_with_schema(SCHEMA_VERSION as f64);
        assert!(Report::from_json(&j).is_ok());
    }

    fn rep_json_with_schema(v: f64) -> Json {
        let mut j = demo_report().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".to_string(), Json::Num(v));
        }
        j
    }

    #[test]
    fn markdown_has_record_columns() {
        let md = demo_report().to_markdown();
        assert!(md.contains("| metric | paper | measured | Δ | band | status |"));
        assert!(md.contains("92.00%"));
        assert!(md.contains("pass"));
        assert!(md.contains("info"));
        assert!(md.contains("Verdict: 1 of 1 banded rows pass."));
    }

    #[test]
    fn profiles_resolve() {
        assert_eq!(ReproduceOpts::for_profile("quick").unwrap().profile, "quick");
        assert_eq!(ReproduceOpts::for_profile("full").unwrap().scale, 0.1);
        assert!(ReproduceOpts::for_profile("nope").is_err());
        assert_eq!(parse_seed_list("1, 2,3").unwrap(), vec![1, 2, 3]);
        assert!(parse_seed_list("1,x").is_err());
    }
}
