//! Synthetic benchmark text generator — the dataset substrate.
//!
//! The paper evaluates on IMDB / HateSpeech / ISEAR / FEVER, none of
//! which ship with this offline image. Per the substitution rule
//! (DESIGN.md §3) we build class-conditional document generators whose
//! **difficulty composition** reproduces what the cascade's dynamics
//! depend on: which capacity tier can learn which fraction of the
//! stream. Each document belongs to one of three separability strata:
//!
//! * [`Stratum::Easy`] — class signal carried by *unigram* keyword
//!   tokens: learnable by hashed bag-of-words logistic regression.
//! * [`Stratum::Medium`] — keyword tokens of a *shifted* class, each
//!   immediately preceded by a flip-marker token. Marginal unigram
//!   statistics are uninformative (markers appear equally in every
//!   class), but an order-aware model (the transformer) can learn
//!   `marker + keyword ⇒ shifted class`.
//! * [`Stratum::Hard`] — the label is a hidden relation between an
//!   entity token and a fact token drawn from a large key space
//!   (FEVER-style "parametric knowledge"): effectively only the expert
//!   (which, like the paper's LLM, "knows" the world) gets these right.
//!
//! Documents also carry a *category* (topic/genre) that shifts the
//! filler-token distribution only — the substrate for the §5.4
//! category-distribution-shift experiment — and a length drawn from a
//! per-benchmark log-normal fit to the paper's Table 5 buckets.

use crate::config::BenchmarkId;
use crate::prng::{Cdf, Rng};

/// Difficulty stratum of one generated document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stratum {
    /// Unigram-separable (LR-learnable).
    Easy,
    /// Order-separable (transformer-learnable).
    Medium,
    /// Relational/ambiguous (expert-only).
    Hard,
}

impl Stratum {
    /// Stable wire/report name.
    pub fn name(&self) -> &'static str {
        match self {
            Stratum::Easy => "easy",
            Stratum::Medium => "medium",
            Stratum::Hard => "hard",
        }
    }

    /// Inverse of [`Stratum::name`].
    pub fn from_name(s: &str) -> Option<Stratum> {
        match s {
            "easy" => Some(Stratum::Easy),
            "medium" => Some(Stratum::Medium),
            "hard" => Some(Stratum::Hard),
            _ => None,
        }
    }
}

/// One generated document with ground truth + generation metadata.
#[derive(Clone, Debug)]
pub struct Doc {
    /// Whitespace-joined token text (what the featurizer consumes).
    pub text: String,
    /// Ground-truth label in `0..classes`.
    pub label: usize,
    /// Difficulty stratum the generator drew.
    pub stratum: Stratum,
    /// Topic/genre category in `0..NUM_CATEGORIES`.
    pub category: usize,
    /// Token count (pre-truncation length).
    pub len: usize,
}

/// Number of filler-topic categories (IMDB "genres").
pub const NUM_CATEGORIES: usize = 10;

/// Tokens-per-class in the informative keyword pools.
const KEYWORDS_PER_CLASS: usize = 40;
/// Flip-marker pool size (shared across classes — marginally neutral).
const NUM_MARKERS: usize = 12;
/// Entity/fact pool sizes for the hard stratum key space.
const NUM_ENTITIES: usize = 600;
const NUM_FACTS: usize = 600;
/// Filler vocabulary size (Zipf-distributed common words).
const NUM_FILLER: usize = 3000;

/// Per-benchmark generator parameters.
#[derive(Clone, Debug)]
pub struct GenParams {
    /// Number of classes.
    pub classes: usize,
    /// Class prior weights (unnormalized).
    pub class_weights: Vec<f64>,
    /// P(easy) — the hard stratum gets `1 − p_easy − p_medium`.
    pub p_easy: f64,
    /// P(medium).
    pub p_medium: f64,
    /// Log-normal length location μ (of the underlying normal).
    pub len_mu: f64,
    /// Log-normal length scale σ (of the underlying normal).
    pub len_sigma: f64,
    /// Strength of the length↔difficulty correlation in [0,1]
    /// (Table 5: longer IMDB reviews are harder).
    pub len_difficulty_corr: f64,
    /// Keyword density: informative tokens per 12 filler tokens.
    pub keyword_density: f64,
}

impl GenParams {
    /// Preset for one of the paper's four benchmarks. The strata mix is
    /// calibrated so the distilled-model ceilings land near Table 1
    /// (see DESIGN.md §3, and §10 for measured values).
    pub fn preset(bench: BenchmarkId) -> Self {
        match bench {
            BenchmarkId::Imdb => GenParams {
                classes: 2,
                class_weights: vec![1.0, 1.0],
                p_easy: 0.78,
                p_medium: 0.12,
                len_mu: 6.75,  // exp(6.75) ≈ 854 chars ≈ Table 5 median
                len_sigma: 0.55,
                len_difficulty_corr: 0.7,
                keyword_density: 2.0,
            },
            BenchmarkId::HateSpeech => GenParams {
                classes: 2,
                // hate : noHate = 1 : 7.95 (paper §4)
                class_weights: vec![7.95, 1.0],
                p_easy: 0.82,
                p_medium: 0.08,
                len_mu: 5.2,
                len_sigma: 0.6,
                len_difficulty_corr: 0.2,
                keyword_density: 2.2,
            },
            BenchmarkId::Isear => GenParams {
                classes: 7,
                class_weights: vec![1.0; 7],
                p_easy: 0.42,
                p_medium: 0.25,
                len_mu: 4.8,
                len_sigma: 0.5,
                len_difficulty_corr: 0.3,
                keyword_density: 1.6,
            },
            BenchmarkId::Fever => GenParams {
                classes: 2,
                class_weights: vec![1.0, 1.0],
                p_easy: 0.15,
                p_medium: 0.32,
                len_mu: 4.5,
                len_sigma: 0.4,
                len_difficulty_corr: 0.2,
                keyword_density: 1.8,
            },
        }
    }
}

/// Class-conditional document generator.
pub struct Generator {
    params: GenParams,
    rng: Rng,
    filler_cdf: Cdf,
    /// Hidden entity×fact → label relation (the expert's "knowledge").
    relation_salt: u64,
}

impl Generator {
    /// Build a generator for a benchmark preset with a seed.
    pub fn new(bench: BenchmarkId, seed: u64) -> Self {
        Generator::with_params(GenParams::preset(bench), seed)
    }

    /// Build from explicit parameters (tests, ablations).
    pub fn with_params(params: GenParams, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x0C1_CA5CADE);
        // Zipf weights for filler tokens (s = 1.1, classic text-ish).
        let weights: Vec<f64> =
            (1..=NUM_FILLER).map(|k| 1.0 / (k as f64).powf(1.1)).collect();
        let filler_cdf = Cdf::new(&weights);
        let relation_salt = rng.next_u64();
        Generator { params, rng, filler_cdf, relation_salt }
    }

    /// Generator parameters (read-only).
    pub fn params(&self) -> &GenParams {
        &self.params
    }

    /// The hidden relation: which label an (entity, fact) pair encodes.
    /// Deterministic, known to the expert simulator, opaque to models.
    pub fn relation_label(&self, entity: usize, fact: usize, classes: usize) -> usize {
        let mut h = self.relation_salt ^ ((entity as u64) << 32 | fact as u64);
        h ^= h >> 33;
        h = h.wrapping_mul(0xFF51AFD7ED558CCD);
        h ^= h >> 33;
        (h % classes as u64) as usize
    }

    /// Generate the next document.
    pub fn sample(&mut self) -> Doc {
        let label = self.rng.categorical(&self.params.class_weights);
        let category = self.rng.below(NUM_CATEGORIES);
        // Length in tokens from the log-normal (clamped to [8, 320]).
        let raw_len = self.rng.lognormal(self.params.len_mu, self.params.len_sigma);
        let len = (raw_len / 5.0).clamp(8.0, 320.0) as usize; // ~5 chars/word
        // Longer documents skew harder (Table 5): blend the stratum
        // draw toward hard as the length percentile rises.
        let len_pct = ((raw_len.ln() - self.params.len_mu)
            / (self.params.len_sigma * 2.0))
            .clamp(-1.0, 1.0)
            * 0.5
            + 0.5;
        let corr = self.params.len_difficulty_corr;
        let shift = corr * (len_pct - 0.5); // [-corr/2, corr/2]
        let p_easy = (self.params.p_easy - shift).clamp(0.02, 0.98);
        let p_medium = self.params.p_medium;
        let u = self.rng.f64();
        let stratum = if u < p_easy {
            Stratum::Easy
        } else if u < p_easy + p_medium {
            Stratum::Medium
        } else {
            Stratum::Hard
        };
        let text = self.render(label, stratum, category, len);
        Doc { text, label, stratum, category, len }
    }

    /// Render the token stream for a document.
    fn render(
        &mut self,
        label: usize,
        stratum: Stratum,
        category: usize,
        len: usize,
    ) -> String {
        let k = self.params.classes;
        let density = self.params.keyword_density;
        let mut out = String::with_capacity(len * 7);
        let mut emitted = 0usize;
        // Hard stratum: plant the (entity, fact) pair early so the
        // transformer's 64-token window sees it (like a FEVER claim).
        if stratum == Stratum::Hard {
            // Find a pair consistent with the drawn label by rejection.
            let (mut e, mut f);
            loop {
                e = self.rng.below(NUM_ENTITIES);
                f = self.rng.below(NUM_FACTS);
                if self.relation_label(e, f, k) == label {
                    break;
                }
            }
            out.push_str(&format!("ent{e:04} "));
            out.push_str(&format!("fact{f:04} "));
            emitted += 2;
        }
        while emitted < len {
            // Filler burst.
            let burst = 6 + self.rng.below(8);
            for _ in 0..burst.min(len - emitted) {
                let w = self.filler_cdf.sample(&mut self.rng);
                out.push_str(&format!("c{category}w{w:04} "));
                emitted += 1;
            }
            if emitted >= len {
                break;
            }
            // Informative tokens according to the stratum.
            let n_kw = (density.floor() as usize)
                + usize::from(self.rng.coin(density.fract()));
            for _ in 0..n_kw {
                if emitted + 2 > len {
                    break;
                }
                match stratum {
                    Stratum::Easy => {
                        let kw = self.rng.below(KEYWORDS_PER_CLASS);
                        out.push_str(&format!("kw{label}x{kw:03} "));
                        emitted += 1;
                    }
                    Stratum::Medium => {
                        // Emit marker + keyword of the *shifted* class;
                        // true label = apparent + 1 (mod k), so apparent
                        // = label - 1 (mod k).
                        let apparent = (label + k - 1) % k;
                        let m = self.rng.below(NUM_MARKERS);
                        let kw = self.rng.below(KEYWORDS_PER_CLASS);
                        out.push_str(&format!("neg{m:02} kw{apparent}x{kw:03} "));
                        emitted += 2;
                    }
                    Stratum::Hard => {
                        // Ambiguous: random-class keyword at low rate —
                        // mild noise that keeps unigrams uninformative.
                        if self.rng.coin(0.3) {
                            let wrong = self.rng.below(k);
                            let kw = self.rng.below(KEYWORDS_PER_CLASS);
                            out.push_str(&format!("kw{wrong}x{kw:03} "));
                            emitted += 1;
                        }
                    }
                }
            }
        }
        out.pop(); // trailing space
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Generator::new(BenchmarkId::Imdb, 7);
        let mut b = Generator::new(BenchmarkId::Imdb, 7);
        for _ in 0..20 {
            let (x, y) = (a.sample(), b.sample());
            assert_eq!(x.text, y.text);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn class_balance_imdb_vs_hatespeech() {
        let mut g = Generator::new(BenchmarkId::Imdb, 1);
        let n = 4000;
        let pos = (0..n).filter(|_| g.sample().label == 1).count();
        assert!((pos as f64 / n as f64 - 0.5).abs() < 0.05);

        let mut g = Generator::new(BenchmarkId::HateSpeech, 1);
        let hate = (0..n).filter(|_| g.sample().label == 1).count();
        let ratio = hate as f64 / n as f64;
        // 1 / (1 + 7.95) ≈ 0.1117
        assert!((ratio - 0.1117).abs() < 0.03, "hate ratio {ratio}");
    }

    #[test]
    fn isear_has_seven_classes() {
        let mut g = Generator::new(BenchmarkId::Isear, 2);
        let mut seen = HashMap::new();
        for _ in 0..2000 {
            *seen.entry(g.sample().label).or_insert(0usize) += 1;
        }
        assert_eq!(seen.len(), 7);
    }

    #[test]
    fn strata_mix_near_preset() {
        let mut g = Generator::new(BenchmarkId::Fever, 3);
        let n = 5000;
        let mut easy = 0;
        for _ in 0..n {
            if g.sample().stratum == Stratum::Easy {
                easy += 1;
            }
        }
        let p = easy as f64 / n as f64;
        assert!((p - 0.15).abs() < 0.05, "easy frac {p}");
    }

    #[test]
    fn easy_docs_contain_own_class_keywords() {
        let mut g = Generator::new(BenchmarkId::Imdb, 4);
        for _ in 0..200 {
            let d = g.sample();
            if d.stratum == Stratum::Easy {
                let tag = format!("kw{}x", d.label);
                assert!(d.text.contains(&tag), "easy doc lacks {tag}: {}", d.text);
            }
        }
    }

    #[test]
    fn medium_docs_contain_markers_and_shifted_keywords() {
        let mut g = Generator::new(BenchmarkId::Imdb, 5);
        let mut found = false;
        for _ in 0..500 {
            let d = g.sample();
            if d.stratum == Stratum::Medium {
                found = true;
                assert!(d.text.contains("neg"), "medium doc lacks marker");
                let apparent = (d.label + 1) % 2;
                let own = format!("kw{}x", d.label);
                let shifted = format!("kw{apparent}x");
                assert!(d.text.contains(&shifted));
                assert!(!d.text.contains(&own));
            }
        }
        assert!(found, "no medium docs in 500 draws");
    }

    #[test]
    fn hard_docs_have_entity_fact_pair_matching_relation() {
        let mut g = Generator::new(BenchmarkId::Fever, 6);
        let mut seen = 0;
        for _ in 0..400 {
            let d = g.sample();
            if d.stratum == Stratum::Hard {
                seen += 1;
                let toks: Vec<&str> = d.text.split_whitespace().collect();
                let e: usize = toks[0].strip_prefix("ent").unwrap().parse().unwrap();
                let f: usize = toks[1].strip_prefix("fact").unwrap().parse().unwrap();
                assert_eq!(g.relation_label(e, f, 2), d.label);
            }
        }
        assert!(seen > 50, "hard stratum too rare: {seen}");
    }

    #[test]
    fn length_correlates_with_difficulty_on_imdb() {
        let mut g = Generator::new(BenchmarkId::Imdb, 8);
        let (mut hard_len, mut easy_len) = (Vec::new(), Vec::new());
        for _ in 0..6000 {
            let d = g.sample();
            match d.stratum {
                Stratum::Hard => hard_len.push(d.len as f64),
                Stratum::Easy => easy_len.push(d.len as f64),
                _ => {}
            }
        }
        let m = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            m(&hard_len) > m(&easy_len),
            "hard {} <= easy {}",
            m(&hard_len),
            m(&easy_len)
        );
    }

    #[test]
    fn category_tokens_present() {
        let mut g = Generator::new(BenchmarkId::Imdb, 9);
        let d = g.sample();
        assert!(d.text.contains(&format!("c{}w", d.category)));
    }
}
