//! # ocl — Online Cascade Learning for Efficient Inference over Streams
//!
//! Production-grade reproduction of Nie et al., ICML 2024, as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the streaming cascade coordinator: Algorithm 1
//!   (online cascade learning via imitation of an LLM expert), the
//!   deferral-calibration policy, online-gradient-descent learner,
//!   DAgger β-schedule, cost/budget accounting, a request router +
//!   dynamic batcher for the serving mode, baselines, and the full
//!   experiment harness regenerating every table and figure of the paper.
//! * **L2 (python/compile, build-time)** — jax model graphs (logistic
//!   regression, BERT-surrogate transformers, deferral MLPs), AOT-lowered
//!   to HLO text.
//! * **L1 (python/compile/kernels, build-time)** — Pallas kernels (fused
//!   classifier head, flash attention, fused LR update) inside the L2 HLO.
//!
//! Python never runs on the request path: with the opt-in `pjrt` cargo
//! feature, [`runtime`] loads the HLO artifacts through the PJRT C API
//! (`xla` crate) and executes them from rust worker threads. The
//! default (feature-less) build is pure rust with zero external
//! dependencies: the [`hostmodel`] mirrors back every cascade level, so
//! the crate builds and tests fully offline.
//!
//! See `DESIGN.md` for the system inventory, the per-experiment index,
//! and measured results (§10).

pub mod baselines;
pub mod bench_support;
pub mod cascade;
pub mod cli;
pub mod codec;
pub mod config;
pub mod data;
pub mod error;
pub mod eval;
pub mod features;
pub mod hostmodel;
pub mod models;
pub mod policy;
pub mod prng;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod text;
pub mod util;

pub use error::{Error, Result};
