//! Experiment harness: regenerates every table and figure of the paper
//! (see DESIGN.md §5 for the index). Each public function renders a
//! paper-style text table/series; `eval::emit` writes it under
//! `reports/`. The regenerators execute the shared experiment registry
//! (`report::registry`) — the same seed-pinned specs behind
//! `ocl reproduce` and the bench harnesses.
//!
//! Streams are scaled by `scale` (default 0.2 in the CLI) relative to
//! the paper's dataset sizes; budgets 𝒩 scale proportionally, so the
//! *budget fraction* axis matches the paper exactly. DESIGN.md §10
//! records paper-vs-measured for the featured operating points.

use std::fmt::Write as _;
use std::rc::Rc;

use crate::baselines::{Distillation, OnlineEnsemble};
use crate::cascade::Cascade;
use crate::config::{BenchmarkId, CascadeConfig, Engine, ExpertId, ModelKind};
use crate::data::{Benchmark, Sample, StreamOrder};
use crate::error::Result;
use crate::report::registry::{self, Method};
use crate::runtime::PjrtEngine;
use crate::sim::cost::{CostModel, LatencyModel};
use crate::sim::{Expert, ExpertProfile};

/// Fixed operating threshold scale for budgeted runs (see
/// `Cascade::set_threshold_scale`): defer-happy so the expert budget is
/// spent on annotations while it lasts, then the learned levels serve.
pub const BUDGETED_SCALE: f64 = 0.7;

/// The paper's Table 1 budgets per benchmark (full-size streams).
pub fn table1_budgets(bench: BenchmarkId) -> [usize; 3] {
    match bench {
        BenchmarkId::Imdb => [1300, 3800, 5200],
        BenchmarkId::HateSpeech => [600, 2700, 4900],
        BenchmarkId::Isear => [1200, 1500, 2700],
        BenchmarkId::Fever => [700, 2000, 2800],
    }
}

/// Featured case-analysis budgets (Figs 5–8).
pub fn case_budget(bench: BenchmarkId) -> usize {
    match bench {
        BenchmarkId::Imdb => 3671,
        BenchmarkId::HateSpeech => 507,
        BenchmarkId::Isear => 2517,
        BenchmarkId::Fever => 2635,
    }
}

/// One run's headline numbers.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Accuracy vs ground truth.
    pub accuracy: f64,
    /// Recall of class 1 (reported for HateSpeech).
    pub recall: f64,
    /// Precision of class 1.
    pub precision: f64,
    /// F1 of class 1.
    pub f1: f64,
    /// Expert calls actually used.
    pub llm_calls: u64,
    /// Total FLOPs.
    pub flops: f64,
    /// Expert-alone accuracy on the same stream.
    pub expert_accuracy: f64,
}

/// Common experiment context.
pub struct Harness {
    /// Stream scale relative to the paper's dataset sizes.
    pub scale: f64,
    /// Base RNG seed.
    pub seed: u64,
    /// Engine for cascade models.
    pub engine: Engine,
    /// PJRT engine when `engine == Pjrt`.
    pub pjrt: Option<Rc<PjrtEngine>>,
}

impl Harness {
    /// Host-engine harness at a stream scale.
    pub fn new(scale: f64, seed: u64) -> Self {
        Harness { scale, seed, engine: Engine::Host, pjrt: None }
    }

    /// Scaled stream length for a benchmark.
    pub fn stream_len(&self, bench: BenchmarkId) -> usize {
        ((bench.stream_len() as f64) * self.scale).round().max(300.0) as usize
    }

    /// Scale a paper budget to this harness's stream size.
    pub fn scaled_budget(&self, bench: BenchmarkId, full_budget: usize) -> u64 {
        let frac = full_budget as f64 / bench.stream_len() as f64;
        ((self.stream_len(bench) as f64) * frac).round().max(16.0) as u64
    }

    /// Build (benchmark, expert) with calibrated strata/length stats.
    pub fn setup(&self, bench: BenchmarkId, expert: ExpertId) -> (Benchmark, Expert) {
        let n = self.stream_len(bench);
        let b = Benchmark::build_sized(bench, self.seed, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let e = Expert::new(
            ExpertProfile::for_pair(expert, bench),
            b.strata_fractions(),
            mean_len,
            self.seed ^ 0xE0,
        );
        (b, e)
    }

    fn config(&self, bench: BenchmarkId, expert: ExpertId, large: bool) -> CascadeConfig {
        let mut cfg = if large {
            CascadeConfig::large(bench, expert)
        } else {
            CascadeConfig::small(bench, expert)
        };
        cfg.engine = self.engine;
        cfg.seed = self.seed;
        cfg
    }

    /// Run online cascade learning at a budget; returns the result and
    /// the snapshot series (for case-analysis figures).
    pub fn run_ocl(
        &self,
        bench: BenchmarkId,
        expert: ExpertId,
        budget: Option<u64>,
        large: bool,
        order: StreamOrder,
    ) -> Result<(RunResult, Vec<crate::cascade::metrics::Snapshot>)> {
        let (b, e) = self.setup(bench, expert);
        let cfg = self.config(bench, expert, large);
        let snap = (b.samples.len() / 40).max(25);
        let mut c = Cascade::new(cfg, b.classes, e, self.pjrt.as_ref(), snap)?;
        c.set_threshold_scale(BUDGETED_SCALE);
        match budget {
            Some(n) => c.set_budget_paced(n, b.samples.len()),
            None => c.set_budget(None),
        }
        let stream = b.stream_ordered(order, self.seed);
        c.run_stream(&stream);
        let m = &c.metrics;
        Ok((
            RunResult {
                accuracy: m.accuracy(),
                recall: m.recall(1),
                precision: m.precision(1),
                f1: m.f1(1),
                llm_calls: m.llm_calls(),
                flops: m.flops(),
                expert_accuracy: m.expert_accuracy(),
            },
            m.series.clone(),
        ))
    }

    /// Table-1 protocol variant of [`Harness::run_ocl`]: learning and
    /// the budget span the whole stream, accuracy is measured on the
    /// second half only (identical to the distillation test set).
    pub fn run_ocl_split(
        &self,
        bench: BenchmarkId,
        expert: ExpertId,
        budget: Option<u64>,
        large: bool,
        order: StreamOrder,
    ) -> Result<RunResult> {
        let (b, e) = self.setup(bench, expert);
        let cfg = self.config(bench, expert, large);
        let mut c = Cascade::new(cfg, b.classes, e, self.pjrt.as_ref(), usize::MAX / 2)?;
        c.set_threshold_scale(BUDGETED_SCALE);
        match budget {
            Some(n) => c.set_budget_paced(n, b.samples.len()),
            None => c.set_budget(None),
        }
        let stream = b.stream_ordered(order, self.seed);
        let (train, test) = stream.split_at(stream.len() / 2);
        for s in train {
            c.process(s);
        }
        let spent_first_half = c.llm_calls();
        c.reset_metrics();
        for s in test {
            c.process(s);
        }
        c.metrics.finalize();
        let m = &c.metrics;
        Ok(RunResult {
            accuracy: m.accuracy(),
            recall: m.recall(1),
            precision: m.precision(1),
            f1: m.f1(1),
            llm_calls: m.llm_calls() + spent_first_half,
            flops: m.flops(),
            expert_accuracy: m.expert_accuracy(),
        })
    }

    /// Test-half protocol variant of [`Harness::run_oel`].
    pub fn run_oel_split(
        &self,
        bench: BenchmarkId,
        expert: ExpertId,
        budget: u64,
        order: StreamOrder,
    ) -> Result<RunResult> {
        let (b, e) = self.setup(bench, expert);
        let cfg = self.config(bench, expert, false);
        let rate = budget as f64 / b.samples.len() as f64;
        let mut oel = OnlineEnsemble::new(&cfg, b.classes, e, rate, self.pjrt.as_ref())?;
        let stream = b.stream_ordered(order, self.seed);
        let (train, test) = stream.split_at(stream.len() / 2);
        for s in train {
            oel.process(s);
        }
        let spent = oel.metrics.llm_calls();
        oel.reset_metrics();
        for s in test {
            oel.process(s);
        }
        oel.metrics.finalize();
        let m = &oel.metrics;
        Ok(RunResult {
            accuracy: m.accuracy(),
            recall: m.recall(1),
            precision: m.precision(1),
            f1: m.f1(1),
            llm_calls: m.llm_calls() + spent,
            flops: m.flops(),
            expert_accuracy: m.expert_accuracy(),
        })
    }

    /// Run the online-ensemble baseline at a budget.
    pub fn run_oel(
        &self,
        bench: BenchmarkId,
        expert: ExpertId,
        budget: u64,
        order: StreamOrder,
    ) -> Result<RunResult> {
        let (b, e) = self.setup(bench, expert);
        let cfg = self.config(bench, expert, false);
        let rate = budget as f64 / b.samples.len() as f64;
        let mut oel = OnlineEnsemble::new(&cfg, b.classes, e, rate, self.pjrt.as_ref())?;
        let stream = b.stream_ordered(order, self.seed);
        oel.run_stream(&stream);
        let m = &oel.metrics;
        Ok(RunResult {
            accuracy: m.accuracy(),
            recall: m.recall(1),
            precision: m.precision(1),
            f1: m.f1(1),
            llm_calls: m.llm_calls(),
            flops: m.flops(),
            expert_accuracy: m.expert_accuracy(),
        })
    }

    /// Run a distillation baseline (50/50 split, budget on train half).
    pub fn run_distill(
        &self,
        bench: BenchmarkId,
        expert: ExpertId,
        kind: ModelKind,
        budget: u64,
    ) -> Result<RunResult> {
        let (b, e) = self.setup(bench, expert);
        let stream = b.stream();
        let (train, test) = stream.split_at(stream.len() / 2);
        let mut d = Distillation::new(kind, b.classes, self.seed, self.pjrt.as_ref())?;
        d.run(&e, train, test, budget as usize);
        let m = &d.metrics;
        Ok(RunResult {
            accuracy: m.accuracy(),
            recall: m.recall(1),
            precision: m.precision(1),
            f1: m.f1(1),
            llm_calls: budget,
            flops: m.flops(),
            expert_accuracy: m.expert_accuracy(),
        })
    }
}

// ---------------------------------------------------------------------------
// Table / figure regenerators
// ---------------------------------------------------------------------------

fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

/// Table 1: methods × budgets × benchmarks (× experts). Every cell is
/// a `registry::table1_spec` execution, so the bench harness and
/// `ocl reproduce` time/measure exactly this workload.
pub fn table1(h: &Harness, experts: &[ExpertId]) -> Result<String> {
    let mut out = String::new();
    for &expert in experts {
        let _ = writeln!(
            out,
            "\n=== Table 1 ({} as the LLM expert, stream scale {}) ===",
            expert.name(),
            h.scale
        );
        for bench in BenchmarkId::ALL {
            let budgets = table1_budgets(bench);
            let _ = writeln!(
                out,
                "\n[{}] classes={} stream={} budgets(full)={:?} scaled={:?}",
                bench.name(),
                bench.classes(),
                h.stream_len(bench),
                budgets,
                budgets.map(|n| h.scaled_budget(bench, n)),
            );
            let hs = bench == BenchmarkId::HateSpeech;
            let hdr = if hs { "acc|recall" } else { "accuracy" };
            let _ = writeln!(out, "{:<26} {:>14} {:>14} {:>14}", "method", hdr, hdr, hdr);
            // Expert reference row (budget 0 run measures it cheaply).
            let (expert_row, _) =
                h.run_ocl(bench, expert, Some(0), false, StreamOrder::Natural)?;
            let _ = writeln!(
                out,
                "{:<26} {:>44}",
                format!("{} (zero-shot)", expert.name()),
                pct(expert_row.expert_accuracy)
            );
            let mut rows: Vec<(String, Vec<String>)> = Method::TABLE1
                .iter()
                .map(|m| (m.display().to_string(), vec![]))
                .collect();
            for bi in 0..budgets.len() {
                for (mi, &method) in Method::TABLE1.iter().enumerate() {
                    let r = registry::table1_spec(bench, expert, method, bi).execute(h)?;
                    let cell = if hs {
                        format!("{}|{}", pct(r.accuracy), pct(r.recall))
                    } else {
                        pct(r.accuracy)
                    };
                    rows[mi].1.push(cell);
                }
            }
            for (name, cells) in rows {
                let _ = writeln!(
                    out,
                    "{:<26} {:>14} {:>14} {:>14}",
                    name, cells[0], cells[1], cells[2]
                );
            }
        }
    }
    Ok(out)
}

/// Figures 3/4/10/11: accuracy(+PRF)-vs-cost curves — the
/// `registry::curve_specs` budget sweep.
pub fn curves(
    h: &Harness,
    bench: BenchmarkId,
    expert: ExpertId,
    large: bool,
) -> Result<String> {
    let t = h.stream_len(bench);
    let mut out = format!(
        "# fig-curve bench={} expert={} large={} stream={}\n",
        bench.name(),
        expert.name(),
        large,
        t
    );
    let _ = writeln!(
        out,
        "{:<10} {:>8} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "budget", "calls", "ocl_acc", "ocl_rec", "ocl_f1", "ocl_prec", "oel_acc", "oel_rec"
    );
    let ocl = if large { Method::OclLarge } else { Method::Ocl };
    for &fr in &registry::CURVE_FRACS {
        let oc = registry::curve_spec(bench, expert, ocl, fr).execute(h)?;
        let oe = registry::curve_spec(bench, expert, Method::OnlineEnsemble, fr).execute(h)?;
        let _ = writeln!(
            out,
            "{:<10} {:>8} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
            format!("{:.0}%", fr * 100.0),
            oc.llm_calls,
            pct(oc.accuracy),
            pct(oc.recall),
            pct(oc.f1),
            pct(oc.precision),
            pct(oe.accuracy),
            pct(oe.recall),
        );
    }
    Ok(out)
}

/// Figures 5–8: case-analysis time series at the featured budget.
pub fn case_analysis(h: &Harness, bench: BenchmarkId, expert: ExpertId) -> Result<String> {
    let budget = h.scaled_budget(bench, case_budget(bench));
    let (res, series) =
        h.run_ocl(bench, expert, Some(budget), false, StreamOrder::Natural)?;
    let mut out = format!(
        "# fig-case bench={} expert={} budget={} (paper N={})\n",
        bench.name(),
        expert.name(),
        budget,
        case_budget(bench)
    );
    let _ = writeln!(
        out,
        "{:>7} {:>8} {:>11} {:>8} {:>8} {:>8} {:>9}",
        "t", "acc", "expert_acc", "f_lr", "f_bert", "f_llm", "llm_calls"
    );
    for s in &series {
        let _ = writeln!(
            out,
            "{:>7} {:>8} {:>11} {:>8.3} {:>8.3} {:>8.3} {:>9}",
            s.t,
            pct(s.accuracy),
            pct(s.expert_accuracy),
            s.handled_frac[0],
            s.handled_frac[1],
            s.handled_frac[2],
            s.llm_calls
        );
    }
    let _ = writeln!(
        out,
        "final: acc={} expert={} llm_calls={} savings={:.0}%",
        pct(res.accuracy),
        pct(res.expert_accuracy),
        res.llm_calls,
        (1.0 - res.llm_calls as f64 / h.stream_len(bench) as f64) * 100.0
    );
    Ok(out)
}

/// Figure 9 + Table 2: distribution-shift robustness on IMDB — the
/// `registry::shift_specs` grid (scenarios × budget fractions).
pub fn shift(h: &Harness, expert: ExpertId) -> Result<String> {
    let mut out = format!("# fig9/table2 shift robustness expert={}\n", expert.name());
    let mut avgs = Vec::new();
    for (name, order) in registry::shift_scenarios() {
        let _ = writeln!(out, "\n[{name}]");
        let _ = writeln!(out, "{:<8} {:>8} {:>9} {:>9}", "budget", "calls", "ocl_acc", "oel_acc");
        let mut accs = Vec::new();
        for &fr in &registry::SHIFT_FRACS {
            let oc = registry::shift_spec(expert, name, order, Method::Ocl, fr).execute(h)?;
            let oe =
                registry::shift_spec(expert, name, order, Method::OnlineEnsemble, fr).execute(h)?;
            accs.push(oc.accuracy);
            let _ = writeln!(
                out,
                "{:<8} {:>8} {:>9} {:>9}",
                format!("{:.0}%", fr * 100.0),
                oc.llm_calls,
                pct(oc.accuracy),
                pct(oe.accuracy)
            );
        }
        avgs.push((name, accs.iter().sum::<f64>() / accs.len() as f64));
    }
    let base = avgs[0].1;
    let _ = writeln!(out, "\n# Table 2: average OCL accuracy across budgets");
    for (name, a) in &avgs {
        let _ = writeln!(out, "{:<20} {:>8}  diff {:+.2} pts", name, pct(*a), (a - base) * 100.0);
    }
    Ok(out)
}

/// Table 5's length buckets: samples sorted by token length plus the
/// quintile width `q` (five `q`-wide buckets; the `len % 5` remainder
/// folds into the last, i.e. bucket `i` is `sorted[i*q ..]` capped at
/// `(i+1)*q` except the final one). Shared by [`table5`] and the §10
/// record's Table 5 section so bucket boundaries can never drift apart.
pub fn length_quintiles(b: &Benchmark) -> (Vec<&Sample>, usize) {
    let mut sorted: Vec<&Sample> = b.samples.iter().collect();
    sorted.sort_by_key(|s| s.len);
    let q = sorted.len() / 5;
    (sorted, q)
}

/// Table 5: expert accuracy by document-length bucket (IMDB).
pub fn table5(h: &Harness, expert: ExpertId) -> Result<String> {
    let (b, e) = h.setup(BenchmarkId::Imdb, expert);
    let (sorted, q) = length_quintiles(&b);
    let mut out = format!(
        "# Table 5: {} accuracy by IMDB length bucket (tokens)\n",
        expert.name()
    );
    let _ = writeln!(out, "{:<16} {:>7} {:>10} {:>10}", "bucket", "count", "avg_len", "accuracy");
    let mut total_correct = 0usize;
    for i in 0..5 {
        let lo = i * q;
        let hi = if i == 4 { sorted.len() } else { (i + 1) * q };
        let xs = &sorted[lo..hi];
        let correct = xs.iter().filter(|s| e.peek(s, b.classes) == s.label).count();
        total_correct += correct;
        let avg = xs.iter().map(|s| s.len as f64).sum::<f64>() / xs.len() as f64;
        let _ = writeln!(
            out,
            "{:<16} {:>7} {:>10.1} {:>10}",
            format!(
                "{}-{}",
                xs.first().map(|s| s.len).unwrap_or(0),
                xs.last().map(|s| s.len).unwrap_or(0)
            ),
            xs.len(),
            avg,
            pct(correct as f64 / xs.len() as f64)
        );
    }
    let _ = writeln!(
        out,
        "{:<16} {:>7} {:>10} {:>10}",
        "total",
        sorted.len(),
        "",
        pct(total_correct as f64 / sorted.len() as f64)
    );
    Ok(out)
}

/// Appendix B.1 + C.1: prefill latency model and cost equilibrium.
pub fn costmodel() -> String {
    let mut out = String::from("# Appendix B.1 — prefill experiment (replayed model)\n");
    let _ = writeln!(
        out,
        "8192-token prompt first-token latency: {:.2} s (paper: 3.6 s)",
        LatencyModel::prefill_secs(8192.0)
    );
    let _ = writeln!(
        out,
        "docs/hour/server: {:.0}; servers for 1M docs/h: {:.0} (paper: 1000)",
        LatencyModel::docs_per_hour_per_server(),
        LatencyModel::servers_needed(1e6)
    );
    let _ = writeln!(out, "\n# Appendix C.1 — FLOP accounting");
    for (name, inf, tr) in [
        ("LR", CostModel::LR_INFER, CostModel::LR_TRAIN),
        ("BERT-base", CostModel::BERT_BASE_INFER, CostModel::BERT_BASE_TRAIN),
        ("BERT-large", CostModel::BERT_LARGE_INFER, CostModel::BERT_LARGE_TRAIN),
    ] {
        let _ = writeln!(out, "{name:<12} infer {inf:>12.3e}  train {tr:>12.3e} FLOPs");
    }
    let _ = writeln!(
        out,
        "Llama-2-70B infer: {:.3e} FLOPs ({:.1e}x the full cascade train cost)",
        CostModel::LLM_INFER,
        CostModel::LLM_INFER / CostModel::large_cascade_train_flops()
    );
    let _ = writeln!(out, "\n# cost equilibrium M = xC/(3-2x)");
    for x in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let _ = writeln!(
            out,
            "x={x:.1}: M = {:.3e} FLOPs",
            CostModel::equilibrium_small_model_budget(x, CostModel::LLM_INFER)
        );
    }
    out
}

/// Write a report to `<dir>/<name>` and echo to stdout.
pub fn emit(dir: &str, name: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all(dir)
        .map_err(|e| crate::error::Error::io(dir.to_string(), e))?;
    let path = std::path::Path::new(dir).join(name);
    std::fs::write(&path, content)
        .map_err(|e| crate::error::Error::io(path.display().to_string(), e))?;
    println!("{content}");
    eprintln!("[wrote {}]", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_scaling() {
        let h = Harness::new(0.02, 5);
        assert_eq!(h.stream_len(BenchmarkId::Imdb), 500);
        assert_eq!(h.scaled_budget(BenchmarkId::Imdb, 1300), 26);
    }

    #[test]
    fn costmodel_renders() {
        let s = costmodel();
        assert!(s.contains("3.6"));
        assert!(s.contains("equilibrium"));
    }

    #[test]
    fn table5_shows_declining_accuracy() {
        let h = Harness::new(0.3, 7);
        let s = table5(&h, ExpertId::Gpt35).unwrap();
        assert!(s.contains("bucket"));
        let accs: Vec<f64> = s
            .lines()
            .skip(2)
            .take(5)
            .filter_map(|l| l.split_whitespace().last()?.parse().ok())
            .collect();
        assert_eq!(accs.len(), 5);
        assert!(accs[0] > accs[4], "{accs:?}");
    }

    #[test]
    fn tiny_case_analysis_runs() {
        let h = Harness::new(0.02, 9);
        let s = case_analysis(&h, BenchmarkId::HateSpeech, ExpertId::Gpt35).unwrap();
        assert!(s.contains("final:"));
    }
}
