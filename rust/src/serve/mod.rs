//! Streaming serving mode: request router + dynamic batcher + per-model
//! worker threads (the vLLM-style leader/worker topology).
//!
//! Why threads-per-model: `PjRtClient` is `Rc`-based and cannot cross
//! threads, so each worker *builds its own engine* on its own thread;
//! the router owns only channels. The router executes the cascade
//! policy (deferral walk + online learning cadence) while workers
//! execute model inference/updates — queries are batched per level (up
//! to `batch_max` or `deadline`), which is what amortizes PJRT dispatch
//! overhead on the hot path (§Perf L3).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{CascadeConfig, Engine, ModelKind};
use crate::data::Sample;
use crate::error::{Error, Result};
use crate::models::{
    build_calibrator, build_level, Featurized, Pipeline,
};
use crate::prng::Rng;
use crate::sim::Expert;
use crate::util::{argmax, Percentiles, Ring};

/// A client request: one document to classify.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-assigned id (returned in the response).
    pub id: u64,
    /// Document text.
    pub text: String,
    /// Ground truth — metrics only (the router never reads it).
    pub truth: usize,
    /// Stable sample id for the expert simulator.
    pub sample: Sample,
}

/// The served answer.
#[derive(Clone, Debug)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Predicted label.
    pub pred: usize,
    /// Which level answered (levels.len() = expert).
    pub handled_by: usize,
    /// End-to-end latency.
    pub latency: Duration,
    /// Ground truth (echoed for client-side accuracy accounting).
    pub truth: usize,
}

/// Serving report: latency distribution + throughput + routing mix.
#[derive(Debug)]
pub struct ServeReport {
    /// Requests served.
    pub served: usize,
    /// End-to-end latency percentiles (milliseconds).
    pub latency_ms: Percentiles,
    /// Wall-clock duration of the run (seconds).
    pub wall_secs: f64,
    /// Requests per second.
    pub throughput: f64,
    /// Per-level handled counts (last = expert).
    pub handled: Vec<usize>,
    /// Accuracy vs ground truth.
    pub accuracy: f64,
    /// Expert calls.
    pub llm_calls: u64,
}

// --- worker protocol -------------------------------------------------------

struct Job {
    req_id: u64,
    f: Arc<Featurized>,
}

enum WorkerMsg {
    Infer(Vec<Job>),
    Train(Vec<(Arc<Featurized>, usize)>, f32),
    TrainCalib(Vec<(Vec<f32>, f32)>, f32),
    Shutdown,
}

struct WorkerReply {
    level: usize,
    results: Vec<(u64, Vec<f32>, f32)>, // (req_id, probs, score)
}

/// Handle to one level worker thread.
struct Worker {
    tx: Sender<WorkerMsg>,
    handle: JoinHandle<()>,
}

fn spawn_worker(
    level: usize,
    kind: ModelKind,
    classes: usize,
    seed: u64,
    engine: Engine,
    artifacts_dir: String,
    reply_tx: Sender<WorkerReply>,
) -> Worker {
    let (tx, rx): (Sender<WorkerMsg>, Receiver<WorkerMsg>) = channel();
    let handle = std::thread::spawn(move || {
        // The engine is constructed on this thread (PjRtClient is !Send).
        let pjrt = if engine.is_pjrt() {
            Some(crate::runtime::worker_engine(&artifacts_dir))
        } else {
            None
        };
        let mut model =
            build_level(pjrt.as_ref(), kind, classes, seed).expect("worker model");
        let mut calib =
            build_calibrator(pjrt.as_ref(), classes, seed).expect("worker calibrator");
        while let Ok(msg) = rx.recv() {
            match msg {
                WorkerMsg::Infer(jobs) => {
                    let fs: Vec<&Featurized> =
                        jobs.iter().map(|j| j.f.as_ref()).collect();
                    let probs = model.predict_batch(&fs);
                    let results = jobs
                        .iter()
                        .zip(probs)
                        .map(|(j, p)| {
                            let s = calib.score(&p);
                            (j.req_id, p, s)
                        })
                        .collect();
                    if reply_tx.send(WorkerReply { level, results }).is_err() {
                        break;
                    }
                }
                WorkerMsg::Train(batch, lr) => {
                    for chunk in batch.chunks(8) {
                        if chunk.len() < 8 {
                            break;
                        }
                        let b: Vec<(&Featurized, usize)> =
                            chunk.iter().map(|(f, y)| (f.as_ref(), *y)).collect();
                        model.train(&b, lr);
                    }
                }
                WorkerMsg::TrainCalib(batch, lr) => {
                    if batch.len() >= 8 {
                        let b: Vec<(&[f32], f32)> = batch[..8]
                            .iter()
                            .map(|(p, z)| (p.as_slice(), *z))
                            .collect();
                        calib.train(&b, lr);
                    }
                }
                WorkerMsg::Shutdown => break,
            }
        }
    });
    Worker { tx, handle }
}

// --- router ----------------------------------------------------------------

/// Dynamic batching parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max jobs per inference batch.
    pub batch_max: usize,
    /// Max time the oldest job may wait before the batch is flushed.
    pub deadline: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { batch_max: 8, deadline: Duration::from_millis(2) }
    }
}

struct Pending {
    f: Arc<Featurized>,
    truth: usize,
    sample: Sample,
    t0: Instant,
    seen: Vec<Option<Vec<f32>>>,
}

struct LevelQueue {
    jobs: VecDeque<Job>,
    oldest: Option<Instant>,
    in_flight: bool,
}

/// The streaming cascade server.
pub struct Server {
    workers: Vec<Worker>,
    reply_rx: Receiver<WorkerReply>,
    cfg: CascadeConfig,
    classes: usize,
    policy: BatchPolicy,
    expert: Expert,
    pipeline: Pipeline,
    rng: Rng,
    // learner state (mirrors Cascade)
    caches: Vec<Ring<(Arc<Featurized>, usize)>>,
    calib_caches: Vec<Ring<(Vec<f32>, f32)>>,
    pendings: Vec<usize>,
    calib_pendings: Vec<usize>,
    betas: Vec<f64>,
    threshold_scale: f64,
}

impl Server {
    /// Spawn workers and build the router.
    pub fn new(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        policy: BatchPolicy,
        artifacts_dir: &str,
    ) -> Result<Self> {
        let (reply_tx, reply_rx) = channel();
        let mut workers = Vec::new();
        for (i, lc) in cfg.levels.iter().enumerate() {
            workers.push(spawn_worker(
                i,
                lc.model,
                classes,
                cfg.seed ^ ((i as u64 + 1) * 0x5E77E),
                cfg.engine,
                artifacts_dir.to_string(),
                reply_tx.clone(),
            ));
        }
        let n = cfg.levels.len();
        Ok(Server {
            workers,
            reply_rx,
            classes,
            policy,
            expert,
            pipeline: Pipeline::default(),
            rng: Rng::new(cfg.seed ^ 0x5E57E),
            caches: cfg
                .levels
                .iter()
                .map(|l| Ring::new(l.cache_size.max(l.batch_size) * 16))
                .collect(),
            calib_caches: (0..n).map(|_| Ring::new(128)).collect(),
            pendings: vec![0; n],
            calib_pendings: vec![0; n],
            betas: vec![cfg.beta0; n],
            threshold_scale: 1.0,
            cfg,
        })
    }

    /// Set the cost-pressure knob (see [`crate::cascade::Cascade`]).
    pub fn set_threshold_scale(&mut self, s: f64) {
        self.threshold_scale = s;
    }

    /// Serve a stream of requests arriving through `rx`; send responses
    /// to `tx`. Returns the report when `rx` closes and drains.
    pub fn serve(
        mut self,
        rx: Receiver<Request>,
        tx: Sender<Response>,
    ) -> Result<ServeReport> {
        let t_start = Instant::now();
        let n_levels = self.cfg.levels.len();
        let mut pending: std::collections::HashMap<u64, Pending> =
            std::collections::HashMap::new();
        let mut queues: Vec<LevelQueue> = (0..n_levels)
            .map(|_| LevelQueue { jobs: VecDeque::new(), oldest: None, in_flight: false })
            .collect();
        let mut lat = Percentiles::new();
        let mut handled = vec![0usize; n_levels + 1];
        let mut correct = 0usize;
        let mut served = 0usize;
        let mut llm_calls = 0u64;
        let mut inputs_open = true;

        loop {
            // 1. admit new requests (non-blocking drain).
            while inputs_open {
                match rx.try_recv() {
                    Ok(req) => {
                        let f = Arc::new(self.pipeline.featurize(&req.text));
                        let state = Pending {
                            f: f.clone(),
                            truth: req.truth,
                            sample: req.sample,
                            t0: Instant::now(),
                            seen: vec![None; n_levels],
                        };
                        pending.insert(req.id, state);
                        // DAgger jump straight to the expert?
                        let jump = self.betas[0] > 0.0 && self.rng.coin(self.betas[0]);
                        for b in &mut self.betas {
                            let decay = self.cfg.levels[0].beta_decay;
                            *b *= decay;
                        }
                        if jump {
                            self.to_expert(
                                req.id, &mut pending, &tx, &mut lat, &mut handled,
                                &mut correct, &mut served, &mut llm_calls,
                            );
                        } else {
                            queues[0].jobs.push_back(Job { req_id: req.id, f });
                            queues[0].oldest.get_or_insert_with(Instant::now);
                        }
                    }
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        inputs_open = false;
                    }
                }
            }

            // 2. flush batches that are full or past deadline.
            for (i, q) in queues.iter_mut().enumerate() {
                let due = q.jobs.len() >= self.policy.batch_max
                    || q.oldest
                        .map(|t| t.elapsed() >= self.policy.deadline)
                        .unwrap_or(false)
                    || (!inputs_open && !q.jobs.is_empty());
                if due && !q.in_flight && !q.jobs.is_empty() {
                    let take = q.jobs.len().min(self.policy.batch_max);
                    let jobs: Vec<Job> = q.jobs.drain(..take).collect();
                    q.oldest = if q.jobs.is_empty() { None } else { Some(Instant::now()) };
                    q.in_flight = true;
                    self.workers[i]
                        .tx
                        .send(WorkerMsg::Infer(jobs))
                        .map_err(|_| Error::Worker(format!("level {i} died")))?;
                }
            }

            // 3. handle one worker reply (with a small timeout so the
            //    loop keeps admitting/flushing).
            match self.reply_rx.recv_timeout(Duration::from_micros(200)) {
                Ok(reply) => {
                    let lvl = reply.level;
                    queues[lvl].in_flight = false;
                    for (req_id, probs, score) in reply.results {
                        let Some(state) = pending.get_mut(&req_id) else { continue };
                        state.seen[lvl] = Some(probs.clone());
                        let tau =
                            self.cfg.levels[lvl].calibration * self.threshold_scale;
                        let defer = (score as f64) > tau;
                        if !defer {
                            // exit here
                            let pred = argmax(&probs);
                            let state = pending.remove(&req_id).expect("state");
                            lat.push(state.t0.elapsed().as_secs_f64() * 1e3);
                            handled[lvl] += 1;
                            if pred == state.truth {
                                correct += 1;
                            }
                            served += 1;
                            let _ = tx.send(Response {
                                id: req_id,
                                pred,
                                handled_by: lvl,
                                latency: state.t0.elapsed(),
                                truth: state.truth,
                            });
                        } else if lvl + 1 < n_levels {
                            let f = state.f.clone();
                            queues[lvl + 1].jobs.push_back(Job { req_id, f });
                            queues[lvl + 1].oldest.get_or_insert_with(Instant::now);
                        } else {
                            self.to_expert(
                                req_id, &mut pending, &tx, &mut lat, &mut handled,
                                &mut correct, &mut served, &mut llm_calls,
                            );
                        }
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(Error::Worker("all workers died".into()));
                }
            }

            if !inputs_open
                && pending.is_empty()
                && queues.iter().all(|q| q.jobs.is_empty() && !q.in_flight)
            {
                break;
            }
        }

        // shutdown workers
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.handle.join();
        }
        let wall = t_start.elapsed().as_secs_f64();
        Ok(ServeReport {
            served,
            throughput: served as f64 / wall.max(1e-9),
            wall_secs: wall,
            latency_ms: lat,
            handled,
            accuracy: if served == 0 { 0.0 } else { correct as f64 / served as f64 },
            llm_calls,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn to_expert(
        &mut self,
        req_id: u64,
        pending: &mut std::collections::HashMap<u64, Pending>,
        tx: &Sender<Response>,
        lat: &mut Percentiles,
        handled: &mut [usize],
        correct: &mut usize,
        served: &mut usize,
        llm_calls: &mut u64,
    ) {
        let Some(state) = pending.remove(&req_id) else { return };
        let n_levels = self.cfg.levels.len();
        let y_star = self
            .expert
            .annotate(&state.sample, self.classes)
            .unwrap_or(0);
        *llm_calls += 1;
        // online learning: feed caches, train at cadence
        for i in 0..n_levels {
            self.caches[i].push((state.f.clone(), y_star));
            self.pendings[i] += 1;
            if let Some(probs) = &state.seen[i] {
                let z = if argmax(probs) != y_star { 1.0 } else { 0.0 };
                self.calib_caches[i].push((probs.clone(), z));
                self.calib_pendings[i] += 1;
            }
            let bs = self.cfg.levels[i].batch_size;
            if self.pendings[i] >= bs && self.caches[i].len() >= bs {
                let items = self.caches[i].to_vec();
                let idx = self.rng.sample_indices(items.len(), bs.min(items.len()));
                let batch: Vec<(Arc<Featurized>, usize)> =
                    idx.iter().map(|&j| items[j].clone()).collect();
                let _ = self.workers[i]
                    .tx
                    .send(WorkerMsg::Train(batch, self.cfg.levels[i].model_lr));
                self.pendings[i] = 0;
            }
            if self.calib_pendings[i] >= 8 && self.calib_caches[i].len() >= 8 {
                let items = self.calib_caches[i].to_vec();
                let idx = self.rng.sample_indices(items.len(), 8);
                let batch: Vec<(Vec<f32>, f32)> =
                    idx.iter().map(|&j| items[j].clone()).collect();
                let _ = self.workers[i].tx.send(WorkerMsg::TrainCalib(
                    batch,
                    self.cfg.levels[i].mlp_lr * 50.0,
                ));
                self.calib_pendings[i] = 0;
            }
        }
        lat.push(state.t0.elapsed().as_secs_f64() * 1e3);
        handled[n_levels] += 1;
        if y_star == state.truth {
            *correct += 1;
        }
        *served += 1;
        let _ = tx.send(Response {
            id: req_id,
            pred: y_star,
            handled_by: n_levels,
            latency: state.t0.elapsed(),
            truth: state.truth,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BenchmarkId, ExpertId};
    use crate::data::Benchmark;
    use crate::sim::ExpertProfile;

    #[test]
    fn serves_a_small_stream_end_to_end() {
        let n = 400;
        let b = Benchmark::build_sized(BenchmarkId::Imdb, 31, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let expert = Expert::new(
            ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
            b.strata_fractions(),
            mean_len,
            31,
        );
        let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let server =
            Server::new(cfg, 2, expert, BatchPolicy::default(), "artifacts").unwrap();
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let submit = std::thread::spawn(move || {
            for (i, s) in b.samples.iter().enumerate() {
                req_tx
                    .send(Request {
                        id: i as u64,
                        text: s.text.clone(),
                        truth: s.label,
                        sample: s.clone(),
                    })
                    .unwrap();
            }
            // req_tx drops -> server drains and stops
        });
        let report = server.serve(req_rx, resp_tx).unwrap();
        submit.join().unwrap();
        let responses: Vec<Response> = resp_rx.iter().collect();
        assert_eq!(report.served, n);
        assert_eq!(responses.len(), n);
        // every request answered exactly once
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        assert!(report.accuracy > 0.5, "acc {}", report.accuracy);
        assert!(report.throughput > 10.0, "thr {}", report.throughput);
        assert_eq!(report.handled.iter().sum::<usize>(), n);
    }
}
