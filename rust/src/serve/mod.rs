//! Streaming serving mode: request router + dynamic batcher + per-level
//! worker pools (the vLLM-style leader/worker topology), with worker
//! supervision, admission control, and scale-out sharding.
//!
//! Why threads-per-model: `PjRtClient` is `Rc`-based and cannot cross
//! threads, so each worker *builds its own engine* on its own thread;
//! the router owns only channels. The router executes the cascade
//! policy (deferral walk + online learning cadence) while workers
//! execute model inference/updates — queries are batched per level (up
//! to `batch_max` or `deadline`), which is what amortizes PJRT dispatch
//! overhead on the hot path (§Perf L3).
//!
//! **Topology.** Three nested layers (DESIGN.md §9):
//! - [`shard`] — N routers behind a hashing front dispatcher, with an
//!   optional cross-shard annotation broadcast so every shard's
//!   learners converge toward the single-learner trajectory.
//! - [`pool`] — per level, a *learner authority* worker that applies
//!   all training plus read-only replicas that install the authority's
//!   published snapshots for inference fan-out. Respawns are *warm*:
//!   they restore the latest snapshot instead of fresh weights.
//! - [`crate::models::Snapshot`] — the bit-for-bit serializable weight
//!   state that moves authority → replica, across respawns, and (via
//!   JSON) across processes.
//!
//! With `shards = 1, replicas = 1, sync = 0` all of this degenerates
//! to the single supervised router, bit-for-bit.
//!
//! **Learner parity.** The router's online-learning mirror of
//! [`crate::cascade::Cascade`] consults each level's *own* DAgger β at
//! the value snapshotted at the request's admission (so queueing delay
//! never skews jump probabilities; decay uses each level's own factor,
//! one step per admitted request), builds training batches via
//! the shared [`crate::cascade::replay_picks`], trains calibrators with
//! [`crate::cascade::CALIB_REPLAY`] replay passes at the shared
//! [`crate::cascade::MLP_LR_SCALE`], and evaluates walk-skipped levels
//! through async calibration probes — so the served cascade learns the
//! same way the offline one does (asserted in `tests/test_serve_load.rs`).
//! All training flows through each pool's single authority, which is
//! what keeps the trajectory serialized even at replica capacity > 1.
//!
//! **Supervision.** A dead pool worker (panic, send/recv failure, or
//! injected [`Chaos`]) is detected by the router loop, respawned from
//! config, and its in-flight batch is requeued at the front of the
//! level queue — every admitted request is still answered exactly once
//! (stale replies from the old worker generation are dropped by epoch).
//! The respawn restores the latest published snapshot (warm restart);
//! only gradient steps since the last publication are lost, and the
//! replay caches living in the router re-teach those on the next
//! training trigger. The restart budget is [`ServeConfig::max_restarts`].
//!
//! **Admission control.** The in-system population is bounded by a
//! single [`ServeConfig::max_pending`] budget shared by *every* shard
//! behind a front (an [`AdmissionGate`]; a stand-alone router owns a
//! private gate, which degenerates to the old per-router bound).
//! Arrivals beyond the budget are shed with an immediate [`Response`]
//! (`shed = true`) and counted separately, so overload degrades by
//! refusing work instead of by growing queues without bound — and a
//! hot shard can no longer hide behind an idle peer's headroom.
//!
//! **Durability.** With a checkpoint directory configured ([`ckpt`]),
//! the router persists its full learner state every
//! [`ServeConfig::ckpt_every`] expert annotations and at graceful
//! shutdown. Cadence checkpoints are quiescent barriers: admission
//! pauses, in-flight work drains, the state is written atomically,
//! admission resumes — which is what makes a resumed β/chunk-count
//! trajectory bit-identical to an uninterrupted run. Quiescence covers
//! the pipelined path too: stage queues and in-flight speculative
//! copies drain before the barrier fires.
//!
//! **Pipelining + speculation** (`ServeConfig::{pipeline,
//! spec_threshold}`, DESIGN.md §13). With `pipeline` on, deferred jobs
//! ride bounded per-level [`stage`] queues and dispatch the moment a
//! replica frees instead of waiting out the batch deadline — level
//! k+1 inference for one batch overlaps level k inference for the
//! next. With `spec_threshold < 1`, a gate that defers on a score
//! above the threshold also dispatches the request *speculatively* one
//! level further ahead, before that level's gate result lands; the
//! real gate's decision then consumes the speculative result (hit) or
//! discards it (wasted). Both are inference-only scheduling changes:
//! gates alone decide exits, expert hops, and what trains, every RNG
//! draw happens at the same per-request points, and speculative
//! results never enter `seen`/calibration unless the gate really
//! deferred there — so the learner trajectory is bit-identical to the
//! sequential router (pinned in `tests/test_serve_load.rs`).

pub mod barrier;
pub mod ckpt;
pub mod load;
pub mod net;
pub mod pool;
pub mod reshard;
pub mod scale;
pub mod shard;
pub(crate) mod stage;

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use crate::sync::Arc;

use crate::cascade::{replay_picks, CALIB_CACHE, CALIB_REPLAY, MLP_LR_SCALE, REPLAY_FACTOR};
use crate::config::CascadeConfig;
pub use crate::config::{ServeConfig, ShardConfig};
use crate::data::Sample;
use crate::error::{Error, Result};
use crate::models::{Featurized, Pipeline};
use crate::prng::Rng;
use crate::sim::Expert;
use crate::util::{argmax, Percentiles, Ring};

use barrier::{CkptBarrier, ExportOutcome};
use ckpt::{CkptSink, LevelState, ShardState};
use pool::{LevelPool, PoolInit, WorkerReply, WorkerSpec};

/// A client request: one document to classify.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Client-assigned id (returned in the response).
    pub id: u64,
    /// Document text.
    pub text: String,
    /// Ground truth — metrics only (the router never reads it).
    pub truth: usize,
    /// Stable sample id for the expert simulator.
    pub sample: Sample,
}

/// The served answer.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Predicted label (0 and meaningless when `shed`).
    pub pred: usize,
    /// Which level answered: `0..levels.len()` = cascade level,
    /// `levels.len()` = expert, `levels.len() + 1` = shed at admission.
    pub handled_by: usize,
    /// End-to-end latency (zero when shed).
    pub latency: Duration,
    /// Ground truth (echoed for client-side accuracy accounting).
    pub truth: usize,
    /// True when the request was refused by admission control.
    pub shed: bool,
}

/// Serving report: latency distribution + throughput + routing mix +
/// supervision/overload/snapshot accounting.
#[derive(Debug)]
pub struct ServeReport {
    /// Requests served (excludes shed).
    pub served: usize,
    /// Requests shed by admission control.
    pub shed: usize,
    /// End-to-end latency percentiles (milliseconds, served only).
    pub latency_ms: Percentiles,
    /// Wall-clock duration of the run (seconds).
    pub wall_secs: f64,
    /// Requests served per second *by this run* (a resumed run's
    /// cumulative `served` includes the interrupted run's work, which
    /// this rate deliberately excludes).
    pub throughput: f64,
    /// Per-level handled counts (last = expert).
    pub handled: Vec<usize>,
    /// Accuracy vs ground truth (served only).
    pub accuracy: f64,
    /// Expert calls.
    pub llm_calls: u64,
    /// Worker respawns per level (pool-wide).
    pub restarts: Vec<usize>,
    /// The restart budget the run was configured with
    /// ([`ServeConfig::max_restarts`]).
    pub restart_cap: usize,
    /// Respawns that restored a published snapshot (warm restarts).
    pub warm_respawns: Vec<usize>,
    /// Snapshot publications per level.
    pub snapshots: Vec<u64>,
    /// Snapshot staleness per level at the end of the run: authority
    /// training chunks not yet captured by a publication.
    pub snapshot_lag: Vec<u64>,
    /// Inference jobs dispatched per level per pool member (member 0 =
    /// the learner authority) — the per-replica throughput counters.
    pub replica_jobs: Vec<Vec<u64>>,
    /// Largest in-system population observed (≤ `max_pending`; local
    /// to this shard — the shared budget's peak is reported by
    /// `shard::ShardReport::peak_pending`).
    pub peak_pending: usize,
    /// True when this run restored a checkpoint (counters above then
    /// continue the interrupted run's totals).
    pub resumed: bool,
    /// Durable checkpoints written during this run (cadence + the
    /// graceful-shutdown one).
    pub ckpts: u64,
    /// Cadence checkpoint attempts aborted because the level authority
    /// was alive but too slow to export within
    /// [`ServeConfig::export_timeout`] — each abort resumes admission
    /// and re-arms the next cadence (liveness over ckpt freshness).
    pub ckpt_aborts: u64,
    /// Per-level DAgger β after the run (cascade-parity diagnostic).
    pub final_betas: Vec<f64>,
    /// 8-sample model-training chunks executed per level worker.
    pub train_batches: Vec<u64>,
    /// 8-sample calibrator-training chunks executed per level worker.
    pub calib_batches: Vec<u64>,
    /// Cumulative wall-clock nanoseconds spent in batched inference
    /// (predict + calibrator scoring) per level, summed across the
    /// level's pool members. Report-only: not checkpointed.
    pub infer_ns: Vec<u64>,
    /// Speculative dispatches whose target level the real gate then
    /// deferred into (the speculation paid off).
    pub spec_hits: u64,
    /// Speculative dispatches discarded because the real gate kept,
    /// jumped to the expert, or exhausted the cascade.
    pub spec_wasted: u64,
    /// Per-level peak queued-work depth (stage queue + batcher backlog)
    /// observed during the run — the pipelining backpressure signal.
    pub queue_depth: Vec<usize>,
    /// Latency percentiles (ms) for requests answered at level 0 — the
    /// non-deferred population the pipelining success metric compares
    /// against.
    pub latency_direct_ms: Percentiles,
    /// Latency percentiles (ms) for requests that deferred at least
    /// once (answered at level ≥ 1 or by the expert).
    pub latency_deferred_ms: Percentiles,
    /// Autoscale events that added a replica to some level pool
    /// (0 unless [`ServeConfig::autoscale`] is on).
    pub scale_ups: u64,
    /// Autoscale events that removed a replica from some level pool.
    pub scale_downs: u64,
}

impl ServeReport {
    /// JSON encoding (bench baselines, report files).
    pub fn to_json(&self) -> crate::codec::Json {
        use crate::codec::Json;
        let q = self.latency_ms.pcts(&[50.0, 95.0, 99.0]);
        let nums = |xs: &[usize]| {
            Json::Arr(xs.iter().map(|&r| Json::Num(r as f64)).collect())
        };
        let nums64 = |xs: &[u64]| {
            Json::Arr(xs.iter().map(|&r| Json::Num(r as f64)).collect())
        };
        Json::obj(vec![
            ("served", Json::Num(self.served as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("throughput", Json::Num(self.throughput)),
            ("p50_ms", Json::Num(q[0])),
            ("p95_ms", Json::Num(q[1])),
            ("p99_ms", Json::Num(q[2])),
            ("accuracy", Json::Num(self.accuracy)),
            ("llm_calls", Json::Num(self.llm_calls as f64)),
            ("restarts", nums(&self.restarts)),
            ("restart_cap", Json::Num(self.restart_cap as f64)),
            ("warm_respawns", nums(&self.warm_respawns)),
            ("snapshots", nums64(&self.snapshots)),
            ("snapshot_lag", nums64(&self.snapshot_lag)),
            (
                "replica_jobs",
                Json::Arr(self.replica_jobs.iter().map(|r| nums64(r)).collect()),
            ),
            ("peak_pending", Json::Num(self.peak_pending as f64)),
            ("resumed", Json::Bool(self.resumed)),
            ("ckpts", Json::Num(self.ckpts as f64)),
            ("ckpt_aborts", Json::Num(self.ckpt_aborts as f64)),
            ("handled", nums(&self.handled)),
            (
                "final_betas",
                Json::Arr(self.final_betas.iter().map(|&b| Json::Num(b)).collect()),
            ),
            ("infer_ns", nums64(&self.infer_ns)),
            ("spec_hits", Json::Num(self.spec_hits as f64)),
            ("spec_wasted", Json::Num(self.spec_wasted as f64)),
            ("queue_depth", nums(&self.queue_depth)),
            ("p99_direct_ms", Json::Num(self.latency_direct_ms.pct(99.0))),
            ("p99_deferred_ms", Json::Num(self.latency_deferred_ms.pct(99.0))),
            ("p50_direct_ms", Json::Num(self.latency_direct_ms.pct(50.0))),
            ("p50_deferred_ms", Json::Num(self.latency_deferred_ms.pct(50.0))),
            ("scale_ups", Json::Num(self.scale_ups as f64)),
            ("scale_downs", Json::Num(self.scale_downs as f64)),
        ])
    }
}

/// The shared in-system budget ([`ServeConfig::max_pending`]). One
/// gate is shared by every shard behind a [`shard::ShardFront`], so
/// admission is bounded *globally* — previously each shard owned its
/// own `max_pending`, letting an N-shard deployment hold N× the
/// configured population.
///
/// **Verification.** The acquire/release/shed protocol is one of the
/// three model-checked cores: [`crate::mc::models::GateSpec`] mirrors
/// this CAS loop step-for-step and `tests/test_loom.rs` exhaustively
/// explores its interleavings (no-lost-permit, `cur ≤ cap` always,
/// `peak ≤ cap`, every client either admits or sheds exactly once) —
/// plus a real-thread stress pass over *this* type that the nightly
/// ThreadSanitizer job also runs. Keep the two in lockstep: any change
/// here must be reflected in the model.
pub struct AdmissionGate {
    cap: usize,
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl AdmissionGate {
    /// A gate with `cap` in-system slots.
    pub fn new(cap: usize) -> Self {
        AdmissionGate { cap, cur: AtomicUsize::new(0), peak: AtomicUsize::new(0) }
    }

    /// Reserve one in-system slot; `false` when the budget is full
    /// (the caller sheds). Lock-free: shards race through CAS.
    pub fn try_admit(&self) -> bool {
        let mut cur = self.cur.load(Ordering::Relaxed);
        loop {
            if cur >= self.cap {
                return false;
            }
            match self.cur.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(cur + 1, Ordering::Relaxed);
                    return true;
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Release one slot (request answered).
    pub fn release(&self) {
        self.cur.fetch_sub(1, Ordering::AcqRel);
    }

    /// Largest population the gate ever admitted.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Current in-system population (tests/diagnostics).
    pub fn current(&self) -> usize {
        self.cur.load(Ordering::Relaxed)
    }
}

/// Fault injection: crash one pool worker after the N-th admission
/// (the serve-layer twin of `Expert::set_available(false)`).
#[derive(Clone, Copy, Debug)]
pub struct Chaos {
    /// Which level's pool to hit.
    pub kill_level: usize,
    /// Which pool member to kill (0 = the learner authority).
    pub kill_replica: usize,
    /// Crash after this many admitted (non-shed) requests.
    pub after_requests: usize,
}

// --- router ----------------------------------------------------------------

/// One unit of level work: an inference (or calibration-probe) job.
/// `pub(crate)` because it crosses into [`pool`]'s worker protocol.
#[derive(Clone)]
pub(crate) struct Job {
    /// Request id for inference jobs; router-allocated probe id for
    /// calibration probes. The two id spaces may overlap — `probe`
    /// disambiguates (client ids are arbitrary u64s, so no id range
    /// can be reserved for probes).
    pub(crate) req_id: u64,
    /// True for calibration-probe jobs (their replies feed
    /// `probe_truth`, never the pending map).
    pub(crate) probe: bool,
    /// True for speculative copies (dispatched ahead of the gate
    /// decision; the reply is consumed only if the real gate deferred
    /// into this level, else dropped — see module docs).
    pub(crate) spec: bool,
    pub(crate) f: Arc<Featurized>,
    /// Enqueue instant — the batch deadline is measured from here, so a
    /// partial drain never re-arms the clock for surviving jobs.
    pub(crate) enq: Instant,
}

struct Pending {
    f: Arc<Featurized>,
    truth: usize,
    sample: Sample,
    t0: Instant,
    /// Per-level (probs, deferral score) gathered on the walk.
    seen: Vec<Option<(Vec<f32>, f32)>>,
    /// β vector snapshot at admission (pre-decay): the walk's DAgger
    /// gates consult *these* values, exactly as `Cascade::process`
    /// consults the pre-decay β of the sample's own step — a deferral
    /// processed after later admissions must not see further-decayed β.
    betas_at_admit: Vec<f64>,
    /// Level currently holding a speculative copy (queued or in
    /// flight), if any. Doubles as the staleness guard: an arriving
    /// speculative reply is dropped unless it matches this level.
    spec_level: Option<usize>,
    /// Speculative result that landed before the real gate decided
    /// whether to defer into its level.
    spec_result: Option<(Vec<f32>, f32)>,
    /// Set once the real gate deferred into `spec_level` while the
    /// speculative copy was still in flight — its reply is then
    /// consumed as the real level result the moment it arrives.
    spec_keep: bool,
}

/// Calibration probe bookkeeping for an expert-annotated request whose
/// walk skipped some levels (see module docs, Learner parity).
struct ProbeWait {
    y_star: usize,
    left: usize,
}

/// A batch of expert annotations replicated from a peer shard
/// ([`shard`] sync; see `ShardConfig::sync_interval`).
pub(crate) struct SyncBatch(pub(crate) Vec<(Arc<Featurized>, usize)>);

struct LevelQueue {
    jobs: VecDeque<Job>,
    /// Batches currently at pool members — kept for requeue-on-death
    /// (one slot per replica).
    in_flight: Vec<Option<Vec<Job>>>,
}

impl LevelQueue {
    fn new(replicas: usize) -> Self {
        LevelQueue { jobs: VecDeque::new(), in_flight: vec![None; replicas] }
    }

    fn push(&mut self, job: Job) {
        self.jobs.push_back(job);
    }

    /// Enqueue instant of the oldest queued job — deadline clock.
    fn oldest_enq(&self) -> Option<Instant> {
        self.jobs.front().map(|j| j.enq)
    }

    /// Should this queue flush a batch now?
    fn due(&self, batch_max: usize, deadline: Duration, draining: bool) -> bool {
        !self.jobs.is_empty()
            && (self.jobs.len() >= batch_max
                || draining
                || self
                    .oldest_enq()
                    .map(|t| t.elapsed() >= deadline)
                    .unwrap_or(false))
    }

    fn take(&mut self, max: usize) -> Vec<Job> {
        let take = self.jobs.len().min(max);
        self.jobs.drain(..take).collect()
    }

    /// Least-loaded free pool member (ties → lowest index); `None`
    /// when every member has a batch in flight.
    fn free_replica(&self, jobs_done: &[u64]) -> Option<usize> {
        (0..self.in_flight.len())
            .filter(|&r| self.in_flight[r].is_none())
            .min_by_key(|&r| jobs_done[r])
    }

    /// Put a requeued batch back at the front, preserving order and the
    /// original enqueue timestamps.
    fn requeue_front(&mut self, jobs: Vec<Job>) {
        for job in jobs.into_iter().rev() {
            self.jobs.push_front(job);
        }
    }
}

/// Cumulative counters restored from a checkpoint (all zero for a
/// fresh run) — a resumed run's `ServeReport` continues the totals the
/// interrupted run had banked.
#[derive(Clone, Default)]
struct RunBase {
    served: usize,
    shed: usize,
    correct: usize,
    llm_calls: u64,
    handled: Vec<usize>,
    cursor: u64,
}

/// Mutable per-run state of the serve loop (split from `Server` so the
/// router methods can borrow both independently).
struct RunState {
    pending: HashMap<u64, Pending>,
    probe_truth: HashMap<u64, ProbeWait>,
    queues: Vec<LevelQueue>,
    /// Per-level stage queues — the pipelined dispatch path (empty and
    /// inert when `ServeConfig::pipeline` is off).
    stages: Vec<stage::StageQueue>,
    lat: Percentiles,
    /// Latency split by routing outcome: answered at level 0 vs
    /// deferred at least once (the pipelining success metric).
    lat_direct: Percentiles,
    lat_deferred: Percentiles,
    handled: Vec<usize>,
    correct: usize,
    served: usize,
    shed: usize,
    llm_calls: u64,
    admitted: usize,
    peak_pending: usize,
    /// Speculation outcome counters (see `ServeReport`).
    spec_hits: u64,
    spec_wasted: u64,
    /// Per-level peak queued-work depth (stage + batcher backlog).
    queue_depth: Vec<usize>,
    /// Stream high-water mark: 1 + the largest request id seen. At a
    /// quiescent checkpoint (pending empty) this is exactly the resume
    /// cursor — every id below it has been fully absorbed. Assumes the
    /// driver assigns sequential ids, which `load::drive` and `ocl
    /// serve` do.
    cursor: u64,
}

impl RunState {
    fn new(n_levels: usize, replicas: usize, stage_depth: usize, base: &RunBase) -> Self {
        RunState {
            pending: HashMap::new(),
            probe_truth: HashMap::new(),
            queues: (0..n_levels).map(|_| LevelQueue::new(replicas)).collect(),
            stages: (0..n_levels).map(|_| stage::StageQueue::new(stage_depth)).collect(),
            lat: Percentiles::new(),
            lat_direct: Percentiles::new(),
            lat_deferred: Percentiles::new(),
            handled: if base.handled.is_empty() {
                vec![0; n_levels + 1]
            } else {
                base.handled.clone()
            },
            correct: base.correct,
            served: base.served,
            shed: base.shed,
            llm_calls: base.llm_calls,
            admitted: 0,
            peak_pending: 0,
            spec_hits: 0,
            spec_wasted: 0,
            queue_depth: vec![0; n_levels],
            cursor: base.cursor,
        }
    }

    /// Nothing left to do once inputs are closed? Quiescence for the
    /// checkpoint barrier and shutdown: empty stage queues are part of
    /// it, and in-flight speculative copies drain through the same
    /// `in_flight` slots as everything else — a pending request whose
    /// only outstanding work is a speculative reply keeps `pending`
    /// non-empty until that reply lands and resolves it.
    fn idle(&self) -> bool {
        self.pending.is_empty()
            && self.probe_truth.is_empty()
            && self.stages.iter().all(|s| s.is_empty())
            && self.queues.iter().all(|q| {
                q.jobs.is_empty() && q.in_flight.iter().all(|f| f.is_none())
            })
    }

    /// Record the per-level queued-work high-water mark (report
    /// diagnostics; called each dispatch sweep).
    fn note_queue_depth(&mut self) {
        for i in 0..self.queue_depth.len() {
            let d = self.stages[i].len() + self.queues[i].jobs.len();
            self.queue_depth[i] = self.queue_depth[i].max(d);
        }
    }
}

/// The streaming cascade server (one router shard).
pub struct Server {
    pools: Vec<LevelPool>,
    reply_rx: Receiver<WorkerReply>,
    cfg: CascadeConfig,
    serve_cfg: ServeConfig,
    classes: usize,
    expert: Expert,
    pipeline: Pipeline,
    rng: Rng,
    chaos: Option<Chaos>,
    // cross-shard annotation sync (wired by `shard::ShardFront`)
    sync_out: Vec<Sender<SyncBatch>>,
    sync_in: Option<Receiver<SyncBatch>>,
    sync_staged: Vec<(Arc<Featurized>, usize)>,
    /// Probe-id allocator: every annotation event (local or remote)
    /// that spawns calibration probes gets one fresh key into
    /// `probe_truth`. Probe jobs are tagged (`Job::probe`), so this
    /// space never clashes with client request ids.
    probe_seq: u64,
    // learner state (mirrors Cascade)
    caches: Vec<Ring<(Arc<Featurized>, usize)>>,
    calib_caches: Vec<Ring<(Vec<f32>, f32)>>,
    pendings: Vec<usize>,
    calib_pendings: Vec<usize>,
    betas: Vec<f64>,
    threshold_scale: f64,
    // admission + durability
    admission: Arc<AdmissionGate>,
    ckpt_sink: Option<Arc<CkptSink>>,
    shard_idx: usize,
    resumed: bool,
    barrier: CkptBarrier,
    base: RunBase,
}

impl Server {
    /// Spawn the level pools and build the router (fresh learner state).
    pub fn new(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        serve_cfg: ServeConfig,
        artifacts_dir: &str,
    ) -> Result<Self> {
        Self::build(cfg, classes, expert, serve_cfg, artifacts_dir, None)
    }

    /// Rebuild a router from a checkpointed shard state: the pools'
    /// snapshot slots are seeded with the checkpointed weights before
    /// any worker spawns, and every learner field (β, RNG, caches,
    /// cadence counters, sync stage, cumulative report counters)
    /// continues exactly where the checkpoint left it.
    pub fn resume(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        serve_cfg: ServeConfig,
        artifacts_dir: &str,
        state: ShardState,
    ) -> Result<Self> {
        Self::build(cfg, classes, expert, serve_cfg, artifacts_dir, Some(state))
    }

    fn build(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        serve_cfg: ServeConfig,
        artifacts_dir: &str,
        state: Option<ShardState>,
    ) -> Result<Self> {
        if serve_cfg.batch_max == 0 || serve_cfg.max_pending == 0 {
            return Err(Error::Config(
                "serve batch_max and max_pending must be positive".into(),
            ));
        }
        if serve_cfg.shard.replicas_per_level == 0 || serve_cfg.shard.shards == 0 {
            return Err(Error::Config(
                "serve shards and replicas_per_level must be positive".into(),
            ));
        }
        // Struct-literal construction can bypass `ServeConfig::builder`,
        // so the pipeline/speculation knobs are re-checked here.
        if serve_cfg.stage_queue_depth == 0 {
            return Err(Error::Config("serve stage_queue_depth must be positive".into()));
        }
        if !(serve_cfg.spec_threshold > 0.0 && serve_cfg.spec_threshold <= 1.0) {
            return Err(Error::Config(format!(
                "serve spec_threshold must be in (0, 1], got {}",
                serve_cfg.spec_threshold
            )));
        }
        if serve_cfg.autoscale {
            if serve_cfg.replicas_min == 0 {
                return Err(Error::Config("serve replicas_min must be positive".into()));
            }
            if serve_cfg.replicas_min > serve_cfg.replicas_max {
                return Err(Error::Config(format!(
                    "serve replicas_min ({}) must not exceed replicas_max ({})",
                    serve_cfg.replicas_min, serve_cfg.replicas_max
                )));
            }
            let r = serve_cfg.shard.replicas_per_level;
            if r < serve_cfg.replicas_min || r > serve_cfg.replicas_max {
                return Err(Error::Config(format!(
                    "serve replicas_per_level ({r}) must start inside the autoscale \
                     bounds [{}, {}]",
                    serve_cfg.replicas_min, serve_cfg.replicas_max
                )));
            }
        }
        if let Some(s) = &state {
            s.check_config(&cfg, classes)?;
        }
        let (reply_tx, reply_rx) = channel();
        let pools: Vec<LevelPool> = cfg
            .levels
            .iter()
            .enumerate()
            .map(|(i, lc)| {
                let init = state.as_ref().map(|s| {
                    let l = &s.levels[i];
                    PoolInit {
                        model: l.model.clone(),
                        calib: l.calib.clone(),
                        train_chunks: l.train_chunks,
                        calib_chunks: l.calib_chunks,
                        train_sends: l.train_sends,
                    }
                });
                LevelPool::new(
                    WorkerSpec {
                        level: i,
                        kind: lc.model,
                        classes,
                        seed: cfg.seed ^ ((i as u64 + 1) * 0x5E77E),
                        engine: cfg.engine,
                        artifacts_dir: artifacts_dir.to_string(),
                    },
                    serve_cfg.shard.replicas_per_level,
                    serve_cfg.publish_every,
                    reply_tx.clone(),
                    init,
                )
            })
            .collect();
        drop(reply_tx); // each pool holds its own clone for respawns
        let n = cfg.levels.len();
        let mut caches: Vec<Ring<(Arc<Featurized>, usize)>> = cfg
            .levels
            .iter()
            .map(|l| Ring::new(l.cache_size.max(l.batch_size) * REPLAY_FACTOR))
            .collect();
        let mut calib_caches: Vec<Ring<(Vec<f32>, f32)>> =
            (0..n).map(|_| Ring::new(CALIB_CACHE)).collect();
        let mut pendings = vec![0; n];
        let mut calib_pendings = vec![0; n];
        let mut betas = vec![cfg.beta0; n];
        let mut rng = Rng::new(cfg.seed ^ 0x5E57E);
        let mut probe_seq = 0;
        let mut threshold_scale = 1.0;
        let mut sync_staged = Vec::new();
        let mut shard_idx = 0;
        let mut base = RunBase::default();
        let resumed = state.is_some();
        if let Some(s) = state {
            base = RunBase {
                served: s.served,
                shed: s.shed,
                correct: s.correct,
                llm_calls: s.llm_calls,
                handled: s.handled,
                cursor: s.cursor,
            };
            for (i, l) in s.levels.into_iter().enumerate() {
                for item in l.cache {
                    caches[i].push(item);
                }
                for item in l.calib_cache {
                    calib_caches[i].push(item);
                }
                pendings[i] = l.pending;
                calib_pendings[i] = l.calib_pending;
            }
            betas = s.betas;
            rng = Rng::from_state(s.rng_s, s.rng_cached);
            probe_seq = s.probe_seq;
            threshold_scale = s.threshold_scale;
            sync_staged = s.sync_staged;
            shard_idx = s.shard;
        }
        Ok(Server {
            pools,
            reply_rx,
            classes,
            expert,
            pipeline: Pipeline::default(),
            rng,
            chaos: None,
            sync_out: Vec::new(),
            sync_in: None,
            sync_staged,
            probe_seq,
            caches,
            calib_caches,
            pendings,
            calib_pendings,
            betas,
            threshold_scale,
            admission: Arc::new(AdmissionGate::new(serve_cfg.max_pending)),
            ckpt_sink: None,
            shard_idx,
            resumed,
            barrier: CkptBarrier::new(serve_cfg.ckpt_every),
            base,
            serve_cfg,
            cfg,
        })
    }

    /// Wire durable checkpointing: the router will deposit its state
    /// into `sink` as shard `shard_idx` every
    /// [`ServeConfig::ckpt_every`] annotations and at graceful
    /// shutdown.
    pub fn attach_ckpt(&mut self, sink: Arc<CkptSink>, shard_idx: usize) {
        self.ckpt_sink = Some(sink);
        self.shard_idx = shard_idx;
    }

    /// Share a global admission budget (called by
    /// [`shard::ShardFront`]; a stand-alone server keeps its private
    /// gate).
    pub(crate) fn set_admission(&mut self, gate: Arc<AdmissionGate>) {
        self.admission = gate;
    }

    /// Set the cost-pressure knob (see [`crate::cascade::Cascade`]).
    pub fn set_threshold_scale(&mut self, s: f64) {
        self.threshold_scale = s;
    }

    /// Arm fault injection (supervision tests): crash one pool worker
    /// mid-stream. `kill_level`/`kill_replica` must exist.
    pub fn inject_chaos(&mut self, chaos: Chaos) {
        assert!(chaos.kill_level < self.cfg.levels.len(), "chaos level out of range");
        assert!(
            chaos.kill_replica < self.pools[chaos.kill_level].replicas(),
            "chaos replica out of range"
        );
        self.chaos = Some(chaos);
    }

    /// Wire the cross-shard annotation broadcast (called by
    /// [`shard::ShardFront`]; a stand-alone server has no peers).
    pub(crate) fn wire_sync(
        &mut self,
        out: Vec<Sender<SyncBatch>>,
        inbox: Receiver<SyncBatch>,
    ) {
        self.sync_out = out;
        self.sync_in = Some(inbox);
    }

    /// Serve a stream of requests arriving through `rx`; send responses
    /// to `tx`. Returns the report when `rx` closes and drains.
    pub fn serve(
        mut self,
        rx: Receiver<Request>,
        tx: Sender<Response>,
    ) -> Result<ServeReport> {
        let t_start = Instant::now();
        let n_levels = self.cfg.levels.len();
        let mut st = RunState::new(
            n_levels,
            self.serve_cfg.shard.replicas_per_level,
            self.serve_cfg.stage_queue_depth,
            &self.base,
        );
        let mut inputs_open = true;
        // One-shot end-of-stream broadcast of below-interval staged
        // annotations (the drain-on-exit flush).
        let mut sync_flushed = false;
        // Elasticity: one autoscale controller per level, consulted
        // once per dispatch sweep. `None` unless the config opts in —
        // the default topology stays static and bit-identical to
        // earlier releases.
        let mut scalers: Option<Vec<scale::ScaleController>> =
            self.serve_cfg.autoscale.then(|| {
                let policy = scale::ScalePolicy::bounded(
                    self.serve_cfg.replicas_min,
                    self.serve_cfg.replicas_max,
                    self.serve_cfg.batch_max,
                );
                (0..n_levels).map(|_| scale::ScaleController::new(policy)).collect()
            });
        let mut scale_ups = 0u64;
        let mut scale_downs = 0u64;

        loop {
            // 0. supervision: respawn dead workers, requeue their batches.
            for i in 0..n_levels {
                for r in 0..self.pools[i].replicas() {
                    if self.pools[i].workers[r].handle.is_finished() {
                        self.respawn(i, r, &mut st.queues)?;
                    }
                }
            }

            // 0b. arm the checkpoint barrier when the cadence is due
            //     (the pause→drain→export→resume state machine lives
            //     in [`CkptBarrier`] — model-checked by test_loom).
            if inputs_open && self.ckpt_sink.is_some() {
                self.barrier.maybe_arm();
            }

            // 1. admit new requests (non-blocking drain + admission
            //    control); paused while a checkpoint barrier drains —
            //    arrivals wait in the channel, not in router state.
            while inputs_open && !self.barrier.paused() {
                match rx.try_recv() {
                    Ok(req) => self.admit(req, &mut st, &tx),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        inputs_open = false;
                    }
                }
            }

            // 1b. absorb peer-shard annotations (cross-shard sync);
            //     also paused during a barrier so the drain converges.
            if !self.barrier.paused() {
                self.drain_sync(&mut st);
            }

            // 2. flush batches to free pool members (least-loaded
            //    first). Stage-queue jobs (pipelined deferrals +
            //    speculation) are due the moment a replica is free;
            //    batcher jobs wait for fill, deadline, or drain.
            st.note_queue_depth();

            // 2a. elasticity: grow/shrink the level pools off live
            //     queue depth. Scale-up appends a worker (a fresh
            //     `in_flight` slot keeps the queue/pool widths in
            //     lockstep); scale-down retires only the highest-index
            //     member, and only while its slot is empty, so no batch
            //     is ever orphaned and the learner authority (worker 0)
            //     is structurally untouchable — `remove_replica` stops
            //     at one member. A busy victim just skips the event;
            //     the controller's cooldown retries later.
            if let Some(scalers) = scalers.as_mut() {
                for i in 0..n_levels {
                    let depth = st.stages[i].len() + st.queues[i].jobs.len();
                    match scalers[i].decide(depth, self.pools[i].replicas()) {
                        scale::ScaleDecision::Up => {
                            self.pools[i].add_replica();
                            st.queues[i].in_flight.push(None);
                            scale_ups += 1;
                        }
                        scale::ScaleDecision::Down => {
                            let victim = self.pools[i].replicas() - 1;
                            if victim > 0
                                && st.queues[i].in_flight[victim].is_none()
                                && self.pools[i].remove_replica()
                            {
                                st.queues[i].in_flight.pop();
                                scale_downs += 1;
                            }
                        }
                        scale::ScaleDecision::Hold => {}
                    }
                }
            }

            for i in 0..n_levels {
                loop {
                    let Some(r) =
                        st.queues[i].free_replica(&self.pools[i].replica_jobs)
                    else {
                        break;
                    };
                    let jobs = if !st.stages[i].is_empty() {
                        st.stages[i].take(self.serve_cfg.batch_max)
                    } else if st.queues[i].due(
                        self.serve_cfg.batch_max,
                        self.serve_cfg.deadline,
                        !inputs_open || self.barrier.paused(),
                    ) {
                        st.queues[i].take(self.serve_cfg.batch_max)
                    } else {
                        break;
                    };
                    // Stage-dispatched batches park in the same
                    // `in_flight` slots as batcher ones, so
                    // supervision requeue and quiescence see them.
                    let ok = self.pools[i].send_infer(r, jobs.clone());
                    st.queues[i].in_flight[r] = Some(jobs);
                    if !ok {
                        // Worker gone: respawn now; the batch we just
                        // parked in `in_flight` is requeued inside.
                        self.respawn(i, r, &mut st.queues)?;
                    }
                }
            }

            // 3. handle one worker reply (with a small timeout so the
            //    loop keeps admitting/flushing/supervising).
            match self.reply_rx.recv_timeout(Duration::from_micros(200)) {
                Ok(reply) => self.on_reply(reply, &mut st, &tx),
                Err(crate::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(crate::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    // Unreachable: every pool holds a reply_tx clone
                    // precisely so respawns can re-wire workers.
                    return Err(Error::Worker("reply channel closed".into()));
                }
            }

            // 4. barrier reached quiescence → write the checkpoint and
            //    re-open admission. A pool member dying between the
            //    supervision sweep and the export must not abort the
            //    run: leave the barrier armed — the next iteration's
            //    supervision respawns the worker and the barrier
            //    retries (admission stays paused meanwhile). An
            //    authority that is *alive but slow* must not hold the
            //    barrier either (the pre-fix stall): the attempt is
            //    aborted, admission resumes, and the barrier re-arms
            //    only after another `ckpt_every` annotations.
            if self.barrier.paused() && st.idle() {
                // `write_ckpt` records the outcome into the barrier:
                // Written and TimedOut both disarm (TimedOut resets
                // the cadence and counts an abort); AuthorityDead
                // leaves the barrier armed so the next iteration's
                // supervision respawns the worker and retries.
                match self.write_ckpt(&st, self.serve_cfg.export_timeout) {
                    Ok(_) => {}
                    Err(Error::Worker(_)) => {}
                    Err(e) => return Err(e),
                }
            }

            if !inputs_open && st.idle() {
                if !sync_flushed {
                    // Stream end: our outgoing annotation stream is
                    // complete (remote absorbs never annotate), so
                    // broadcast the below-interval leftovers and drop
                    // our senders — peers' inboxes can then disconnect.
                    self.flush_sync();
                    sync_flushed = true;
                }
                // Keep absorbing peers' annotations until every peer
                // has flushed and hung up (no peer: exits immediately).
                if self.sync_in.is_none() {
                    break;
                }
            }
        }

        // Graceful-shutdown checkpoint: the drain above left the
        // router quiescent, so this captures an exact resume point. A
        // worker crash racing shutdown gets one supervised respawn and
        // retry — it must not cost the final checkpoint (the respawn
        // warm-starts from the latest publication, the usual warm-
        // respawn staleness bound).
        if self.ckpt_sink.is_some() {
            // The shutdown checkpoint is mandatory and the stream is
            // already drained — there is no admission left to stall —
            // so it uses a generous fixed export bound rather than
            // `export_timeout` (which exists to bound how long a
            // *cadence* barrier may pause admission).
            let patient = Duration::from_secs(60);
            let wrote = match self.write_ckpt(&st, patient) {
                Ok(w) => w,
                Err(e) => {
                    if !matches!(e, Error::Worker(_)) {
                        return Err(e);
                    }
                    for i in 0..n_levels {
                        for r in 0..self.pools[i].replicas() {
                            if self.pools[i].workers[r].handle.is_finished() {
                                self.respawn(i, r, &mut st.queues)?;
                            }
                        }
                    }
                    self.write_ckpt(&st, patient)?
                }
            };
            if !wrote {
                return Err(Error::Ckpt(
                    "graceful-shutdown checkpoint export timed out".into(),
                ));
            }
        }

        // shutdown pools
        for p in &mut self.pools {
            p.shutdown();
        }
        let wall = t_start.elapsed().as_secs_f64();
        Ok(ServeReport {
            served: st.served,
            shed: st.shed,
            spec_hits: st.spec_hits,
            spec_wasted: st.spec_wasted,
            queue_depth: st.queue_depth.clone(),
            latency_direct_ms: st.lat_direct,
            latency_deferred_ms: st.lat_deferred,
            // This run's own rate: exclude the restored base, else a
            // resumed tail reports the whole stream over its short wall.
            throughput: (st.served - self.base.served) as f64 / wall.max(1e-9),
            wall_secs: wall,
            latency_ms: st.lat,
            handled: st.handled,
            accuracy: if st.served == 0 {
                0.0
            } else {
                st.correct as f64 / st.served as f64
            },
            llm_calls: st.llm_calls,
            restarts: self.pools.iter().map(|p| p.restarts).collect(),
            restart_cap: self.serve_cfg.max_restarts,
            warm_respawns: self.pools.iter().map(|p| p.warm_respawns).collect(),
            snapshots: self.pools.iter().map(|p| p.published()).collect(),
            snapshot_lag: self.pools.iter().map(|p| p.snapshot_lag()).collect(),
            replica_jobs: self.pools.iter().map(|p| p.replica_jobs.clone()).collect(),
            peak_pending: st.peak_pending,
            resumed: self.resumed,
            ckpts: self.barrier.writes(),
            ckpt_aborts: self.barrier.aborts(),
            final_betas: self.betas.clone(),
            train_batches: self
                .pools
                .iter()
                .map(|p| p.stats.train_chunks.load(Ordering::Relaxed))
                .collect(),
            calib_batches: self
                .pools
                .iter()
                .map(|p| p.stats.calib_chunks.load(Ordering::Relaxed))
                .collect(),
            infer_ns: self
                .pools
                .iter()
                .map(|p| p.stats.infer_ns.load(Ordering::Relaxed))
                .collect(),
            scale_ups,
            scale_downs,
        })
    }

    /// Admission: shed when the (possibly shard-shared) budget is
    /// full, otherwise run the cascade's level-0 DAgger gate and
    /// enqueue (or jump straight to the expert).
    fn admit(&mut self, req: Request, st: &mut RunState, tx: &Sender<Response>) {
        st.cursor = st.cursor.max(req.id + 1);
        if !self.admission.try_admit() {
            st.shed += 1;
            let _ = tx.send(Response {
                id: req.id,
                pred: 0,
                handled_by: self.cfg.levels.len() + 1,
                latency: Duration::ZERO,
                truth: req.truth,
                shed: true,
            });
            return;
        }
        st.admitted += 1;
        if let Some(c) = self.chaos {
            if st.admitted == c.after_requests {
                // Best-effort: the worker may already be dead.
                self.pools[c.kill_level].crash(c.kill_replica);
            }
        }
        let f = Arc::new(self.pipeline.featurize(&req.text));
        st.pending.insert(
            req.id,
            Pending {
                f: f.clone(),
                truth: req.truth,
                sample: req.sample,
                t0: Instant::now(),
                seen: vec![None; self.cfg.levels.len()],
                betas_at_admit: self.betas.clone(),
                spec_level: None,
                spec_result: None,
                spec_keep: false,
            },
        );
        st.peak_pending = st.peak_pending.max(st.pending.len());
        // DAgger jump straight to the expert? Level 0's own β gates the
        // walk's entry; each level's β decays with its *own* factor —
        // exactly one decay step per admitted request, matching
        // `Cascade::process` (one per processed sample).
        let jump = self.betas[0] > 0.0 && self.rng.coin(self.betas[0]);
        for (b, lc) in self.betas.iter_mut().zip(self.cfg.levels.iter()) {
            *b *= lc.beta_decay;
        }
        if jump {
            self.to_expert(req.id, st, tx);
        } else {
            // Admission always rides the level-0 batcher: arrival
            // batching is the point of the deadline there — the
            // pipelined stage path exists for *deferrals*.
            st.queues[0].push(Job {
                req_id: req.id,
                probe: false,
                spec: false,
                f,
                enq: Instant::now(),
            });
        }
    }

    /// Allocate a fresh probe-bookkeeping id (`probe_truth` key).
    fn next_probe_id(&mut self) -> u64 {
        self.probe_seq += 1;
        self.probe_seq
    }

    /// Process one worker reply batch: exits, deferrals (with per-level
    /// DAgger gates), speculative results, and calibration-probe
    /// completions.
    fn on_reply(&mut self, reply: WorkerReply, st: &mut RunState, tx: &Sender<Response>) {
        let lvl = reply.level;
        if reply.epoch != self.pools[lvl].workers[reply.replica].epoch {
            // A reply from a worker generation the supervisor already
            // replaced — its jobs were requeued; whichever copy answers
            // first wins, the other is dropped here or at the pending
            // lookup below.
            return;
        }
        st.queues[lvl].in_flight[reply.replica] = None;
        for (req_id, is_probe, is_spec, probs, score) in reply.results {
            // Calibration probe for an already-answered (or remote)
            // annotation? Probe jobs are tagged explicitly — client
            // request ids and probe ids live in overlapping u64 spaces.
            if is_probe {
                if let Some(w) = st.probe_truth.get_mut(&req_id) {
                    let y_star = w.y_star;
                    w.left -= 1;
                    if w.left == 0 {
                        st.probe_truth.remove(&req_id);
                    }
                    self.push_calib(lvl, probs, y_star);
                }
                continue;
            }
            if is_spec {
                // A speculative result. Consume it as the real level
                // result only when the real gate already deferred here
                // (`spec_keep`); park it when the gate is still out;
                // drop it when the speculation was cancelled (the
                // request exited, jumped, or a *new* request reuses
                // the id — a fresh `Pending` starts with
                // `spec_level: None`, so a stale copy can never leak
                // into it).
                let Some(state) = st.pending.get_mut(&req_id) else { continue };
                if state.spec_level != Some(lvl) {
                    continue;
                }
                if state.spec_keep {
                    state.spec_level = None;
                    state.spec_keep = false;
                    self.gate_result(req_id, lvl, probs, score, st, tx);
                } else {
                    state.spec_result = Some((probs, score));
                }
                continue;
            }
            if st.pending.contains_key(&req_id) {
                self.gate_result(req_id, lvl, probs, score, st, tx);
            }
        }
    }

    /// Run the deferral gate on one level result for a pending request:
    /// exit, defer (with the per-level DAgger gate), or expert hop —
    /// plus the speculation bookkeeping around the decision. Recurses
    /// at most once per remaining level when a parked speculative
    /// result is consumed.
    fn gate_result(
        &mut self,
        req_id: u64,
        lvl: usize,
        probs: Vec<f32>,
        score: f32,
        st: &mut RunState,
        tx: &Sender<Response>,
    ) {
        let n_levels = self.cfg.levels.len();
        {
            let Some(state) = st.pending.get_mut(&req_id) else { return };
            state.seen[lvl] = Some((probs.clone(), score));
        }
        let tau = self.cfg.levels[lvl].calibration * self.threshold_scale;
        let defer = (score as f64) > tau;
        if !defer {
            // exit here — any outstanding speculation was wasted
            self.cancel_spec(req_id, st);
            let pred = argmax(&probs);
            // lint: allow(unwrap) — key existence was just proven
            // by the `get_mut` above; a miss is a bug.
            let state = st.pending.remove(&req_id).expect("state");
            self.admission.release();
            let ms = state.t0.elapsed().as_secs_f64() * 1e3;
            st.lat.push(ms);
            if lvl == 0 {
                st.lat_direct.push(ms);
            } else {
                st.lat_deferred.push(ms);
            }
            st.handled[lvl] += 1;
            if pred == state.truth {
                st.correct += 1;
            }
            st.served += 1;
            let _ = tx.send(Response {
                id: req_id,
                pred,
                handled_by: lvl,
                latency: state.t0.elapsed(),
                truth: state.truth,
                shed: false,
            });
        } else if lvl + 1 < n_levels {
            // Cascade parity: the next level's own β is consulted
            // before its model runs — at the value snapshotted at
            // this request's admission, so queueing delay never
            // skews the jump probability relative to the cascade.
            let next = lvl + 1;
            let (b_next, spec_next) = {
                // lint: allow(unwrap) — key existence was just proven
                // by the `get_mut` above; a miss is a bug.
                let state = st.pending.get(&req_id).expect("state");
                (state.betas_at_admit[next], state.spec_level == Some(next))
            };
            let jump = b_next > 0.0 && self.rng.coin(b_next);
            if jump {
                self.to_expert(req_id, st, tx);
            } else if spec_next {
                // The speculation paid off: the gate really deferred
                // into the speculated level. Consume a parked result
                // right now (recursing into its gate), or mark the
                // in-flight copy's reply as the real one.
                st.spec_hits += 1;
                let parked = {
                    // lint: allow(unwrap) — existence proven above.
                    let state = st.pending.get_mut(&req_id).expect("state");
                    match state.spec_result.take() {
                        Some(r) => {
                            state.spec_level = None;
                            Some(r)
                        }
                        None => {
                            state.spec_keep = true;
                            None
                        }
                    }
                };
                if let Some((p, s)) = parked {
                    self.gate_result(req_id, next, p, s, st, tx);
                }
            } else {
                // lint: allow(unwrap) — existence proven above.
                let f = st.pending.get(&req_id).expect("state").f.clone();
                self.dispatch_deferred(
                    next,
                    Job { req_id, probe: false, spec: false, f, enq: Instant::now() },
                    st,
                );
                self.maybe_speculate(req_id, score, next, st);
            }
        } else {
            self.to_expert(req_id, st, tx);
        }
    }

    /// Route a deferred job: the stage queue when pipelining (dispatch
    /// the moment a replica frees — no deadline wait), falling back to
    /// the regular batcher when pipelining is off or the stage queue
    /// is full (backpressure without loss).
    fn dispatch_deferred(&mut self, lvl: usize, job: Job, st: &mut RunState) {
        if self.serve_cfg.pipeline {
            match st.stages[lvl].push(job) {
                None => return,
                Some(back) => st.queues[lvl].push(back),
            }
        } else {
            st.queues[lvl].push(job);
        }
    }

    /// Speculative dispatch (inference-only): the gate at `next - 1`
    /// just deferred into `next` on a score above
    /// [`ServeConfig::spec_threshold`] — a strong signal the *next*
    /// gate will defer too — so level `next + 1` starts now instead of
    /// after `next`'s round-trip. Never targets the expert (an expert
    /// hop annotates and trains — gates alone may trigger that), draws
    /// no RNG, and a full stage queue simply drops the idea: the
    /// speculation was optional work.
    fn maybe_speculate(&mut self, req_id: u64, score: f32, next: usize, st: &mut RunState) {
        let target = next + 1;
        if target >= self.cfg.levels.len()
            || !((score as f64) > self.serve_cfg.spec_threshold)
        {
            return;
        }
        let Some(state) = st.pending.get_mut(&req_id) else { return };
        debug_assert!(state.spec_level.is_none(), "one speculation per walk step");
        let job = Job {
            req_id,
            probe: false,
            spec: true,
            f: state.f.clone(),
            enq: Instant::now(),
        };
        let accepted = if self.serve_cfg.pipeline {
            st.stages[target].push(job).is_none()
        } else {
            st.queues[target].push(job);
            true
        };
        if accepted {
            // lint: allow(unwrap) — `get_mut` above proved existence.
            let state = st.pending.get_mut(&req_id).expect("state");
            state.spec_level = Some(target);
            state.spec_result = None;
            state.spec_keep = false;
        }
    }

    /// Discard an outstanding speculative copy of `req_id` (the real
    /// gate kept, jumped to the expert, or exhausted the cascade): a
    /// still-queued copy is removed so it never reaches a worker; an
    /// in-flight copy finishes and its reply is dropped — by the
    /// pending-map miss once the request exits, or by the
    /// `spec_level` guard in [`Server::on_reply`].
    fn cancel_spec(&mut self, req_id: u64, st: &mut RunState) {
        let Some(state) = st.pending.get_mut(&req_id) else { return };
        let Some(lvl) = state.spec_level.take() else { return };
        state.spec_result = None;
        state.spec_keep = false;
        st.spec_wasted += 1;
        st.stages[lvl].remove_spec(req_id);
        st.queues[lvl].jobs.retain(|j| !(j.spec && j.req_id == req_id));
    }

    /// Push one calibration example and run the shared replay-training
    /// cadence (`CALIB_REPLAY` × 8 at `mlp_lr × MLP_LR_SCALE`) —
    /// mirrors `Cascade::train_calibrator`.
    fn push_calib(&mut self, i: usize, probs: Vec<f32>, y_star: usize) {
        let z = if argmax(&probs) != y_star { 1.0 } else { 0.0 };
        self.calib_caches[i].push((probs, z));
        self.calib_pendings[i] += 1;
        if self.calib_pendings[i] >= 8 && self.calib_caches[i].len() >= 8 {
            let items = self.calib_caches[i].to_vec();
            let mut batch = Vec::with_capacity(CALIB_REPLAY * 8);
            for _ in 0..CALIB_REPLAY {
                for j in self.rng.sample_indices(items.len(), 8) {
                    batch.push(items[j].clone());
                }
            }
            self.pools[i]
                .send_train_calib(batch, self.cfg.levels[i].mlp_lr * MLP_LR_SCALE);
            self.calib_pendings[i] = 0;
        }
    }

    /// Replace a dead pool worker: fresh thread from the same spec,
    /// bumped epoch (stale replies get dropped), warm-started from the
    /// latest published snapshot, in-flight batch requeued at the front
    /// of the level queue.
    fn respawn(&mut self, i: usize, r: usize, queues: &mut [LevelQueue]) -> Result<()> {
        self.pools[i].respawn(r, self.serve_cfg.max_restarts)?;
        if let Some(jobs) = queues[i].in_flight[r].take() {
            queues[i].requeue_front(jobs);
        }
        Ok(())
    }

    /// End-of-stream sync flush: broadcast annotations still staged
    /// below the `sync_interval` threshold (they used to be silently
    /// dropped — the fix for the "annotations near stream end are
    /// lost" gap), then drop our peer senders so their inboxes can
    /// disconnect. Called exactly once, at the first locally-idle
    /// moment after the input stream closes; from then on this shard
    /// can only *absorb* (remote absorbs never produce annotations),
    /// so its outgoing stream really is complete.
    fn flush_sync(&mut self) {
        if !self.sync_out.is_empty() && !self.sync_staged.is_empty() {
            let staged = std::mem::take(&mut self.sync_staged);
            for peer in &self.sync_out {
                let _ = peer.send(SyncBatch(staged.clone()));
            }
        }
        self.sync_out.clear();
    }

    /// Capture the full learner state at a quiescent point and persist
    /// it through the sink (atomic write + manifest commit). `Ok(false)`
    /// means the attempt was aborted because a live authority did not
    /// export within `timeout` — nothing was written. Every outcome is
    /// recorded into the [`CkptBarrier`], which owns the disarm/retry
    /// decision: `Written` and `TimedOut` disarm, a dead authority
    /// (`Err(Error::Worker)`) leaves the barrier armed for a
    /// respawn-and-retry.
    fn write_ckpt(&mut self, st: &RunState, timeout: Duration) -> Result<bool> {
        let Some(sink) = self.ckpt_sink.clone() else {
            return Ok(true);
        };
        debug_assert!(st.idle(), "checkpoints must capture a quiescent router");
        let state = match self.export_state(st, timeout) {
            Ok(Some(state)) => state,
            Ok(None) => {
                self.barrier.record(ExportOutcome::TimedOut);
                return Ok(false);
            }
            Err(e) => {
                if matches!(e, Error::Worker(_)) {
                    self.barrier.record(ExportOutcome::AuthorityDead);
                }
                return Err(e);
            }
        };
        sink.deposit(self.shard_idx, &state)?;
        self.barrier.record(ExportOutcome::Written);
        Ok(true)
    }

    /// Assemble the durable [`ShardState`]: live authority weights
    /// (synchronous pool export), learner-cadence counters, replay
    /// caches, RNG, β, the sync stage, and cumulative serve counters.
    /// `Ok(None)` when any level authority is alive but failed to
    /// export within `timeout` (see [`LevelPool::export`]).
    fn export_state(&self, st: &RunState, timeout: Duration) -> Result<Option<ShardState>> {
        let mut levels = Vec::with_capacity(self.pools.len());
        for (i, pool) in self.pools.iter().enumerate() {
            let Some((model, calib)) = pool.export(timeout)? else {
                return Ok(None);
            };
            levels.push(LevelState {
                model,
                calib,
                train_chunks: pool.stats.train_chunks.load(Ordering::Relaxed),
                calib_chunks: pool.stats.calib_chunks.load(Ordering::Relaxed),
                train_sends: pool.train_sends(),
                pending: self.pendings[i],
                calib_pending: self.calib_pendings[i],
                cache: self.caches[i].to_vec(),
                calib_cache: self.calib_caches[i].to_vec(),
            });
        }
        let (rng_s, rng_cached) = self.rng.state();
        Ok(Some(ShardState {
            shard: self.shard_idx,
            cursor: st.cursor,
            rng_s,
            rng_cached,
            betas: self.betas.clone(),
            threshold_scale: self.threshold_scale,
            probe_seq: self.probe_seq,
            sync_staged: self.sync_staged.clone(),
            served: st.served,
            shed: st.shed,
            correct: st.correct,
            llm_calls: st.llm_calls,
            handled: st.handled.clone(),
            levels,
        }))
    }

    /// Drain annotations replicated from peer shards and absorb them
    /// into the learner state (cross-shard convergence).
    fn drain_sync(&mut self, st: &mut RunState) {
        let mut remote: Vec<(Arc<Featurized>, usize)> = Vec::new();
        let mut disconnected = false;
        if let Some(rx) = &self.sync_in {
            loop {
                match rx.try_recv() {
                    Ok(SyncBatch(items)) => remote.extend(items),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }
        if disconnected {
            // Peers shut down first (stream end); no more syncs.
            self.sync_in = None;
        }
        for (f, y_star) in remote {
            self.absorb_remote(f, y_star, st);
        }
    }

    /// Absorb one peer-shard annotation: replay caches + training
    /// cadence + calibration probes, exactly like a local expert
    /// annotation — but with no response, no latency/accuracy
    /// accounting, no β side effects, and no expert-call charge (the
    /// origin shard already paid for the call).
    fn absorb_remote(&mut self, f: Arc<Featurized>, y_star: usize, st: &mut RunState) {
        let n_levels = self.cfg.levels.len();
        let probe_id = self.next_probe_id();
        let mut probes = 0usize;
        for i in 0..n_levels {
            self.caches[i].push((f.clone(), y_star));
            self.pendings[i] += 1;
            // Every level is "walk-skipped" for a remote annotation:
            // its calibration example rides the level queue as a probe.
            st.queues[i].push(Job {
                req_id: probe_id,
                probe: true,
                spec: false,
                f: f.clone(),
                enq: Instant::now(),
            });
            probes += 1;
            self.maybe_train(i);
        }
        st.probe_truth.insert(probe_id, ProbeWait { y_star, left: probes });
    }

    /// Fire the level's model-training trigger when its cadence is due
    /// (shared by local annotations and cross-shard absorbs).
    fn maybe_train(&mut self, i: usize) {
        let bs = self.cfg.levels[i].batch_size;
        if self.pendings[i] >= bs && self.caches[i].len() >= bs {
            let items = self.caches[i].to_vec();
            let picks = replay_picks(&mut self.rng, items.len(), bs);
            let batch: Vec<(Arc<Featurized>, usize)> =
                picks.iter().map(|&j| items[j].clone()).collect();
            self.pools[i].send_train(batch, self.cfg.levels[i].model_lr);
            self.pendings[i] = 0;
        }
    }

    /// Expert annotation + the online-learning cadence (mirrors
    /// `Cascade::absorb_annotation`, including evaluating walk-skipped
    /// levels for calibration — async, via probe jobs). An expert
    /// outage routes to [`Server::expert_outage_fallback`] instead:
    /// no fabricated label, no training, no expert-call accounting.
    fn to_expert(&mut self, req_id: u64, st: &mut RunState, tx: &Sender<Response>) {
        // An outstanding speculative copy is moot once the walk leaves
        // the cascade — discard it (counts `spec_wasted`; no-op when
        // nothing was speculated).
        self.cancel_spec(req_id, st);
        let annotation = match st.pending.get(&req_id) {
            Some(state) => self.expert.annotate(&state.sample, self.classes),
            None => return,
        };
        let Some(y_star) = annotation else {
            self.expert_outage_fallback(req_id, st, tx);
            return;
        };
        // lint: allow(unwrap) — key existence was just proven by the
        // `get` above and nothing ran in between; a miss is a bug.
        let state = st.pending.remove(&req_id).expect("pending state");
        self.admission.release();
        let n_levels = self.cfg.levels.len();
        st.llm_calls += 1;
        self.barrier.note_annotation();
        // Cross-shard sync: stage the annotation for broadcast.
        if !self.sync_out.is_empty() && self.serve_cfg.shard.sync_interval > 0 {
            self.sync_staged.push((state.f.clone(), y_star));
            if self.sync_staged.len() >= self.serve_cfg.shard.sync_interval {
                let staged = std::mem::take(&mut self.sync_staged);
                for peer in &self.sync_out {
                    // A peer that already drained and exited is fine.
                    let _ = peer.send(SyncBatch(staged.clone()));
                }
            }
        }
        let probe_id = self.next_probe_id();
        let mut probes = 0usize;
        for i in 0..n_levels {
            self.caches[i].push((state.f.clone(), y_star));
            self.pendings[i] += 1;
            match &state.seen[i] {
                Some((probs, _)) => self.push_calib(i, probs.clone(), y_star),
                None => {
                    // Cascade parity (Eq. 5): levels the walk skipped
                    // are evaluated so every calibrator receives its
                    // (m_i(x), z_i) example. In the serving topology
                    // that evaluation rides the level's batch queue.
                    st.queues[i].push(Job {
                        req_id: probe_id,
                        probe: true,
                        spec: false,
                        f: state.f.clone(),
                        enq: Instant::now(),
                    });
                    probes += 1;
                }
            }
            self.maybe_train(i);
        }
        if probes > 0 {
            st.probe_truth.insert(probe_id, ProbeWait { y_star, left: probes });
        }
        let ms = state.t0.elapsed().as_secs_f64() * 1e3;
        st.lat.push(ms);
        st.lat_deferred.push(ms);
        st.handled[n_levels] += 1;
        if y_star == state.truth {
            st.correct += 1;
        }
        st.served += 1;
        let _ = tx.send(Response {
            id: req_id,
            pred: y_star,
            handled_by: n_levels,
            latency: state.t0.elapsed(),
            truth: state.truth,
            shed: false,
        });
    }

    /// Expert outage (failure injection / upstream outage): answer
    /// without an annotation, mirroring `Cascade::fallback_pred` — a
    /// confidence-weighted mixture over the level predictions gathered
    /// on the walk, no training, no expert-call accounting. A request
    /// with no predictions yet (admission jump) re-enters the walk at
    /// level 0 instead, so it accumulates predictions to answer from.
    fn expert_outage_fallback(
        &mut self,
        req_id: u64,
        st: &mut RunState,
        tx: &Sender<Response>,
    ) {
        let Some(state) = st.pending.get(&req_id) else { return };
        if state.seen.iter().all(|s| s.is_none()) {
            let f = state.f.clone();
            st.queues[0].push(Job {
                req_id,
                probe: false,
                spec: false,
                f,
                enq: Instant::now(),
            });
            return;
        }
        // lint: allow(unwrap) — key existence was just proven by the
        // `get` above; a miss is a bug.
        let state = st.pending.remove(&req_id).expect("pending state");
        self.admission.release();
        let mut mix = vec![0.0f32; self.classes];
        for (probs, score) in state.seen.iter().flatten() {
            let w = (1.0 - *score).max(0.05);
            for (m, &p) in mix.iter_mut().zip(probs) {
                *m += w * p;
            }
        }
        let pred = argmax(&mix);
        // The deepest level answers (cascade-parity attribution).
        let lvl = self.cfg.levels.len() - 1;
        let ms = state.t0.elapsed().as_secs_f64() * 1e3;
        st.lat.push(ms);
        if lvl == 0 {
            st.lat_direct.push(ms);
        } else {
            st.lat_deferred.push(ms);
        }
        st.handled[lvl] += 1;
        if pred == state.truth {
            st.correct += 1;
        }
        st.served += 1;
        let _ = tx.send(Response {
            id: req_id,
            pred,
            handled_by: lvl,
            latency: state.t0.elapsed(),
            truth: state.truth,
            shed: false,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BenchmarkId, ExpertId};
    use crate::data::Benchmark;
    use crate::sim::ExpertProfile;

    #[test]
    fn serves_a_small_stream_end_to_end() {
        let n = 400;
        let b = Benchmark::build_sized(BenchmarkId::Imdb, 31, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let expert = Expert::new(
            ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
            b.strata_fractions(),
            mean_len,
            31,
        );
        let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let server =
            Server::new(cfg, 2, expert, ServeConfig::default(), "artifacts").unwrap();
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let submit = crate::sync::thread::spawn(move || {
            for (i, s) in b.samples.iter().enumerate() {
                req_tx
                    .send(Request {
                        id: i as u64,
                        text: s.text.clone(),
                        truth: s.label,
                        sample: s.clone(),
                    })
                    .unwrap();
            }
            // req_tx drops -> server drains and stops
        });
        let report = server.serve(req_rx, resp_tx).unwrap();
        submit.join().unwrap();
        let responses: Vec<Response> = resp_rx.iter().collect();
        assert_eq!(report.served + report.shed, n);
        assert_eq!(responses.len(), n);
        // every request answered exactly once
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        assert!(report.accuracy > 0.5, "acc {}", report.accuracy);
        assert!(report.throughput > 10.0, "thr {}", report.throughput);
        assert_eq!(report.handled.iter().sum::<usize>(), report.served);
        // a quiet run: no restarts, bounded pending, betas decayed
        assert_eq!(report.restarts, vec![0, 0]);
        assert_eq!(report.warm_respawns, vec![0, 0]);
        assert!(!report.resumed, "fresh server must not claim a restore");
        assert_eq!(report.ckpts, 0, "no sink attached → no checkpoints");
        assert_eq!(report.restart_cap, ServeConfig::default().max_restarts);
        assert!(report.peak_pending <= ServeConfig::default().max_pending);
        assert_eq!(report.final_betas.len(), 2);
        assert!(report.final_betas.iter().all(|&b| b < 1.0));
        // online learning actually reached the workers
        assert!(report.train_batches.iter().any(|&t| t > 0), "{:?}", report.train_batches);
        assert!(report.calib_batches.iter().any(|&t| t > 0), "{:?}", report.calib_batches);
        // the authority published snapshots on the default cadence, and
        // all inference ran on the single pool member
        assert!(report.snapshots.iter().any(|&s| s > 0), "{:?}", report.snapshots);
        assert_eq!(report.replica_jobs.len(), 2);
        for lvl in &report.replica_jobs {
            assert_eq!(lvl.len(), 1, "default topology is one member per pool");
        }
    }

    #[test]
    fn autoscaled_run_stays_inside_bounds_and_serves_exactly_once() {
        let n = 300;
        let b = Benchmark::build_sized(BenchmarkId::Imdb, 77, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let expert = Expert::new(
            ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
            b.strata_fractions(),
            mean_len,
            77,
        );
        let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let serve_cfg = ServeConfig::builder()
            .autoscale(true)
            .replicas_min(1)
            .replicas_max(3)
            .build()
            .unwrap();
        let server = Server::new(cfg, 2, expert, serve_cfg, "artifacts").unwrap();
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let submit = crate::sync::thread::spawn(move || {
            for (i, s) in b.samples.iter().enumerate() {
                req_tx
                    .send(Request {
                        id: i as u64,
                        text: s.text.clone(),
                        truth: s.label,
                        sample: s.clone(),
                    })
                    .unwrap();
            }
        });
        let report = server.serve(req_rx, resp_tx).unwrap();
        submit.join().unwrap();
        let responses: Vec<Response> = resp_rx.iter().collect();
        // Elasticity must never cost correctness: exactly-once service.
        assert_eq!(report.served + report.shed, n);
        assert_eq!(responses.len(), n);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        // The final topology sits inside the configured bounds, and the
        // event counters are consistent with it (each level started at
        // one member).
        for lvl in &report.replica_jobs {
            assert!(
                (1..=3).contains(&lvl.len()),
                "replicas left the [min, max] bounds: {lvl:?}"
            );
        }
        let final_members: u64 =
            report.replica_jobs.iter().map(|l| l.len() as u64).sum();
        assert_eq!(
            2 + report.scale_ups - report.scale_downs,
            final_members,
            "scale events must reconcile with the final replica counts"
        );
    }

    fn job(id: u64, enq: Instant) -> Job {
        Job {
            req_id: id,
            probe: false,
            spec: false,
            f: Arc::new(Pipeline::default().featurize("doc")),
            enq,
        }
    }

    #[test]
    fn partial_drain_keeps_true_queue_age() {
        // ISSUE satellite: after a partial drain the surviving jobs'
        // deadline must measure true queue age, not restart from the
        // drain instant. Large deadline + batch_max = 1 exercises the
        // partial-drain path explicitly.
        let old = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .expect("monotonic clock too young");
        let mut q = LevelQueue::new(1);
        q.push(job(1, old));
        q.push(job(2, old));
        let taken = q.take(1); // batch_max = 1 → partial drain
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].req_id, 1);
        // The survivor still reports its ORIGINAL enqueue instant...
        assert_eq!(q.oldest_enq(), Some(old));
        // ...so a deadline below its true age fires immediately,
        assert!(q.due(8, Duration::from_millis(10), false));
        // ...while a large deadline leaves only size/drain triggers.
        assert!(!q.due(8, Duration::from_secs(3600), false));
        assert!(q.due(1, Duration::from_secs(3600), false));
        assert!(q.due(8, Duration::from_secs(3600), true));
        // Requeue-on-death preserves order and timestamps.
        q.requeue_front(taken);
        assert_eq!(q.jobs.front().unwrap().req_id, 1);
        assert_eq!(q.oldest_enq(), Some(old));
    }

    #[test]
    fn free_replica_prefers_least_loaded() {
        let mut q = LevelQueue::new(3);
        assert_eq!(q.free_replica(&[5, 2, 9]), Some(1));
        q.in_flight[1] = Some(vec![]);
        assert_eq!(q.free_replica(&[5, 2, 9]), Some(0));
        q.in_flight[0] = Some(vec![]);
        q.in_flight[2] = Some(vec![]);
        assert_eq!(q.free_replica(&[5, 2, 9]), None);
    }

    #[test]
    fn rejects_degenerate_serve_config() {
        let b = Benchmark::build_sized(BenchmarkId::Imdb, 1, 4);
        let expert = Expert::new(
            ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
            b.strata_fractions(),
            100.0,
            1,
        );
        let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        // `Server::build` re-validates struct-literal configs that
        // bypassed `ServeConfig::builder` (whose own rejection matrix
        // is covered in `config::tests`).
        for bad in [
            ServeConfig { max_pending: 0, ..ServeConfig::default() },
            ServeConfig {
                shard: ShardConfig { replicas_per_level: 0, ..ShardConfig::default() },
                ..ServeConfig::default()
            },
            ServeConfig { stage_queue_depth: 0, ..ServeConfig::default() },
            ServeConfig { spec_threshold: 0.0, ..ServeConfig::default() },
            ServeConfig { spec_threshold: 2.0, ..ServeConfig::default() },
            ServeConfig { autoscale: true, replicas_min: 0, ..ServeConfig::default() },
            ServeConfig {
                autoscale: true,
                replicas_min: 4,
                replicas_max: 2,
                ..ServeConfig::default()
            },
            ServeConfig {
                autoscale: true,
                replicas_min: 2,
                replicas_max: 4,
                ..ServeConfig::default() // replicas_per_level 1 < min
            },
        ] {
            assert!(
                Server::new(cfg.clone(), 2, expert.clone(), bad, "artifacts").is_err(),
                "{bad:?} must be rejected"
            );
        }
        // The builder's happy path is accepted end-to-end.
        let good = ServeConfig::builder()
            .pipeline(true)
            .spec_threshold(0.5)
            .build()
            .unwrap();
        assert!(Server::new(cfg, 2, expert, good, "artifacts").is_ok());
    }
}
