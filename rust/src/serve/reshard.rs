//! N→M checkpoint resharding: migrate a durable manifest across shard
//! counts offline, so a deployment can change topology at a restore
//! boundary instead of being welded to the shard count it first ran at.
//!
//! `reshard(src, dst, M)` reads the newest manifest in `src` (written
//! at some shard count N, discovered from the manifest itself),
//! validates it strictly, and materializes an M-shard manifest in
//! `dst`. The merge rules (DESIGN.md §14):
//!
//! * **Learner state is authority-seeded.** Every new shard's
//!   per-level model/calibrator snapshots, DAgger β vector, RNG words,
//!   and training-cadence counters are taken from the *lowest* old
//!   shard id (shard 0) — the same worker-0-is-authority convention
//!   the replica pools use. Shard 0's learned trajectory therefore
//!   survives any reshard bit-for-bit, which is what keeps the
//!   Theorem 3.2 no-regret argument intact: the surviving policy is an
//!   actual prefix-trained policy, not an average of incomparable ones.
//! * **Replay content is re-hashed.** Replay-cache, calibration-cache,
//!   and staged-sync entries from *all* old shards are re-partitioned
//!   across the M new shards with the same Fibonacci hash
//!   ([`shard_of`]) the router uses for request ids, keyed on a stable
//!   content hash — deterministic, so resharding the same manifest
//!   twice yields byte-identical output.
//! * **Counters are conserved.** Cumulative serve counters (served,
//!   shed, correct, llm_calls, per-level handled) are summed onto new
//!   shard 0 and zeroed elsewhere, so topology changes never inflate
//!   or lose report totals.
//! * **The cursor is the min over old shards.** Each old shard
//!   checkpoints at its own quiescent instant; only the minimum is a
//!   global high-water mark. Requests between min and max are
//!   re-observed — the same at-least-once semantics a multi-shard
//!   resume already has.
//!
//! The output directory must not already contain a manifest: resharding
//! is a whole-topology rewrite, and depositing into a live checkpoint
//! directory would interleave two incompatible shard counts.

use std::path::Path;

use crate::error::{Error, Result};
use crate::models::Featurized;
use crate::sync::Arc;

use super::ckpt::{self, CkptSink, ResumeMode, ShardState};
use super::shard::shard_of;

/// What a completed reshard did — printed by `ocl reshard` and
/// asserted on by the elasticity tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReshardSummary {
    /// Shard count of the source manifest (N).
    pub from_shards: usize,
    /// Shard count written to the destination (M).
    pub to_shards: usize,
    /// Global resume cursor of the new manifest (min over old shards).
    pub cursor: u64,
    /// Total served count carried across (conserved onto new shard 0).
    pub served_total: usize,
    /// Replay-cache entries re-partitioned (summed over levels).
    pub replay_entries: usize,
    /// Calibration-cache entries re-partitioned (summed over levels).
    pub calib_entries: usize,
    /// Staged cross-shard sync annotations re-partitioned.
    pub sync_entries: usize,
}

impl ReshardSummary {
    /// One-line human/CI-greppable form.
    pub fn describe(&self) -> String {
        format!(
            "reshard {}→{}: cursor={} served_total={} replay={} calib={} sync={}",
            self.from_shards,
            self.to_shards,
            self.cursor,
            self.served_total,
            self.replay_entries,
            self.calib_entries,
            self.sync_entries
        )
    }
}

/// FNV-1a fold of a byte slice into `h`.
fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
}

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// Stable content key for an annotation `(query, label)` — hashes the
/// token ids (the canonical identity of a featurized query) plus the
/// label, so the same annotation lands on the same new shard no matter
/// which old shard's cache it came from.
fn annotation_key(f: &Featurized, y: usize) -> u64 {
    let mut h = FNV_OFFSET;
    for &id in &f.ids {
        fnv(&mut h, &id.to_le_bytes());
    }
    fnv(&mut h, &(y as u64).to_le_bytes());
    h
}

/// Stable content key for a calibration example `(probs, z)`.
fn calib_key(probs: &[f32], z: f32) -> u64 {
    let mut h = FNV_OFFSET;
    for &p in probs {
        fnv(&mut h, &p.to_bits().to_le_bytes());
    }
    fnv(&mut h, &z.to_bits().to_le_bytes());
    h
}

/// Reshard the newest manifest in `src` (validated strictly at its
/// own recorded shard count N) into an M-shard manifest under `dst`.
/// `dst` is created if missing and must not already hold a manifest.
pub fn reshard(
    src: impl AsRef<Path>,
    dst: impl AsRef<Path>,
    to_shards: usize,
) -> Result<ReshardSummary> {
    let (src, dst) = (src.as_ref(), dst.as_ref());
    if to_shards == 0 {
        return Err(Error::Usage("reshard: target shard count must be ≥ 1".into()));
    }
    let from_shards = ckpt::latest_manifest_shards(src)?;
    if from_shards == 0 {
        return Err(Error::Ckpt("reshard: source manifest covers 0 shards".into()));
    }
    let states = ckpt::load_latest(src, ResumeMode::Strict, from_shards)?
        .ok_or_else(|| Error::Ckpt("reshard: no restorable checkpoint".into()))?;
    if ckpt::latest_manifest_shards(dst).is_ok() {
        return Err(Error::Ckpt(format!(
            "reshard: destination '{}' already holds a checkpoint manifest",
            dst.display()
        )));
    }

    let new_states = reshard_states(&states, to_shards);
    let summary = ReshardSummary {
        from_shards,
        to_shards,
        cursor: new_states[0].cursor,
        served_total: new_states.iter().map(|s| s.served).sum(),
        replay_entries: new_states
            .iter()
            .flat_map(|s| s.levels.iter())
            .map(|l| l.cache.len())
            .sum(),
        calib_entries: new_states
            .iter()
            .flat_map(|s| s.levels.iter())
            .map(|l| l.calib_cache.len())
            .sum(),
        sync_entries: new_states.iter().map(|s| s.sync_staged.len()).sum(),
    };

    // Deposit in shard order: the last deposit (once every shard has a
    // file) commits the manifest, so a crash mid-reshard leaves `dst`
    // manifest-less — restartable, never torn.
    let sink = CkptSink::create(dst, to_shards)?;
    for s in &new_states {
        sink.deposit(s.shard, s)?;
    }
    Ok(summary)
}

/// Pure in-memory core of [`reshard`]: merge N shard states into M.
/// Exposed for the property tests — no filesystem, fully deterministic.
pub fn reshard_states(states: &[ShardState], to_shards: usize) -> Vec<ShardState> {
    let authority = &states[0];
    let n_levels = authority.levels.len();
    let cursor = states.iter().map(|s| s.cursor).min().unwrap_or(0);

    let mut out: Vec<ShardState> = (0..to_shards)
        .map(|k| {
            let mut s = authority.clone();
            s.shard = k;
            s.cursor = cursor;
            // Counters conserve onto new shard 0 (summed below).
            s.served = 0;
            s.shed = 0;
            s.correct = 0;
            s.llm_calls = 0;
            s.handled = vec![0; authority.handled.len()];
            s.sync_staged = Vec::new();
            for l in &mut s.levels {
                l.cache = Vec::new();
                l.calib_cache = Vec::new();
            }
            s
        })
        .collect();

    for s in states {
        out[0].served += s.served;
        out[0].shed += s.shed;
        out[0].correct += s.correct;
        out[0].llm_calls += s.llm_calls;
        for (acc, h) in out[0].handled.iter_mut().zip(&s.handled) {
            *acc += h;
        }
    }

    // Re-partition replay content by stable content hash, walking old
    // shards (then entries) in order — deterministic placement *and*
    // deterministic order within each new shard's cache.
    for s in states {
        for (f, y) in &s.sync_staged {
            let k = shard_of(annotation_key(f, *y), to_shards);
            out[k].sync_staged.push((Arc::clone(f), *y));
        }
        for (i, l) in s.levels.iter().enumerate().take(n_levels) {
            for (f, y) in &l.cache {
                let k = shard_of(annotation_key(f, *y), to_shards);
                out[k].levels[i].cache.push((Arc::clone(f), *y));
            }
            for (p, z) in &l.calib_cache {
                let k = shard_of(calib_key(p, *z), to_shards);
                out[k].levels[i].calib_cache.push((p.clone(), *z));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::ckpt::LevelState;
    use super::*;

    fn state(shard: usize, cursor: u64, served: usize) -> ShardState {
        use crate::models::{Pipeline, Snapshot};
        let p = Pipeline::default();
        let snap = |kind: &str, n: usize| Snapshot {
            kind: kind.into(),
            classes: 2,
            data: (0..n).map(|i| i as f32 * 0.25).collect(),
        };
        let f = |t: &str| Arc::new(p.featurize(t));
        ShardState {
            shard,
            cursor,
            rng_s: [1 + shard as u64, 2, 3, 4],
            rng_cached: None,
            betas: vec![0.5 + shard as f64 * 0.1, 0.25],
            threshold_scale: 1.0,
            probe_seq: 3,
            sync_staged: vec![(f(&format!("kw0x{shard:03}")), shard % 2)],
            served,
            shed: shard,
            correct: served / 2,
            llm_calls: 5 + shard as u64,
            handled: vec![served / 2, served / 4, served / 4],
            levels: (0..2)
                .map(|i| LevelState {
                    model: snap(if i == 0 { "lr" } else { "tfm_base" }, 8),
                    calib: snap("mlp", 4),
                    train_chunks: 10 + shard as u64,
                    calib_chunks: 6,
                    train_sends: 2,
                    pending: 1,
                    calib_pending: 0,
                    cache: vec![
                        (f(&format!("kw1x{:03}", shard * 2 + i)), 0),
                        (f(&format!("kw2x{:03}", shard * 3 + i)), 1),
                    ],
                    calib_cache: vec![(vec![0.5 + shard as f32 * 0.1, 0.4], 1.0)],
                })
                .collect(),
        }
    }

    #[test]
    fn merge_conserves_counters_and_seeds_from_authority() {
        let old = vec![state(0, 40, 100), state(1, 37, 90)];
        for m in [1usize, 2, 3, 5] {
            let new = reshard_states(&old, m);
            assert_eq!(new.len(), m);
            // Authority-seeded learner state on every new shard.
            for (k, s) in new.iter().enumerate() {
                assert_eq!(s.shard, k);
                assert_eq!(s.cursor, 37, "cursor must be the min over old shards");
                assert_eq!(s.betas, old[0].betas);
                assert_eq!(s.rng_s, old[0].rng_s);
                for (l, ol) in s.levels.iter().zip(&old[0].levels) {
                    assert_eq!(l.model, ol.model);
                    assert_eq!(l.train_chunks, ol.train_chunks);
                }
            }
            // Conservation: totals survive any M.
            assert_eq!(new.iter().map(|s| s.served).sum::<usize>(), 190);
            assert_eq!(new.iter().map(|s| s.llm_calls).sum::<u64>(), 11);
            let handled: Vec<usize> = (0..3)
                .map(|i| new.iter().map(|s| s.handled[i]).sum())
                .collect();
            assert_eq!(handled, vec![95, 47, 47]);
            let replay: usize = new
                .iter()
                .flat_map(|s| s.levels.iter())
                .map(|l| l.cache.len())
                .sum();
            assert_eq!(replay, 8, "every replay entry must land exactly once");
            let sync: usize = new.iter().map(|s| s.sync_staged.len()).sum();
            assert_eq!(sync, 2);
            // Determinism: same input, same output.
            assert_eq!(reshard_states(&old, m), new);
        }
    }

    #[test]
    fn reshard_to_one_concatenates_everything_onto_shard_zero() {
        let old = vec![state(0, 40, 100), state(1, 37, 90)];
        let new = reshard_states(&old, 1);
        assert_eq!(new[0].served, 190);
        assert_eq!(new[0].levels[0].cache.len(), 4);
        assert_eq!(new[0].levels[0].calib_cache.len(), 2);
    }
}
