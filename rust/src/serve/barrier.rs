//! The checkpoint-barrier state machine, extracted from the serve
//! loop so it is a *model-checkable unit*: pure state, no clocks, no
//! channels, no I/O.
//!
//! Protocol (DESIGN.md §11): every [`crate::config::ServeConfig::ckpt_every`]
//! expert annotations the barrier **arms**. While armed, the router
//! pauses admission and cross-shard sync absorption so in-flight work
//! drains to a quiescent point; at quiescence it attempts a state
//! export and reports the outcome back here:
//!
//! - [`ExportOutcome::Written`] — the checkpoint is durable: disarm,
//!   reset the cadence, count a write.
//! - [`ExportOutcome::TimedOut`] — a level authority was *alive but
//!   slow* (the PR 6 liveness fix): abort the attempt, disarm, reset
//!   the cadence, count an abort. Liveness beats checkpoint freshness:
//!   admission must not stay paused behind a wedged export.
//! - [`ExportOutcome::AuthorityDead`] — a level authority's thread
//!   died: **stay armed**. The supervision sweep respawns the worker
//!   and the still-armed barrier retries; admission stays paused so
//!   the quiescent point is preserved across the respawn.
//!
//! The invariants (exhaustively checked over interleavings by
//! `tests/test_loom.rs` via [`crate::mc::models::BarrierSpec`], which
//! drives *this* type, not a re-implementation):
//! exports are only attempted at quiescence; at most one write per
//! arm; `Written`/`TimedOut` always re-open admission; a dead
//! authority never disarms; a `TimedOut` abort re-arms only after a
//! full fresh cadence. Barrier correctness is what makes a resumed
//! learner trajectory bit-identical to an uninterrupted one — the
//! serve-side precondition for the paper's Theorem 3.2 regret bound
//! (see DESIGN.md §11).

/// Outcome of one checkpoint export attempt, reported into
/// [`CkptBarrier::record`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExportOutcome {
    /// The quiescent state was captured and durably written.
    Written,
    /// A level authority was alive but did not export within the
    /// configured bound — the attempt is aborted, nothing was written.
    TimedOut,
    /// A level authority's thread was dead — respawn and retry while
    /// still armed.
    AuthorityDead,
}

/// Cadence + pause state of the quiescent checkpoint barrier (see the
/// module docs for the protocol).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CkptBarrier {
    /// Annotations between cadence checkpoints (0 disables arming;
    /// the graceful-shutdown checkpoint still records through here).
    every: usize,
    anns_since: usize,
    armed: bool,
    writes: u64,
    aborts: u64,
}

impl CkptBarrier {
    /// A disarmed barrier with an `every`-annotation cadence.
    pub fn new(every: usize) -> Self {
        CkptBarrier { every, anns_since: 0, armed: false, writes: 0, aborts: 0 }
    }

    /// Count one expert annotation toward the cadence.
    pub fn note_annotation(&mut self) {
        self.anns_since += 1;
    }

    /// Arm when the cadence is due. Returns whether the barrier is
    /// armed after the call (idempotent while armed).
    pub fn maybe_arm(&mut self) -> bool {
        if self.every > 0 && self.anns_since >= self.every {
            self.armed = true;
        }
        self.armed
    }

    /// While `true`, the router must pause admission and sync
    /// absorption and drain to quiescence.
    pub fn paused(&self) -> bool {
        self.armed
    }

    /// Record the outcome of an export attempt (see [`ExportOutcome`]
    /// for the disarm/retry policy each variant implies).
    pub fn record(&mut self, outcome: ExportOutcome) {
        match outcome {
            ExportOutcome::Written => {
                self.armed = false;
                self.anns_since = 0;
                self.writes += 1;
            }
            ExportOutcome::TimedOut => {
                self.armed = false;
                self.anns_since = 0;
                self.aborts += 1;
            }
            ExportOutcome::AuthorityDead => {}
        }
    }

    /// Durable checkpoints recorded (cadence + graceful shutdown).
    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Export attempts aborted on a live-but-slow authority.
    pub fn aborts(&self) -> u64 {
        self.aborts
    }

    /// Annotations since the last disarm (model/test introspection).
    pub fn anns_since(&self) -> usize {
        self.anns_since
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arms_on_cadence_and_resets_on_write() {
        let mut b = CkptBarrier::new(3);
        assert!(!b.maybe_arm());
        for _ in 0..3 {
            b.note_annotation();
        }
        assert!(b.maybe_arm());
        assert!(b.paused());
        b.record(ExportOutcome::Written);
        assert!(!b.paused());
        assert_eq!(b.writes(), 1);
        assert_eq!(b.anns_since(), 0);
        assert!(!b.maybe_arm(), "a write resets the cadence");
    }

    #[test]
    fn timeout_aborts_disarm_and_reset_cadence() {
        let mut b = CkptBarrier::new(2);
        b.note_annotation();
        b.note_annotation();
        assert!(b.maybe_arm());
        b.record(ExportOutcome::TimedOut);
        assert!(!b.paused(), "an abort must re-open admission");
        assert_eq!(b.aborts(), 1);
        assert_eq!(b.writes(), 0);
        assert!(!b.maybe_arm(), "an abort re-arms only after a fresh cadence");
        b.note_annotation();
        b.note_annotation();
        assert!(b.maybe_arm());
    }

    #[test]
    fn dead_authority_keeps_the_barrier_armed() {
        let mut b = CkptBarrier::new(1);
        b.note_annotation();
        assert!(b.maybe_arm());
        b.record(ExportOutcome::AuthorityDead);
        assert!(b.paused(), "respawn-and-retry happens under the same arm");
        b.record(ExportOutcome::Written);
        assert!(!b.paused());
        assert_eq!(b.writes(), 1);
    }

    #[test]
    fn zero_cadence_never_arms_but_still_records_shutdown_writes() {
        let mut b = CkptBarrier::new(0);
        for _ in 0..100 {
            b.note_annotation();
        }
        assert!(!b.maybe_arm());
        b.record(ExportOutcome::Written); // graceful-shutdown checkpoint
        assert_eq!(b.writes(), 1);
    }
}
