//! A real wire front for the serving stack: a zero-dependency,
//! length-prefixed binary protocol over `std::net` TCP.
//!
//! **Why sockets.** Every serve-layer guarantee this crate makes —
//! SLOs under open-loop load, exactly-once response accounting,
//! admission-control shedding, crash/resume bit-identity — was proven
//! over in-process `mpsc` channels, which silently exempt the system
//! from framing, partial reads, connection lifecycle, and process
//! death. This module is the same [`Request`]/[`Response`] contract
//! over an actual [`TcpListener`], so those guarantees are asserted
//! against a deployable surface (`tests/test_net.rs`, CI `net-smoke`
//! and `ckpt-smoke`).
//!
//! **Frame layout.** Every frame is a 6-byte header followed by a
//! compact-JSON payload:
//!
//! ```text
//! [version: u8][tag: u8][len: u32 BE][payload: `len` bytes of JSON]
//! ```
//!
//! The version byte is checked before anything else ([`WIRE_VERSION`];
//! a mismatch is a clean [`Error::Wire`], never a reinterpret), the
//! tag must name a known frame, and `len` is capped at [`MAX_FRAME`]
//! *from the header alone* — an attacker (or corrupt peer) cannot make
//! the receiver buffer an unbounded frame. Payloads reuse the crate's
//! `codec::json` substrate, whose shortest-round-trip f64 printing is
//! what makes `final_betas` comparisons across the wire bit-exact.
//!
//! **Topologies.** Three ways to stand the stack behind a socket:
//!
//! - [`serve`] — one process: a [`ShardFront`] (1..N in-process shards
//!   sharing one global [`super::AdmissionGate`]) behind an accept
//!   loop. `ocl serve --listen <addr>`.
//! - [`serve_shard`] — one process per shard: a single [`Server`]
//!   serving exactly one upstream (the front), with cross-shard
//!   annotation sync carried as [`Frame::Sync`] frames. `ocl serve
//!   --listen <addr> --shard-id <k>`.
//! - [`run_front`] — the thin front process: hash-dispatches client
//!   requests to shard processes ([`shard_of`]), relays responses
//!   back, and rebroadcasts each shard's sync frames to its peers.
//!   `ocl serve --front <addr>,<addr>,...`.
//!
//! In the multi-process topology the PR 4 checkpoint manifest is the
//! shared durable state: every shard process deposits into the same
//! directory ([`CkptSink`] refreshes peer deposits from disk before
//! committing a manifest), and [`build_shard_server`] restores from
//! the newest manifest exactly as the in-process front does. One
//! honest limitation: admission budgets are per-process there — a
//! single CAS gate cannot span processes without a coordination
//! service, so `max_pending` bounds each shard process, not the
//! deployment (the in-process [`serve`] path keeps the global bound).
//!
//! **Delivery semantics.** Within one connection, TCP gives the same
//! FIFO the in-process channels did, so per-shard sync ordering and
//! the responses-before-report ordering hold unchanged. Across a
//! crash, the contract is the checkpoint layer's: at-least-once — a
//! SIGKILLed server loses answers after its last manifest, the client
//! reconnects, reads the new [`Frame::Hello`] cursor, and resubmits
//! from there (`tests/test_net.rs` pins that the resumed trajectory is
//! bit-identical to an uninterrupted run).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::thread::{self, JoinHandle};
use crate::sync::{lock_unpoisoned, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codec::{self, Json};
use crate::config::CascadeConfig;
use crate::data::Sample;
use crate::error::{Error, Result};
use crate::models::Featurized;
use crate::sim::Expert;

use super::ckpt::{self, CkptOptions, CkptSink, ShardState};
use super::shard::{shard_of, ShardFront, ShardReport};
use super::{Request, Response, Server, ServeConfig, ServeReport, SyncBatch};

/// Wire-protocol version byte (first byte of every frame).
pub const WIRE_VERSION: u8 = 1;

/// Maximum payload length a receiver will buffer, enforced from the
/// frame header before any payload byte is read.
pub const MAX_FRAME: usize = 1 << 20;

/// One protocol frame. The numeric tags in the header are fixed by
/// [`Frame::tag`]; adding a frame kind means a new tag, changing a
/// payload means bumping [`WIRE_VERSION`].
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Server → client greeting: the stream position to (re)submit
    /// from. 0 for fresh servers; after a resume, the restored cursor.
    Hello {
        /// Resume cursor: every request id below it is already
        /// absorbed in durable state.
        cursor: u64,
    },
    /// Client → server: one document to classify.
    Request(Request),
    /// Server → client: the served answer (never a shed — sheds have
    /// their own tag so a client can count them without inspecting
    /// flags).
    Response(Response),
    /// Server → client: refused by admission control. Carries no
    /// latency (the refusal is immediate by construction).
    Shed {
        /// The refused request's id.
        id: u64,
        /// Echoed ground truth (client-side accounting parity with
        /// [`Response`]).
        truth: usize,
        /// `levels + 1`, the shed attribution slot.
        handled_by: usize,
    },
    /// Shard ↔ front: a batch of expert annotations to replicate to
    /// peer shards (the cross-process twin of [`SyncBatch`]).
    Sync {
        /// Originating shard (the front rebroadcasts to everyone else).
        shard: usize,
        /// `(featurized query, expert label)` pairs.
        items: Vec<(Featurized, usize)>,
    },
    /// Client → server: no more requests on this connection.
    Eos,
    /// Shard ↔ front: the sender's outgoing annotation stream is
    /// complete (the wire twin of dropping a `SyncBatch` sender).
    SyncEnd {
        /// Whose stream ended (informational on the return path).
        shard: usize,
    },
    /// Server → client: the final run report as JSON, sent after the
    /// last response so a client can assert on `final_betas`,
    /// `served`, `resumed`, ... without scraping stdout.
    Report(Json),
}

impl Frame {
    /// Header tag byte for this frame kind.
    pub fn tag(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::Request(_) => 2,
            Frame::Response(_) => 3,
            Frame::Shed { .. } => 4,
            Frame::Sync { .. } => 5,
            Frame::Eos => 6,
            Frame::SyncEnd { .. } => 7,
            Frame::Report(_) => 8,
        }
    }

    /// JSON payload for this frame. Request/response ids and latency
    /// nanos ride as `u64_hex` — f64 `Num` would corrupt ids above
    /// 2^53, and client-assigned ids are arbitrary u64s.
    fn payload(&self) -> Json {
        match self {
            Frame::Hello { cursor } => {
                Json::obj(vec![("cursor", Json::u64_hex(*cursor))])
            }
            Frame::Request(r) => Json::obj(vec![
                ("id", Json::u64_hex(r.id)),
                ("text", Json::Str(r.text.clone())),
                ("truth", Json::Num(r.truth as f64)),
                ("sample", r.sample.to_json()),
            ]),
            Frame::Response(r) => Json::obj(vec![
                ("id", Json::u64_hex(r.id)),
                ("pred", Json::Num(r.pred as f64)),
                ("handled_by", Json::Num(r.handled_by as f64)),
                ("latency_ns", Json::u64_hex(r.latency.as_nanos() as u64)),
                ("truth", Json::Num(r.truth as f64)),
            ]),
            Frame::Shed { id, truth, handled_by } => Json::obj(vec![
                ("id", Json::u64_hex(*id)),
                ("truth", Json::Num(*truth as f64)),
                ("handled_by", Json::Num(*handled_by as f64)),
            ]),
            Frame::Sync { shard, items } => Json::obj(vec![
                ("shard", Json::Num(*shard as f64)),
                (
                    "items",
                    Json::Arr(
                        items
                            .iter()
                            .map(|(f, y)| {
                                Json::obj(vec![
                                    ("f", f.to_json()),
                                    ("y", Json::Num(*y as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Frame::Eos => Json::obj(vec![]),
            Frame::SyncEnd { shard } => {
                Json::obj(vec![("shard", Json::Num(*shard as f64))])
            }
            Frame::Report(v) => v.clone(),
        }
    }

    /// Decode a frame from its header tag + parsed payload.
    fn decode(tag: u8, v: &Json) -> Result<Frame> {
        let wire = |what: &str| Error::Wire(format!("frame tag {tag}: bad '{what}'"));
        let hex = |k: &str| {
            v.get(k).and_then(Json::as_u64_hex).ok_or_else(|| wire(k))
        };
        let num = |k: &str| v.get(k).and_then(Json::as_usize).ok_or_else(|| wire(k));
        match tag {
            1 => Ok(Frame::Hello { cursor: hex("cursor")? }),
            2 => Ok(Frame::Request(Request {
                id: hex("id")?,
                text: v
                    .get("text")
                    .and_then(Json::as_str)
                    .ok_or_else(|| wire("text"))?
                    .to_string(),
                truth: num("truth")?,
                sample: Sample::from_json(
                    v.get("sample").ok_or_else(|| wire("sample"))?,
                )?,
            })),
            3 => Ok(Frame::Response(Response {
                id: hex("id")?,
                pred: num("pred")?,
                handled_by: num("handled_by")?,
                latency: Duration::from_nanos(hex("latency_ns")?),
                truth: num("truth")?,
                shed: false,
            })),
            4 => Ok(Frame::Shed {
                id: hex("id")?,
                truth: num("truth")?,
                handled_by: num("handled_by")?,
            }),
            5 => Ok(Frame::Sync {
                shard: num("shard")?,
                items: v
                    .get("items")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| wire("items"))?
                    .iter()
                    .map(|it| {
                        let f = Featurized::from_json(
                            it.get("f").ok_or_else(|| wire("items.f"))?,
                        )
                        .map_err(|e| Error::Wire(format!("sync item: {e}")))?;
                        let y = it
                            .get("y")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| wire("items.y"))?;
                        Ok((f, y))
                    })
                    .collect::<Result<_>>()?,
            }),
            6 => Ok(Frame::Eos),
            7 => Ok(Frame::SyncEnd { shard: num("shard")? }),
            8 => Ok(Frame::Report(v.clone())),
            _ => Err(Error::Wire(format!("unknown frame tag {tag}"))),
        }
    }
}

/// Encode one frame: 6-byte header + compact-JSON payload.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let body = frame.payload().to_string_compact();
    debug_assert!(body.len() <= MAX_FRAME, "oversized frame produced locally");
    let mut out = Vec::with_capacity(6 + body.len());
    out.push(WIRE_VERSION);
    out.push(frame.tag());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body.as_bytes());
    out
}

/// Incremental frame reassembly over arbitrary read boundaries: push
/// raw bytes in whatever chunks the socket yields (down to one byte at
/// a time), pull complete frames out. Malformed input — bad version,
/// unknown tag, a header length past [`MAX_FRAME`], non-UTF-8 or
/// non-JSON payload — is an [`Error::Wire`]; the connection is the
/// unit of failure, so callers drop the peer rather than resync.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    /// Empty reassembly buffer.
    pub fn new() -> Self {
        FrameBuf { buf: Vec::new() }
    }

    /// Append raw bytes from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Clone the buffered-but-unconsumed bytes (handshake leftovers
    /// handed from the connect phase to a reader thread).
    fn clone_buf(&self) -> Vec<u8> {
        self.buf.clone()
    }

    /// Next complete frame, `Ok(None)` when more bytes are needed.
    pub fn next(&mut self) -> Result<Option<Frame>> {
        if self.buf.len() < 6 {
            return Ok(None);
        }
        let version = self.buf[0];
        if version != WIRE_VERSION {
            return Err(Error::Wire(format!(
                "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
            )));
        }
        let tag = self.buf[1];
        if !(1..=8).contains(&tag) {
            return Err(Error::Wire(format!("unknown frame tag {tag}")));
        }
        let len =
            u32::from_be_bytes([self.buf[2], self.buf[3], self.buf[4], self.buf[5]])
                as usize;
        if len > MAX_FRAME {
            return Err(Error::Wire(format!(
                "frame length {len} exceeds the {MAX_FRAME}-byte cap"
            )));
        }
        if self.buf.len() < 6 + len {
            return Ok(None);
        }
        let body = std::str::from_utf8(&self.buf[6..6 + len])
            .map_err(|_| Error::Wire("frame payload is not UTF-8".into()))?;
        let payload = codec::parse(body)
            .map_err(|e| Error::Wire(format!("frame payload: {e}")))?;
        let frame = Frame::decode(tag, &payload)?;
        self.buf.drain(..6 + len);
        Ok(Some(frame))
    }
}

// --- socket plumbing -------------------------------------------------------

/// Queue of encoded frames bound for one socket (drained by that
/// socket's writer thread, in order).
type WireTx = Sender<Vec<u8>>;

/// Per-connection write half: a thread that drains encoded frames to
/// the socket in FIFO order. Serializing all writes through one thread
/// is what preserves the in-process channels' ordering guarantees
/// (responses before the report, syncs before the sync-end) with
/// multiple producer threads.
fn spawn_writer(mut stream: TcpStream) -> (WireTx, JoinHandle<()>) {
    let (tx, rx) = channel::<Vec<u8>>();
    let handle = thread::spawn(move || {
        for bytes in rx.iter() {
            // lint: allow(raw-write) — drains frames that were already
            // encoded at the send site; `encode()` is the single place
            // the MAX_FRAME bound is enforced.
            if stream.write_all(&bytes).is_err() {
                break; // peer gone; senders' failures are ignored
            }
        }
        let _ = stream.flush();
    });
    (tx, handle)
}

/// Read exactly one frame, blocking. Used for the [`Frame::Hello`]
/// handshake; the buffer carries over into the connection's read loop
/// so bytes after the handshake frame are not lost.
fn read_one(stream: &TcpStream, fb: &mut FrameBuf) -> Result<Frame> {
    let mut buf = [0u8; 4096];
    let mut rs = stream;
    loop {
        if let Some(f) = fb.next()? {
            return Ok(f);
        }
        match rs.read(&mut buf) {
            Ok(0) => {
                return Err(Error::Wire(
                    "connection closed before a complete frame".into(),
                ))
            }
            Ok(n) => fb.push(&buf[..n]),
            Err(e) => return Err(Error::Wire(format!("read: {e}"))),
        }
    }
}

/// Connect with retry until `timeout` — the two-terminal quickstart
/// and multi-process tests start client and server racily.
pub fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if t0.elapsed() >= timeout {
                    return Err(Error::Wire(format!("connect to {addr}: {e}")));
                }
                thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

// --- client ----------------------------------------------------------------

/// A loopback client: speaks the wire protocol to a [`serve`] /
/// [`run_front`] process and exposes a `Sender<Request>` so the
/// open-loop harness ([`super::load::drive_from`]) drives real sockets
/// unchanged.
pub struct Client {
    cursor: u64,
    req_tx: Sender<Request>,
    writer: JoinHandle<()>,
    reader: JoinHandle<(Vec<Response>, Option<Json>)>,
}

impl Client {
    /// Connect and perform the [`Frame::Hello`] handshake.
    pub fn connect(addr: &str) -> Result<Self> {
        Self::from_stream(TcpStream::connect(addr).map_err(|e| {
            Error::Wire(format!("connect to {addr}: {e}"))
        })?)
    }

    /// [`Client::connect`] with retry until `timeout` (server may
    /// still be binding).
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Self> {
        Self::from_stream(connect_retry(addr, timeout)?)
    }

    fn from_stream(stream: TcpStream) -> Result<Self> {
        let _ = stream.set_nodelay(true);
        let mut fb = FrameBuf::new();
        let cursor = match read_one(&stream, &mut fb)? {
            Frame::Hello { cursor } => cursor,
            other => {
                return Err(Error::Wire(format!(
                    "expected hello, got tag {}",
                    other.tag()
                )))
            }
        };
        let wstream = stream
            .try_clone()
            .map_err(|e| Error::Wire(format!("clone stream: {e}")))?;
        let (req_tx, req_rx) = channel::<Request>();
        let writer = thread::spawn(move || {
            let mut ws = wstream;
            for req in req_rx.iter() {
                if ws.write_all(&encode(&Frame::Request(req))).is_err() {
                    return; // server gone mid-stream (crash tests)
                }
            }
            let _ = ws.write_all(&encode(&Frame::Eos));
            let _ = ws.flush();
        });
        let reader = thread::spawn(move || {
            let mut responses = Vec::new();
            let mut report = None;
            let mut buf = [0u8; 16 * 1024];
            let mut rs = &stream;
            'conn: loop {
                loop {
                    match fb.next() {
                        Ok(Some(Frame::Response(r))) => responses.push(r),
                        Ok(Some(Frame::Shed { id, truth, handled_by })) => {
                            responses.push(Response {
                                id,
                                pred: 0,
                                handled_by,
                                latency: Duration::ZERO,
                                truth,
                                shed: true,
                            })
                        }
                        Ok(Some(Frame::Report(v))) => report = Some(v),
                        Ok(Some(_)) => {}
                        Ok(None) => break,
                        Err(_) => break 'conn,
                    }
                }
                match rs.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => fb.push(&buf[..n]),
                }
            }
            (responses, report)
        });
        Ok(Client { cursor, req_tx, writer, reader })
    }

    /// The server's resume cursor from the handshake: submit request
    /// ids at or above this.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// A request sender wired to the socket — hand it to
    /// [`super::load::drive_from`] to run the open-loop harness over
    /// TCP. The connection sends [`Frame::Eos`] when every clone (and
    /// the client itself via [`Client::finish`]) has dropped.
    pub fn request_sender(&self) -> Sender<Request> {
        self.req_tx.clone()
    }

    /// Close the request stream, wait for the server to hang up, and
    /// return everything received: responses (shed ones flagged) and
    /// the final report, if the server lived to send one (a SIGKILLed
    /// server never does — the crash tests rely on that distinction).
    pub fn finish(self) -> Result<(Vec<Response>, Option<Json>)> {
        drop(self.req_tx);
        self.writer
            .join()
            .map_err(|_| Error::Worker("client writer panicked".into()))?;
        self.reader
            .join()
            .map_err(|_| Error::Worker("client reader panicked".into()))
    }
}

// --- server accept loop ----------------------------------------------------

/// One accepted client connection's handles.
struct Conn {
    wtx: WireTx,
    writer: JoinHandle<()>,
    reader: JoinHandle<()>,
    stream: TcpStream,
}

/// Serve a [`ShardFront`] over TCP: accept clients, forward their
/// requests into the front, route responses back by request id, and
/// broadcast the final [`Frame::Report`] to every client before
/// closing. Returns when every connected client has sent
/// [`Frame::Eos`] (or hung up) and the front has drained.
///
/// Request ids must be unique across concurrently connected clients —
/// they are the response-routing key.
pub fn serve(front: ShardFront, listener: TcpListener) -> Result<ShardReport> {
    let cursor = front.resume_cursor();
    let (req_tx, req_rx) = channel::<Request>();
    let (resp_tx, resp_rx) = channel::<Response>();
    let front_handle = thread::spawn(move || front.serve(req_rx, resp_tx));

    // id → the owning connection's write queue, filled at request
    // forwarding time (before the front can possibly answer), drained
    // by the dispatcher.
    let registry: Arc<Mutex<HashMap<u64, WireTx>>> = Arc::new(Mutex::new(HashMap::new()));
    let reg = registry.clone();
    let dispatcher = thread::spawn(move || {
        for resp in resp_rx.iter() {
            let target = lock_unpoisoned(&reg).remove(&resp.id);
            if let Some(w) = target {
                let frame = if resp.shed {
                    Frame::Shed {
                        id: resp.id,
                        truth: resp.truth,
                        handled_by: resp.handled_by,
                    }
                } else {
                    Frame::Response(resp)
                };
                let _ = w.send(encode(&frame));
            }
        }
    });

    listener
        .set_nonblocking(true)
        .map_err(|e| Error::io("tcp listener", e))?;
    let finished = Arc::new(AtomicUsize::new(0));
    let mut conns: Vec<Conn> = Vec::new();
    let accept_err = loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                let Ok(wstream) = stream.try_clone() else { continue };
                let Ok(rstream) = stream.try_clone() else { continue };
                let (wtx, writer) = spawn_writer(wstream);
                let _ = wtx.send(encode(&Frame::Hello { cursor }));
                let reader = spawn_conn_reader(
                    rstream,
                    wtx.clone(),
                    req_tx.clone(),
                    registry.clone(),
                    finished.clone(),
                );
                conns.push(Conn { wtx, writer, reader, stream });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if !conns.is_empty() && finished.load(Ordering::SeqCst) >= conns.len()
                {
                    break None; // every client is done submitting
                }
                if front_handle.is_finished() {
                    break None; // front error: surface it at the join
                }
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) => break Some(Error::io("tcp accept", e)),
        }
    };

    // Close the request stream; the front drains, writes its shutdown
    // checkpoint, and reports. The dispatcher ends when the front's
    // response senders drop.
    drop(req_tx);
    let result = front_handle
        .join()
        .map_err(|_| Error::Worker("front thread panicked".into()))?;
    dispatcher
        .join()
        .map_err(|_| Error::Worker("response dispatcher panicked".into()))?;
    lock_unpoisoned(&registry).clear();
    match (accept_err, result) {
        (None, Ok(report)) => {
            let bytes = encode(&Frame::Report(report.to_json()));
            for Conn { wtx, writer, reader, stream } in conns {
                let _ = wtx.send(bytes.clone());
                drop(wtx);
                let _ = writer.join(); // all frames flushed to the socket
                let _ = stream.shutdown(Shutdown::Both);
                let _ = reader.join();
            }
            Ok(report)
        }
        (accept_err, result) => {
            for Conn { wtx, writer, reader, stream } in conns {
                drop(wtx);
                let _ = stream.shutdown(Shutdown::Both);
                let _ = writer.join();
                let _ = reader.join();
            }
            Err(accept_err
                .or(result.err())
                .unwrap_or_else(|| Error::Worker("serve loop state".into())))
        }
    }
}

/// Read half of one accepted client: forwards requests into the front
/// (registering the response route first), counts the connection
/// finished at [`Frame::Eos`] or disconnect, and hangs up on protocol
/// violations.
fn spawn_conn_reader(
    stream: TcpStream,
    wtx: WireTx,
    req_tx: Sender<Request>,
    registry: Arc<Mutex<HashMap<u64, WireTx>>>,
    finished: Arc<AtomicUsize>,
) -> JoinHandle<()> {
    thread::spawn(move || {
        let mut fb = FrameBuf::new();
        let mut buf = [0u8; 16 * 1024];
        // Dropped at Eos: the write queue then holds only registered
        // response routes, so the writer can exit once those drain.
        let mut live = Some((req_tx, wtx));
        loop {
            loop {
                match fb.next() {
                    Ok(Some(Frame::Request(req))) => {
                        if let Some((tx, w)) = &live {
                            lock_unpoisoned(&registry)
                                .insert(req.id, w.clone());
                            let _ = tx.send(req);
                        }
                    }
                    Ok(Some(Frame::Eos)) => {
                        if live.take().is_some() {
                            finished.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    Ok(Some(_)) => {} // ignore unexpected-but-valid frames
                    Ok(None) => break,
                    Err(_) => {
                        // Protocol violation: the connection is the
                        // failure unit — drop this peer, keep serving.
                        let _ = stream.shutdown(Shutdown::Both);
                        if live.take().is_some() {
                            finished.fetch_add(1, Ordering::SeqCst);
                        }
                        return;
                    }
                }
            }
            let mut rs = &stream;
            match rs.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => fb.push(&buf[..n]),
            }
        }
        if live.take().is_some() {
            // Disconnect without Eos (client died): stop waiting on it.
            finished.fetch_add(1, Ordering::SeqCst);
        }
    })
}

// --- multi-process shards --------------------------------------------------

/// One shard process's position in an `of`-shard deployment
/// (`ocl serve --shard-id <id>` with `of` taken from the config).
#[derive(Clone, Copy, Debug)]
pub struct ShardSlot {
    /// This process's shard index (`0..of`).
    pub id: usize,
    /// Total shard processes in the deployment.
    pub of: usize,
}

/// Build the [`Server`] for one shard *process*: the per-process half
/// of what [`ShardFront::with_ckpt`] does in-process — fold the shard
/// index into the seed (bit-identical to the in-process shard), restore
/// from the shared checkpoint directory when asked, and attach the
/// shared [`CkptSink`]. Returns the server and the deployment-wide
/// resume cursor (minimum over all shards' checkpointed cursors — the
/// front must resubmit from the most conservative position).
pub fn build_shard_server(
    cfg: CascadeConfig,
    classes: usize,
    expert: Expert,
    serve_cfg: ServeConfig,
    artifacts_dir: &str,
    slot: ShardSlot,
    ckpt: Option<CkptOptions>,
) -> Result<(Server, u64)> {
    if slot.of == 0 || slot.id >= slot.of {
        return Err(Error::Config(format!(
            "shard slot {} out of range for {} shards",
            slot.id, slot.of
        )));
    }
    let mut shard_cfg = cfg.clone();
    shard_cfg.seed = cfg.seed ^ ((slot.id as u64) * 0x51A2_D007);
    let mut cursor = 0u64;
    let mut my_state: Option<ShardState> = None;
    let sink = match &ckpt {
        Some(opts) => {
            if let Some(mode) = opts.resume {
                if let Some(loaded) = ckpt::load_latest(&opts.dir, mode, slot.of)? {
                    // Same shape-drift policy as the in-process front:
                    // strict errors, best-effort falls back to fresh.
                    let shape =
                        loaded.iter().try_for_each(|s| s.check_config(&cfg, classes));
                    match (shape, mode) {
                        (Err(e), ckpt::ResumeMode::Strict) => return Err(e),
                        (Err(_), ckpt::ResumeMode::BestEffort) => {}
                        (Ok(()), _) => {
                            cursor = loaded.iter().map(|s| s.cursor).min().unwrap_or(0);
                            my_state = loaded.into_iter().find(|s| s.shard == slot.id);
                        }
                    }
                }
            }
            Some(CkptSink::create(&opts.dir, slot.of)?)
        }
        None => None,
    };
    let mut srv = match my_state {
        Some(s) => Server::resume(shard_cfg, classes, expert, serve_cfg, artifacts_dir, s)?,
        None => Server::new(shard_cfg, classes, expert, serve_cfg, artifacts_dir)?,
    };
    if let Some(sink) = sink {
        srv.attach_ckpt(sink, slot.id);
    }
    Ok((srv, cursor))
}

/// Run one shard process: accept exactly one connection (the front),
/// answer its requests, forward locally-staged annotation syncs up as
/// [`Frame::Sync`] frames, absorb peer syncs the front relays down,
/// and finish with a [`Frame::Report`]. `cursor` is the resume cursor
/// from [`build_shard_server`], announced in the [`Frame::Hello`].
pub fn serve_shard(
    server: Server,
    cursor: u64,
    shard_id: usize,
    listener: TcpListener,
) -> Result<ServeReport> {
    let mut server = server;
    let (stream, _) = listener.accept().map_err(|e| Error::io("tcp accept", e))?;
    let _ = stream.set_nodelay(true);
    let wstream = stream
        .try_clone()
        .map_err(|e| Error::Wire(format!("clone stream: {e}")))?;
    let (wtx, writer) = spawn_writer(wstream);
    let _ = wtx.send(encode(&Frame::Hello { cursor }));

    let (req_tx, req_rx) = channel::<Request>();
    let (resp_tx, resp_rx) = channel::<Response>();
    let (sync_out_tx, sync_out_rx) = channel::<SyncBatch>();
    let (sync_in_tx, sync_in_rx) = channel::<SyncBatch>();
    // Always wired, even for a 1-shard deployment: the server then
    // waits for the front's SyncEnd before exiting, which keeps the
    // shutdown sequence uniform across topologies.
    server.wire_sync(vec![sync_out_tx], sync_in_rx);
    let server_handle = thread::spawn(move || server.serve(req_rx, resp_tx));

    let resp_wtx = wtx.clone();
    let resp_fwd = thread::spawn(move || {
        for resp in resp_rx.iter() {
            let frame = if resp.shed {
                Frame::Shed { id: resp.id, truth: resp.truth, handled_by: resp.handled_by }
            } else {
                Frame::Response(resp)
            };
            let _ = resp_wtx.send(encode(&frame));
        }
    });
    let sync_wtx = wtx.clone();
    let sync_fwd = thread::spawn(move || {
        for SyncBatch(items) in sync_out_rx.iter() {
            let owned: Vec<(Featurized, usize)> =
                items.iter().map(|(f, y)| ((**f).clone(), *y)).collect();
            let _ = sync_wtx
                .send(encode(&Frame::Sync { shard: shard_id, items: owned }));
        }
        // The server flushed its sync stage and dropped the sender:
        // our outgoing annotation stream is complete.
        let _ = sync_wtx.send(encode(&Frame::SyncEnd { shard: shard_id }));
    });

    let rstream = stream
        .try_clone()
        .map_err(|e| Error::Wire(format!("clone stream: {e}")))?;
    let reader = thread::spawn(move || {
        let mut fb = FrameBuf::new();
        let mut buf = [0u8; 16 * 1024];
        let mut req_tx = Some(req_tx);
        let mut sync_in_tx = Some(sync_in_tx);
        loop {
            loop {
                match fb.next() {
                    Ok(Some(Frame::Request(req))) => {
                        if let Some(tx) = &req_tx {
                            let _ = tx.send(req);
                        }
                    }
                    Ok(Some(Frame::Eos)) => {
                        req_tx = None;
                    }
                    Ok(Some(Frame::Sync { items, .. })) => {
                        if let Some(tx) = &sync_in_tx {
                            let _ = tx.send(SyncBatch(
                                items.into_iter().map(|(f, y)| (Arc::new(f), y)).collect(),
                            ));
                        }
                    }
                    Ok(Some(Frame::SyncEnd { .. })) => {
                        // Peers all flushed: the server's inbox
                        // disconnects and its serve loop can exit.
                        sync_in_tx = None;
                    }
                    Ok(Some(_)) => {}
                    Ok(None) => break,
                    Err(_) => return, // protocol violation: hang up
                }
            }
            let mut rs = &rstream;
            match rs.read(&mut buf) {
                Ok(0) | Err(_) => return,
                Ok(n) => fb.push(&buf[..n]),
            }
        }
    });

    let result = server_handle
        .join()
        .map_err(|_| Error::Worker("shard server thread panicked".into()))?;
    let _ = sync_fwd.join();
    let _ = resp_fwd.join();
    match result {
        Ok(report) => {
            let _ = wtx.send(encode(&Frame::Report(report.to_json())));
            drop(wtx);
            let _ = writer.join();
            let _ = stream.shutdown(Shutdown::Both);
            let _ = reader.join();
            Ok(report)
        }
        Err(e) => {
            drop(wtx);
            let _ = stream.shutdown(Shutdown::Both);
            let _ = writer.join();
            let _ = reader.join();
            Err(e)
        }
    }
}

/// How long the front waits for a crashed shard process to come back
/// before declaring it gone for good. Rolling restarts are operator
/// actions measured in seconds; a shard absent this long is not
/// restarting, and the front then fails the run with a missing-report
/// error rather than serving a silently degraded topology.
const RECONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Supervised write half of one front→shard connection — the rolling-
/// restart seam. All front traffic to a shard goes through its `Link`
/// so the shard process can be SIGKILLed and resumed (`--resume
/// strict`) without the front dropping work:
///
/// * **Requests replay exactly once per client.** Every dispatched
///   request stays in `pending` (as its encoded frame) until a
///   response or shed for its id comes back; on reconnect the whole
///   set is re-sent in id order. Answered requests have left the set,
///   so nothing is double-served on the happy path; in the narrow race
///   where an answer and the crash cross, the duplicate answer is
///   dropped at the front's response registry (the id routes at most
///   once), so clients still see exactly-once.
/// * **Sync rebroadcasts are buffered for the absent peer.** Frames
///   bound for a down shard land in `down_buf` and replay, in order,
///   before any replayed request — annotation replication stays
///   at-least-once across the restart instead of silently dropping the
///   absence window.
/// * **`Eos` is sticky.** If the stream had already been closed when
///   the shard died, the replayed connection re-closes it.
struct Link {
    /// Shard address (reconnect target).
    addr: String,
    /// Live write queue; `None` while the shard is down.
    wtx: Mutex<Option<WireTx>>,
    /// Current writer thread, joined at front shutdown (writers for
    /// dead connections exit on their own when their queue drops).
    writer: Mutex<Option<JoinHandle<()>>>,
    /// Encoded `Request` frames dispatched but not yet answered — the
    /// replay set.
    pending: Mutex<HashMap<u64, Vec<u8>>>,
    /// Sync/sync-end frames that arrived while the shard was down.
    down_buf: Mutex<Vec<Vec<u8>>>,
    /// The front has closed this shard's request stream.
    eos_sent: AtomicBool,
    /// Times this link was re-established after a shard went away.
    reconnects: AtomicUsize,
}

impl Link {
    fn new(addr: String, wtx: WireTx, writer: JoinHandle<()>) -> Self {
        Link {
            addr,
            wtx: Mutex::new(Some(wtx)),
            writer: Mutex::new(Some(writer)),
            pending: Mutex::new(HashMap::new()),
            down_buf: Mutex::new(Vec::new()),
            eos_sent: AtomicBool::new(false),
            reconnects: AtomicUsize::new(0),
        }
    }

    /// Queue `bytes` on the live connection; hands them back when the
    /// shard is down (or its writer just died).
    fn try_send(&self, bytes: Vec<u8>) -> std::result::Result<(), Vec<u8>> {
        let mut guard = lock_unpoisoned(&self.wtx);
        match guard.as_ref() {
            Some(w) => match w.send(bytes) {
                Ok(()) => Ok(()),
                Err(back) => {
                    *guard = None; // writer gone: the link is down
                    Err(back.0)
                }
            },
            None => Err(bytes),
        }
    }

    /// Dispatch one client request: registered in the replay set
    /// *before* the send, so a crash at any point re-delivers it.
    fn send_request(&self, id: u64, bytes: Vec<u8>) {
        lock_unpoisoned(&self.pending).insert(id, bytes.clone());
        let _ = self.try_send(bytes);
    }

    /// A response (or shed) for `id` arrived: it leaves the replay set.
    fn settle(&self, id: u64) {
        lock_unpoisoned(&self.pending).remove(&id);
    }

    /// Send a sync/sync-end rebroadcast, buffering it for replay while
    /// the shard is down.
    fn send_buffered(&self, bytes: Vec<u8>) {
        if let Err(back) = self.try_send(bytes) {
            lock_unpoisoned(&self.down_buf).push(back);
        }
    }

    /// Close this shard's request stream (sticky across reconnects).
    fn send_eos(&self) {
        self.eos_sent.store(true, Ordering::SeqCst);
        let _ = self.try_send(encode(&Frame::Eos));
    }

    /// Drop the write queue so dispatches buffer instead of racing a
    /// dead socket.
    fn mark_down(&self) {
        *lock_unpoisoned(&self.wtx) = None;
    }

    /// Wire a fresh connection and replay everything the shard missed:
    /// buffered rebroadcasts first, then unanswered requests in id
    /// order (determinism), then the sticky `Eos`. The replay happens
    /// on the new queue *before* it is published, under the `pending`
    /// lock, so a concurrently dispatched request is either in the
    /// replayed snapshot or sent once through the published queue —
    /// never neither.
    fn reattach(&self, stream: TcpStream) {
        let (wtx, writer) = spawn_writer(stream);
        let mut down = lock_unpoisoned(&self.down_buf);
        let pend = lock_unpoisoned(&self.pending);
        for bytes in down.drain(..) {
            let _ = wtx.send(bytes);
        }
        let mut replay: Vec<(u64, Vec<u8>)> =
            pend.iter().map(|(id, b)| (*id, b.clone())).collect();
        replay.sort_unstable_by_key(|(id, _)| *id);
        for (_, bytes) in replay {
            let _ = wtx.send(bytes);
        }
        if self.eos_sent.load(Ordering::SeqCst) {
            let _ = wtx.send(encode(&Frame::Eos));
        }
        *lock_unpoisoned(&self.wtx) = Some(wtx);
        let _ = lock_unpoisoned(&self.writer).replace(writer);
        self.reconnects.fetch_add(1, Ordering::SeqCst);
    }

    /// Final teardown: drop the write queue and join the writer.
    fn shutdown(&self) {
        *lock_unpoisoned(&self.wtx) = None;
        let handle = lock_unpoisoned(&self.writer).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

/// Run the thin front process over already-running shard processes:
/// hash-dispatch client requests ([`shard_of`]), relay responses back
/// to the owning client, rebroadcast each shard's [`Frame::Sync`] to
/// its peers, and merge the shards' final reports into one JSON
/// report, broadcast to every client and returned.
///
/// **Rolling restarts.** A shard process that disconnects without a
/// final report is treated as restarting, not gone: its [`Link`]
/// buffers traffic, the front keeps serving through the remaining
/// shards, and when the shard comes back (within
/// [`RECONNECT_TIMEOUT`]) the link replays the buffered sync frames
/// and every unanswered request. The merged report counts the
/// `reconnects`. A shard that stays away past the timeout fails the
/// run with a missing-report error.
///
/// Admission is honest here: each shard process bounds its own
/// population (`max_pending` per process), because a cross-process
/// global gate would need shared state this zero-dependency build
/// doesn't have. The in-process [`serve`] keeps the global bound.
pub fn run_front(shard_addrs: &[String], listener: TcpListener) -> Result<Json> {
    let n = shard_addrs.len();
    if n == 0 {
        return Err(Error::Config("front needs at least one shard address".into()));
    }
    // Handshake every shard first: the deployment cursor is the
    // minimum over shard cursors.
    let mut shard_streams = Vec::with_capacity(n);
    let mut cursor = u64::MAX;
    for addr in shard_addrs {
        let stream = connect_retry(addr, Duration::from_secs(30))?;
        let _ = stream.set_nodelay(true);
        let mut fb = FrameBuf::new();
        match read_one(&stream, &mut fb)? {
            Frame::Hello { cursor: c } => cursor = cursor.min(c),
            other => {
                return Err(Error::Wire(format!(
                    "shard {addr}: expected hello, got tag {}",
                    other.tag()
                )))
            }
        }
        shard_streams.push((stream, fb));
    }
    let cursor = if cursor == u64::MAX { 0 } else { cursor };

    // Supervised write halves up to the shards, shared by client
    // readers (request dispatch) and shard supervisors (sync
    // rebroadcast + replay-on-reconnect).
    let mut link_vec = Vec::with_capacity(n);
    for (addr, (stream, _)) in shard_addrs.iter().zip(&shard_streams) {
        let ws = stream
            .try_clone()
            .map_err(|e| Error::Wire(format!("clone shard stream: {e}")))?;
        let (wtx, writer) = spawn_writer(ws);
        link_vec.push(Link::new(addr.clone(), wtx, writer));
    }
    let links = Arc::new(link_vec);

    let registry: Arc<Mutex<HashMap<u64, WireTx>>> = Arc::new(Mutex::new(HashMap::new()));
    let sync_ends = Arc::new(AtomicUsize::new(0));
    let reports: Arc<Mutex<Vec<Option<Json>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));

    // Shard supervisors: responses route to clients (settling the
    // replay set), syncs rebroadcast to peers, sync-ends count toward
    // the all-flushed broadcast, reports land in the merge slots — and
    // a connection lost *before* the report triggers the rolling-
    // restart path: reconnect, replay, keep reading.
    let mut shard_readers = Vec::with_capacity(n);
    for (i, (stream, fb)) in shard_streams.into_iter().enumerate() {
        let mut fb = FrameBuf { buf: fb.clone_buf() };
        let registry = registry.clone();
        let links = links.clone();
        let sync_ends = sync_ends.clone();
        let reports = reports.clone();
        shard_readers.push(thread::spawn(move || {
            let mut stream = stream;
            let mut buf = [0u8; 16 * 1024];
            loop {
                // Reads until the shard reports (returns) or the
                // connection is lost (falls through to reconnect).
                loop {
                    match fb.next() {
                        Ok(Some(frame @ Frame::Response(_)))
                        | Ok(Some(frame @ Frame::Shed { .. })) => {
                            let id = match &frame {
                                Frame::Response(r) => r.id,
                                Frame::Shed { id, .. } => *id,
                                _ => unreachable!(),
                            };
                            links[i].settle(id);
                            let target = lock_unpoisoned(&registry).remove(&id);
                            if let Some(w) = target {
                                let _ = w.send(encode(&frame));
                            }
                        }
                        Ok(Some(Frame::Sync { shard, items })) => {
                            let bytes = encode(&Frame::Sync { shard, items });
                            for (j, l) in links.iter().enumerate() {
                                if j != shard {
                                    l.send_buffered(bytes.clone());
                                }
                            }
                        }
                        Ok(Some(Frame::SyncEnd { .. })) => {
                            // Once every shard flushed, tell them all:
                            // no more incoming syncs, wind down. The
                            // per-shard socket FIFO plus this SeqCst
                            // counter guarantees no shard sees its
                            // SyncEnd before every rebroadcast sync.
                            if sync_ends.fetch_add(1, Ordering::SeqCst) + 1
                                == links.len()
                            {
                                for (j, l) in links.iter().enumerate() {
                                    l.send_buffered(encode(&Frame::SyncEnd {
                                        shard: j,
                                    }));
                                }
                            }
                        }
                        Ok(Some(Frame::Report(v))) => {
                            lock_unpoisoned(&reports)[i] = Some(v);
                            return; // clean end: the shard is done
                        }
                        Ok(Some(_)) => {}
                        Ok(None) => {
                            let mut rs = &stream;
                            match rs.read(&mut buf) {
                                Ok(0) | Err(_) => break,
                                Ok(got) => fb.push(&buf[..got]),
                            }
                        }
                        // Garbled stream: same recovery as a crash —
                        // the connection is the unit of failure.
                        Err(_) => break,
                    }
                }
                // The shard hung up without reporting: a rolling
                // restart. Buffer its traffic, wait for it to come
                // back, and replay. Its fresh Hello cursor is
                // discarded — the front's stream position is
                // authoritative; the link's replay set covers exactly
                // the gap the restarted shard has not answered.
                links[i].mark_down();
                let Ok(ns) = connect_retry(&links[i].addr, RECONNECT_TIMEOUT) else {
                    return; // stayed away: surfaced as a missing report
                };
                let _ = ns.set_nodelay(true);
                let mut nfb = FrameBuf::new();
                if !matches!(read_one(&ns, &mut nfb), Ok(Frame::Hello { .. })) {
                    return;
                }
                let Ok(ws) = ns.try_clone() else { return };
                links[i].reattach(ws);
                stream = ns;
                fb = nfb;
            }
        }));
    }

    // Client accept loop — same lifecycle as [`serve`]'s.
    listener
        .set_nonblocking(true)
        .map_err(|e| Error::io("tcp listener", e))?;
    let finished = Arc::new(AtomicUsize::new(0));
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                let Ok(ws) = stream.try_clone() else { continue };
                let Ok(rstream) = stream.try_clone() else { continue };
                let (wtx, writer) = spawn_writer(ws);
                let _ = wtx.send(encode(&Frame::Hello { cursor }));
                let reader = spawn_front_client_reader(
                    rstream,
                    wtx.clone(),
                    links.clone(),
                    registry.clone(),
                    finished.clone(),
                );
                conns.push(Conn { wtx, writer, reader, stream });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if !conns.is_empty() && finished.load(Ordering::SeqCst) >= conns.len()
                {
                    break;
                }
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(Error::io("tcp accept", e)),
        }
    }

    // Every client finished → close the shards' request streams; they
    // drain, flush syncs, checkpoint, report, and hang up. `Eos` is
    // sticky per link, so a shard mid-restart still gets it on replay.
    for l in links.iter() {
        l.send_eos();
    }
    for h in shard_readers {
        let _ = h.join();
    }
    let collected: Vec<Option<Json>> =
        std::mem::take(&mut *lock_unpoisoned(&reports));
    let mut per_shard = Vec::with_capacity(n);
    for (i, r) in collected.into_iter().enumerate() {
        per_shard.push(r.ok_or_else(|| {
            Error::Worker(format!("shard {i} hung up without a final report"))
        })?);
    }
    let sum = |key: &str| -> f64 {
        per_shard
            .iter()
            .map(|r| r.get(key).and_then(Json::as_f64).unwrap_or(0.0))
            .sum()
    };
    let reconnects: usize = links
        .iter()
        .map(|l| l.reconnects.load(Ordering::SeqCst))
        .sum();
    let merged = Json::obj(vec![
        ("shards", Json::Num(n as f64)),
        ("served", Json::Num(sum("served"))),
        ("shed", Json::Num(sum("shed"))),
        ("llm_calls", Json::Num(sum("llm_calls"))),
        ("ckpts", Json::Num(sum("ckpts"))),
        ("reconnects", Json::Num(reconnects as f64)),
        (
            "resumed",
            Json::Bool(per_shard.iter().any(|r| {
                r.get("resumed").and_then(Json::as_bool).unwrap_or(false)
            })),
        ),
        ("per_shard", Json::Arr(per_shard)),
    ]);

    lock_unpoisoned(&registry).clear();
    let bytes = encode(&Frame::Report(merged.clone()));
    for Conn { wtx, writer, reader, stream } in conns {
        let _ = wtx.send(bytes.clone());
        drop(wtx);
        let _ = writer.join();
        let _ = stream.shutdown(Shutdown::Both);
        let _ = reader.join();
    }
    for l in links.iter() {
        l.shutdown(); // drop the write queue, join the writer thread
    }
    Ok(merged)
}

/// Read half of one client connection at the front: requests are
/// registered for response routing, then hash-dispatched to their
/// shard's [`Link`] (which keeps them replayable until answered).
fn spawn_front_client_reader(
    stream: TcpStream,
    wtx: WireTx,
    links: Arc<Vec<Link>>,
    registry: Arc<Mutex<HashMap<u64, WireTx>>>,
    finished: Arc<AtomicUsize>,
) -> JoinHandle<()> {
    thread::spawn(move || {
        let n = links.len();
        let mut fb = FrameBuf::new();
        let mut buf = [0u8; 16 * 1024];
        let mut live = Some(wtx);
        loop {
            loop {
                match fb.next() {
                    Ok(Some(Frame::Request(req))) => {
                        if let Some(w) = &live {
                            lock_unpoisoned(&registry)
                                .insert(req.id, w.clone());
                            let s = shard_of(req.id, n);
                            let id = req.id;
                            links[s].send_request(id, encode(&Frame::Request(req)));
                        }
                    }
                    Ok(Some(Frame::Eos)) => {
                        if live.take().is_some() {
                            finished.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    Ok(Some(_)) => {}
                    Ok(None) => break,
                    Err(_) => {
                        let _ = stream.shutdown(Shutdown::Both);
                        if live.take().is_some() {
                            finished.fetch_add(1, Ordering::SeqCst);
                        }
                        return;
                    }
                }
            }
            let mut rs = &stream;
            match rs.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => fb.push(&buf[..n]),
            }
        }
        if live.take().is_some() {
            finished.fetch_add(1, Ordering::SeqCst);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_through_the_codec() {
        let frames = vec![
            Frame::Hello { cursor: u64::MAX - 7 },
            Frame::Shed { id: 1 << 60, truth: 1, handled_by: 3 },
            Frame::Eos,
            Frame::SyncEnd { shard: 2 },
            Frame::Report(Json::obj(vec![("served", Json::Num(12.0))])),
        ];
        let mut fb = FrameBuf::new();
        for f in &frames {
            fb.push(&encode(f));
        }
        for f in &frames {
            assert_eq!(fb.next().unwrap().as_ref(), Some(f));
        }
        assert_eq!(fb.next().unwrap(), None);
    }

    #[test]
    fn header_validation_rejects_before_buffering() {
        // Bad version: rejected on the first 6 bytes.
        let mut fb = FrameBuf::new();
        fb.push(&[99, 1, 0, 0, 0, 0]);
        assert!(matches!(fb.next(), Err(Error::Wire(_))));
        // Unknown tag.
        let mut fb = FrameBuf::new();
        fb.push(&[WIRE_VERSION, 42, 0, 0, 0, 0]);
        assert!(matches!(fb.next(), Err(Error::Wire(_))));
        // Oversized length: rejected from the header alone — no
        // payload bytes were ever supplied.
        let mut fb = FrameBuf::new();
        let mut hdr = vec![WIRE_VERSION, 6];
        hdr.extend_from_slice(&((MAX_FRAME as u32) + 1).to_be_bytes());
        fb.push(&hdr);
        let err = fb.next().unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn incomplete_frames_wait_for_more_bytes() {
        let bytes = encode(&Frame::Hello { cursor: 5 });
        let mut fb = FrameBuf::new();
        for &b in &bytes[..bytes.len() - 1] {
            fb.push(&[b]);
            assert!(fb.next().unwrap().is_none(), "partial frame must not decode");
        }
        fb.push(&bytes[bytes.len() - 1..]);
        assert_eq!(fb.next().unwrap(), Some(Frame::Hello { cursor: 5 }));
    }
}
