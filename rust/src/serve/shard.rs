//! Multi-router scale-out: N independent [`Server`] shards behind a
//! hashing front dispatcher, with an optional cross-shard annotation
//! broadcast.
//!
//! **Why shards.** One router thread serializes admission, the DAgger
//! walk, and the learning cadence; past a few thousand req/s it is the
//! bottleneck regardless of worker capacity. Sharding runs N routers —
//! each with its own worker pools, learner state, and RNG — and splits
//! traffic by a multiplicative hash of the request id, so scale-out is
//! a topology change, not an algorithm change.
//!
//! **Why the broadcast.** A shard only learns from the annotations its
//! own traffic buys, so N shards each see ~1/N of the single router's
//! training signal. With `ShardConfig::sync_interval = k`, every k
//! expert annotations a shard replicates them (featurized query +
//! label) to its peers, which absorb them through the same replay
//! caches and training cadence as local annotations — every shard's
//! learners then converge toward the single-learner trajectory while
//! still answering only their own traffic. β schedules stay local (one
//! decay per *admitted* request), which is the deviation from exact
//! single-learner parity this topology accepts; `shards = 1` remains
//! bit-for-bit the single router.

use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::Arc;

use crate::config::CascadeConfig;
use crate::error::{Error, Result};
use crate::sim::Expert;
use crate::util::Percentiles;

use super::ckpt::{self, CkptOptions, CkptSink, ShardState};
use super::{
    AdmissionGate, Chaos, Request, Response, Server, ServeConfig, ServeReport, SyncBatch,
};

/// Which shard a request id lands on (Fibonacci multiplicative hash —
/// sequential client ids spread uniformly).
pub fn shard_of(id: u64, shards: usize) -> usize {
    ((id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize) % shards.max(1)
}

/// Aggregated result of a multi-shard run.
#[derive(Debug)]
pub struct ShardReport {
    /// Per-shard reports, in shard order.
    pub shards: Vec<ServeReport>,
    /// Wall clock of the whole run (front's view).
    pub wall_secs: f64,
    /// Largest population the *global* admission budget ever held —
    /// bounded by `ServeConfig::max_pending` across all shards
    /// combined, not per shard.
    pub peak_pending: usize,
}

impl ShardReport {
    /// Total requests served (excludes shed).
    pub fn served(&self) -> usize {
        self.shards.iter().map(|r| r.served).sum()
    }

    /// Total requests shed by admission control.
    pub fn shed(&self) -> usize {
        self.shards.iter().map(|r| r.shed).sum()
    }

    /// Total expert calls.
    pub fn llm_calls(&self) -> u64 {
        self.shards.iter().map(|r| r.llm_calls).sum()
    }

    /// Served requests per second across all shards.
    pub fn throughput(&self) -> f64 {
        self.served() as f64 / self.wall_secs.max(1e-9)
    }

    /// Serve-weighted accuracy across shards.
    pub fn accuracy(&self) -> f64 {
        let served = self.served();
        if served == 0 {
            return 0.0;
        }
        self.shards
            .iter()
            .map(|r| r.accuracy * r.served as f64)
            .sum::<f64>()
            / served as f64
    }

    /// Latency distribution over the union of all shards' samples.
    pub fn latency_ms(&self) -> Percentiles {
        let mut p = Percentiles::new();
        for r in &self.shards {
            p.merge(&r.latency_ms);
        }
        p
    }

    /// Latency over requests answered at level 0, union of shards.
    pub fn latency_direct_ms(&self) -> Percentiles {
        let mut p = Percentiles::new();
        for r in &self.shards {
            p.merge(&r.latency_direct_ms);
        }
        p
    }

    /// Latency over deferred requests (level ≥ 1 or expert), union of
    /// shards.
    pub fn latency_deferred_ms(&self) -> Percentiles {
        let mut p = Percentiles::new();
        for r in &self.shards {
            p.merge(&r.latency_deferred_ms);
        }
        p
    }

    /// Total speculative dispatches whose gate confirmed the deferral.
    pub fn spec_hits(&self) -> u64 {
        self.shards.iter().map(|r| r.spec_hits).sum()
    }

    /// Total speculative dispatches discarded on a keep/jump.
    pub fn spec_wasted(&self) -> u64 {
        self.shards.iter().map(|r| r.spec_wasted).sum()
    }

    /// Per-level peak stage+batch queue depth — element-wise max over
    /// shards (each shard has its own queues, so a sum would overstate
    /// any single router's backlog).
    pub fn queue_depth(&self) -> Vec<usize> {
        let n = self.shards.iter().map(|r| r.queue_depth.len()).max().unwrap_or(0);
        let mut out = vec![0usize; n];
        for r in &self.shards {
            for (i, &d) in r.queue_depth.iter().enumerate() {
                out[i] = out[i].max(d);
            }
        }
        out
    }

    /// Worst end-of-run snapshot staleness across shards and levels.
    pub fn max_snapshot_lag(&self) -> u64 {
        self.shards
            .iter()
            .flat_map(|r| r.snapshot_lag.iter().copied())
            .max()
            .unwrap_or(0)
    }

    /// True when any shard restored from a checkpoint.
    pub fn resumed(&self) -> bool {
        self.shards.iter().any(|r| r.resumed)
    }

    /// Total durable checkpoints written across shards this run.
    pub fn ckpts(&self) -> u64 {
        self.shards.iter().map(|r| r.ckpts).sum()
    }

    /// Total autoscale grow events across shards.
    pub fn scale_ups(&self) -> u64 {
        self.shards.iter().map(|r| r.scale_ups).sum()
    }

    /// Total autoscale shrink events across shards.
    pub fn scale_downs(&self) -> u64 {
        self.shards.iter().map(|r| r.scale_downs).sum()
    }

    /// Total wall-clock nanoseconds spent in batched inference across
    /// all shards and levels (worker-side predict + calibrator score).
    pub fn infer_ns(&self) -> u64 {
        self.shards
            .iter()
            .flat_map(|r| r.infer_ns.iter().copied())
            .sum()
    }

    /// JSON encoding (bench baselines, report files).
    pub fn to_json(&self) -> crate::codec::Json {
        use crate::codec::Json;
        let q = self.latency_ms().pcts(&[50.0, 95.0, 99.0]);
        let qd = self.latency_direct_ms().pct(99.0);
        let qf = self.latency_deferred_ms().pct(99.0);
        Json::obj(vec![
            ("shards", Json::Num(self.shards.len() as f64)),
            ("served", Json::Num(self.served() as f64)),
            ("shed", Json::Num(self.shed() as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("throughput", Json::Num(self.throughput())),
            ("p50_ms", Json::Num(q[0])),
            ("p95_ms", Json::Num(q[1])),
            ("p99_ms", Json::Num(q[2])),
            ("p99_direct_ms", Json::Num(qd)),
            ("p99_deferred_ms", Json::Num(qf)),
            ("spec_hits", Json::Num(self.spec_hits() as f64)),
            ("spec_wasted", Json::Num(self.spec_wasted() as f64)),
            (
                "queue_depth",
                Json::Arr(
                    self.queue_depth().iter().map(|&d| Json::Num(d as f64)).collect(),
                ),
            ),
            ("accuracy", Json::Num(self.accuracy())),
            ("llm_calls", Json::Num(self.llm_calls() as f64)),
            ("max_snapshot_lag", Json::Num(self.max_snapshot_lag() as f64)),
            ("peak_pending", Json::Num(self.peak_pending as f64)),
            ("resumed", Json::Bool(self.resumed())),
            ("ckpts", Json::Num(self.ckpts() as f64)),
            ("scale_ups", Json::Num(self.scale_ups() as f64)),
            ("scale_downs", Json::Num(self.scale_downs() as f64)),
            ("infer_ns", Json::Num(self.infer_ns() as f64)),
            (
                "per_shard",
                Json::Arr(self.shards.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// The front dispatcher: builds N router shards, wires the cross-shard
/// annotation broadcast and the shared admission budget, hashes
/// requests to shards, and merges reports.
pub struct ShardFront {
    servers: Vec<Server>,
    gate: Arc<AdmissionGate>,
    resume_cursor: u64,
}

impl ShardFront {
    /// Build `serve_cfg.shard.shards` routers. Shard 0 keeps
    /// `cfg.seed` untouched, so the 1-shard front is bit-for-bit the
    /// single [`Server`]; further shards decorrelate their RNG streams
    /// by folding the shard index into the seed.
    pub fn new(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        serve_cfg: ServeConfig,
        artifacts_dir: &str,
    ) -> Result<Self> {
        Self::with_ckpt(cfg, classes, expert, serve_cfg, artifacts_dir, None)
    }

    /// [`ShardFront::new`] plus durable checkpointing: with
    /// [`CkptOptions`], every shard deposits its state into a shared
    /// [`CkptSink`] (cadence + graceful shutdown), and when
    /// `opts.resume` is set the front first restores the newest valid
    /// checkpoint — each shard continuing its own learner trajectory —
    /// and exposes the stream position to resubmit from as
    /// [`ShardFront::resume_cursor`].
    pub fn with_ckpt(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        serve_cfg: ServeConfig,
        artifacts_dir: &str,
        ckpt: Option<CkptOptions>,
    ) -> Result<Self> {
        let n = serve_cfg.shard.shards;
        if n == 0 {
            return Err(Error::Config("shards must be positive".into()));
        }
        let mut states: Vec<Option<ShardState>> = (0..n).map(|_| None).collect();
        let mut resume_cursor = 0;
        let sink = match &ckpt {
            Some(opts) => {
                if let Some(mode) = opts.resume {
                    if let Some(loaded) = ckpt::load_latest(&opts.dir, mode, n)? {
                        // Shape drift (level count/kind/classes vs the
                        // config being started) follows the same policy
                        // as every other checkpoint defect: strict
                        // errors, best-effort falls back to fresh.
                        let shape = loaded
                            .iter()
                            .try_for_each(|s| s.check_config(&cfg, classes));
                        match (shape, mode) {
                            (Err(e), ckpt::ResumeMode::Strict) => return Err(e),
                            (Err(_), ckpt::ResumeMode::BestEffort) => {}
                            (Ok(()), _) => {
                                // The global resume point is the most
                                // conservative shard cursor: shards that
                                // checkpointed further ahead re-observe
                                // a few requests (at-least-once across
                                // the restart).
                                resume_cursor =
                                    loaded.iter().map(|s| s.cursor).min().unwrap_or(0);
                                for s in loaded {
                                    let i = s.shard;
                                    states[i] = Some(s);
                                }
                            }
                        }
                    }
                }
                Some(CkptSink::create(&opts.dir, n)?)
            }
            None => None,
        };
        let gate = Arc::new(AdmissionGate::new(serve_cfg.max_pending));
        let mut servers = Vec::with_capacity(n);
        for (i, state) in states.iter_mut().enumerate() {
            let mut shard_cfg = cfg.clone();
            shard_cfg.seed = cfg.seed ^ ((i as u64) * 0x51A2_D007);
            let mut srv = match state.take() {
                Some(s) => Server::resume(
                    shard_cfg,
                    classes,
                    expert.clone(),
                    serve_cfg,
                    artifacts_dir,
                    s,
                )?,
                None => Server::new(
                    shard_cfg,
                    classes,
                    expert.clone(),
                    serve_cfg,
                    artifacts_dir,
                )?,
            };
            srv.set_admission(gate.clone());
            if let Some(sink) = &sink {
                srv.attach_ckpt(sink.clone(), i);
            }
            servers.push(srv);
        }
        // Wire the annotation broadcast: every shard gets a sender to
        // every peer and its own inbox.
        if n > 1 && serve_cfg.shard.sync_interval > 0 {
            let links: Vec<(Sender<SyncBatch>, Receiver<SyncBatch>)> =
                (0..n).map(|_| channel()).collect();
            let senders: Vec<Sender<SyncBatch>> =
                links.iter().map(|(tx, _)| tx.clone()).collect();
            for (i, (_, inbox)) in links.into_iter().enumerate() {
                let peers: Vec<Sender<SyncBatch>> = senders
                    .iter()
                    .enumerate()
                    .filter(|&(j, _)| j != i)
                    .map(|(_, tx)| tx.clone())
                    .collect();
                servers[i].wire_sync(peers, inbox);
            }
        }
        Ok(ShardFront { servers, gate, resume_cursor })
    }

    /// Stream position to resubmit from after a restore: every request
    /// id below this was fully absorbed by its shard before the
    /// checkpoint (0 for fresh starts). Ids at or above it must be
    /// offered again.
    pub fn resume_cursor(&self) -> u64 {
        self.resume_cursor
    }

    /// Number of shards behind the front.
    pub fn shards(&self) -> usize {
        self.servers.len()
    }

    /// Set the cost-pressure knob on every shard.
    pub fn set_threshold_scale(&mut self, s: f64) {
        for srv in &mut self.servers {
            srv.set_threshold_scale(s);
        }
    }

    /// Arm fault injection on one shard.
    pub fn inject_chaos(&mut self, shard: usize, chaos: Chaos) {
        self.servers[shard].inject_chaos(chaos);
    }

    /// Serve a stream: dispatch `rx` across the shards by request-id
    /// hash, fan all responses into `tx`, and aggregate the reports.
    pub fn serve(
        self,
        rx: Receiver<Request>,
        tx: Sender<Response>,
    ) -> Result<ShardReport> {
        let t0 = std::time::Instant::now();
        let ShardFront { servers, gate, resume_cursor: _ } = self;
        let n = servers.len();
        let mut shard_txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for srv in servers {
            let (shard_tx, shard_rx) = channel::<Request>();
            let resp_tx = tx.clone();
            shard_txs.push(shard_tx);
            handles.push(crate::sync::thread::spawn(move || srv.serve(shard_rx, resp_tx)));
        }
        drop(tx);
        // Dispatch on this thread: the front is pure routing (hash +
        // channel send), so it never becomes the serialization point
        // the per-shard routers are.
        for req in rx.iter() {
            let s = shard_of(req.id, n);
            if shard_txs[s].send(req).is_err() {
                // The shard died; its join below surfaces the error.
                break;
            }
        }
        drop(shard_txs); // shards drain and stop
        let mut reports = Vec::with_capacity(n);
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(report)) => reports.push(report),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or(Some(Error::Worker("shard thread panicked".into())))
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(ShardReport {
            shards: reports,
            wall_secs: t0.elapsed().as_secs_f64(),
            peak_pending: gate.peak(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_spreads_sequential_ids() {
        let shards = 4;
        let mut counts = vec![0usize; shards];
        for id in 0..4000u64 {
            counts[shard_of(id, shards)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!(
                (700..=1300).contains(&c),
                "shard {s} got {c}/4000 — hash is not spreading"
            );
        }
        assert_eq!(shard_of(123, 1), 0);
    }

    #[test]
    fn report_aggregates_across_shards() {
        fn report(served: usize, acc: f64, lat: &[f64]) -> ServeReport {
            let mut p = Percentiles::new();
            for &x in lat {
                p.push(x);
            }
            let mut direct = Percentiles::new();
            direct.push(lat[0]);
            let mut deferred = Percentiles::new();
            deferred.push(lat[1]);
            ServeReport {
                served,
                shed: 1,
                latency_ms: p,
                latency_direct_ms: direct,
                latency_deferred_ms: deferred,
                wall_secs: 2.0,
                throughput: served as f64 / 2.0,
                handled: vec![served],
                accuracy: acc,
                llm_calls: 3,
                restarts: vec![0],
                restart_cap: 16,
                warm_respawns: vec![0],
                snapshots: vec![2],
                snapshot_lag: vec![served as u64],
                replica_jobs: vec![vec![served as u64]],
                peak_pending: 1,
                resumed: false,
                ckpts: 0,
                ckpt_aborts: 0,
                scale_ups: 2,
                scale_downs: 1,
                final_betas: vec![0.5],
                train_batches: vec![1],
                calib_batches: vec![1],
                infer_ns: vec![served as u64 * 10],
                spec_hits: 2,
                spec_wasted: 1,
                queue_depth: vec![served / 100, 1],
            }
        }
        let r = ShardReport {
            shards: vec![report(100, 0.9, &[1.0, 2.0]), report(300, 0.7, &[3.0, 4.0])],
            wall_secs: 2.0,
            peak_pending: 7,
        };
        assert_eq!(r.served(), 400);
        assert_eq!(r.shed(), 2);
        assert_eq!(r.llm_calls(), 6);
        assert!((r.accuracy() - 0.75).abs() < 1e-12, "serve-weighted: {}", r.accuracy());
        assert_eq!(r.latency_ms().len(), 4);
        assert_eq!(r.max_snapshot_lag(), 300);
        assert!(!r.resumed());
        assert_eq!(r.ckpts(), 0);
        assert_eq!(r.scale_ups(), 4);
        assert_eq!(r.scale_downs(), 2);
        assert_eq!(r.infer_ns(), 4000);
        assert_eq!(r.spec_hits(), 4);
        assert_eq!(r.spec_wasted(), 2);
        // Element-wise max across shards, not a sum.
        assert_eq!(r.queue_depth(), vec![3, 1]);
        assert_eq!(r.latency_direct_ms().len(), 2);
        assert_eq!(r.latency_deferred_ms().len(), 2);
        let v = crate::codec::parse(&r.to_json().to_string_compact()).unwrap();
        assert_eq!(v.get("served").unwrap().as_usize(), Some(400));
        assert_eq!(v.get("peak_pending").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("spec_hits").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("queue_depth").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("resumed").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("per_shard").unwrap().as_arr().unwrap().len(), 2);
    }
}
