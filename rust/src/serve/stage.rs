//! Bounded per-level *stage queues* — the pipelined execution path
//! (DESIGN.md §13).
//!
//! With `ServeConfig::pipeline` on, a request deferred from level k to
//! level k+1 (and any speculative copy running one level further
//! ahead) does not wait for the next batch-deadline sweep: it lands in
//! the destination level's `StageQueue` and is dispatched the moment a
//! pool replica frees up. That is what overlaps L0 inference for batch
//! N with L1 inference for batch N−1 and closes the per-level
//! round-trip gap for deferred requests.
//!
//! The queue is *bounded* ([`ServeConfig::stage_queue_depth`]) so a
//! slow deep level cannot accumulate unbounded router state:
//! [`StageQueue::push`] hands an overflowing job back to the caller,
//! who routes a **deferred** job to the regular batcher (backpressure
//! without loss) and drops a **speculative** one (it was optional
//! work). Cancelled speculation is removed in place
//! ([`StageQueue::remove_spec`]) so a kept request's discarded copy
//! never reaches a worker.
//!
//! This module is deliberately clock-free — stage jobs are due the
//! instant a replica is free, so there is no deadline to measure — and
//! holds no synchronization of its own (the router owns it
//! single-threaded). It is in scope for `ocl-lint`'s `determinism`
//! rule (alongside `serve/ckpt.rs`) and, like every serve module, the
//! `sync-funnel` rule.
//!
//! [`ServeConfig::pipeline`]: crate::config::ServeConfig::pipeline
//! [`ServeConfig::stage_queue_depth`]: crate::config::ServeConfig::stage_queue_depth

use std::collections::VecDeque;

use super::Job;

/// One level's bounded stage queue (see module docs).
pub(crate) struct StageQueue {
    jobs: VecDeque<Job>,
    cap: usize,
    peak: usize,
}

impl StageQueue {
    /// A stage queue admitting at most `cap` queued jobs.
    pub(crate) fn new(cap: usize) -> Self {
        StageQueue { jobs: VecDeque::new(), cap, peak: 0 }
    }

    /// Enqueue for immediate dispatch. On overflow the job is handed
    /// back (`Some`) — the caller decides between batcher fallback
    /// (deferred work) and dropping (speculative work).
    pub(crate) fn push(&mut self, job: Job) -> Option<Job> {
        if self.jobs.len() >= self.cap {
            return Some(job);
        }
        self.jobs.push_back(job);
        self.peak = self.peak.max(self.jobs.len());
        None
    }

    /// Drain up to `max` jobs in FIFO order for one dispatch.
    pub(crate) fn take(&mut self, max: usize) -> Vec<Job> {
        let take = self.jobs.len().min(max);
        self.jobs.drain(..take).collect()
    }

    /// Remove a cancelled speculative copy of `req_id` before it
    /// reaches a worker. Only speculative jobs are eligible — a real
    /// deferred job with the same id must keep riding the queue.
    pub(crate) fn remove_spec(&mut self, req_id: u64) {
        self.jobs.retain(|j| !(j.spec && j.req_id == req_id));
    }

    /// Jobs currently queued.
    pub(crate) fn len(&self) -> usize {
        self.jobs.len()
    }

    /// True when nothing is queued (barrier-quiescence check).
    pub(crate) fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Largest queue depth ever observed (`ServeReport::queue_depth`).
    pub(crate) fn peak(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Pipeline;
    use crate::sync::Arc;

    fn job(id: u64, spec: bool) -> Job {
        Job {
            req_id: id,
            probe: false,
            spec,
            f: Arc::new(Pipeline::default().featurize("doc")),
            enq: std::time::Instant::now(),
        }
    }

    #[test]
    fn fifo_order_and_bounded_overflow() {
        let mut q = StageQueue::new(2);
        assert!(q.push(job(1, false)).is_none());
        assert!(q.push(job(2, false)).is_none());
        // Overflow hands the job back instead of growing or dropping.
        let back = q.push(job(3, false)).expect("overflow must return the job");
        assert_eq!(back.req_id, 3);
        assert_eq!(q.len(), 2);
        let batch = q.take(8);
        assert_eq!(batch.iter().map(|j| j.req_id).collect::<Vec<_>>(), vec![1, 2]);
        assert!(q.is_empty());
        // take() respects the batch bound.
        assert!(q.push(job(4, false)).is_none());
        assert!(q.push(job(5, false)).is_none());
        assert_eq!(q.take(1).len(), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn remove_spec_only_touches_speculative_copies() {
        let mut q = StageQueue::new(8);
        q.push(job(7, true));
        q.push(job(7, false)); // a real deferred job sharing the id
        q.push(job(8, true));
        q.remove_spec(7);
        let left: Vec<(u64, bool)> =
            q.take(8).iter().map(|j| (j.req_id, j.spec)).collect();
        assert_eq!(left, vec![(7, false), (8, true)]);
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut q = StageQueue::new(4);
        q.push(job(1, false));
        q.push(job(2, false));
        q.push(job(3, false));
        q.take(8);
        q.push(job(4, false));
        assert_eq!(q.peak(), 3, "peak survives the drain");
    }
}
