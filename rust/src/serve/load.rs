//! Open-loop load generation + SLO assertions for the serve layer.
//!
//! **Why open-loop:** a closed-loop driver (send → wait for the reply →
//! send the next) lets a slow server throttle its own offered load, so
//! measured latency hides queueing delay precisely when the system is
//! saturating — the classic *coordinated omission* failure. The
//! generator here precomputes the whole arrival schedule from the
//! configured process and submits on that clock no matter how the
//! server is doing; overload then shows up honestly as queue growth,
//! shed responses, and p99 inflation (see DESIGN.md §9).
//!
//! Arrival processes are driven by [`crate::prng::Rng`], so a load run
//! is replayable bit-for-bit from its seed.

use crate::sync::mpsc::Sender;
use crate::sync::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::Sample;
use crate::error::{Error, Result};
use crate::prng::Rng;
use crate::serve::Request;
use crate::util::Percentiles;

/// An open-loop arrival process (rates in requests/second).
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Stationary Poisson arrivals at `rate`.
    Poisson {
        /// Mean arrival rate.
        rate: f64,
    },
    /// Poisson arrivals whose rate ramps linearly from `start` to `end`
    /// over the request sequence (capacity-walk runs).
    Ramp {
        /// Rate at the first request.
        start: f64,
        /// Rate at the last request.
        end: f64,
    },
    /// Square-wave bursts: `peak` for the first `duty` fraction of each
    /// `period`, `base` for the rest (batcher/backpressure stress).
    Burst {
        /// Off-burst rate.
        base: f64,
        /// In-burst rate.
        peak: f64,
        /// Burst cycle length.
        period: Duration,
        /// Fraction of the period spent at `peak`, in (0, 1).
        duty: f64,
    },
}

impl Arrival {
    /// Instantaneous rate at request-fraction `frac` (k/n) and absolute
    /// schedule time `t_secs`.
    fn rate_at(&self, frac: f64, t_secs: f64) -> f64 {
        match *self {
            Arrival::Poisson { rate } => rate,
            Arrival::Ramp { start, end } => start + (end - start) * frac.clamp(0.0, 1.0),
            Arrival::Burst { base, peak, period, duty } => {
                let p = period.as_secs_f64().max(1e-9);
                let phase = (t_secs % p) / p;
                if phase < duty {
                    peak
                } else {
                    base
                }
            }
        }
    }

    /// Precompute `n` absolute arrival offsets from t=0. The schedule
    /// is fixed before the run starts — that is what makes the loop
    /// open: send times never react to server progress.
    pub fn schedule(&self, n: usize, rng: &mut Rng) -> Vec<Duration> {
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            let rate = self.rate_at(k as f64 / n.max(1) as f64, t).max(1e-9);
            t += rng.exp(rate);
            out.push(Duration::from_secs_f64(t));
        }
        out
    }
}

/// Submit `samples` as [`Request`]s on the arrival schedule from a
/// background thread; returns the count actually submitted (stops
/// early only if the server hangs up). Request ids are the sample
/// positions, so exactly-once accounting is a sort away.
pub fn drive(
    samples: Vec<Sample>,
    arrival: Arrival,
    seed: u64,
    tx: Sender<Request>,
) -> JoinHandle<usize> {
    drive_from(samples, arrival, seed, tx, 0)
}

/// [`drive`] with request ids starting at `first_id` instead of 0 —
/// the checkpoint-resume driver: a restored run resubmits the stream
/// tail with its *original* positions, so shard hashing and the
/// server's stream cursor line up with the interrupted run.
pub fn drive_from(
    samples: Vec<Sample>,
    arrival: Arrival,
    seed: u64,
    tx: Sender<Request>,
    first_id: u64,
) -> JoinHandle<usize> {
    crate::sync::thread::spawn(move || {
        let mut rng = Rng::new(seed);
        let schedule = arrival.schedule(samples.len(), &mut rng);
        let t0 = Instant::now();
        let mut sent = 0usize;
        for (i, (s, due)) in samples.iter().zip(&schedule).enumerate() {
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                crate::sync::thread::sleep(wait);
            }
            let ok = tx
                .send(Request {
                    id: first_id + i as u64,
                    text: s.text.clone(),
                    truth: s.label,
                    sample: s.clone(),
                })
                .is_ok();
            if !ok {
                break;
            }
            sent += 1;
        }
        sent
    })
}

/// Latency service-level objective: p50/p99 bounds in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct Slo {
    /// Median bound.
    pub p50_ms: f64,
    /// Tail bound.
    pub p99_ms: f64,
}

impl Slo {
    /// Assert the SLO against a multi-shard run: the bound applies to
    /// the *merged* latency distribution (union of shard samples), the
    /// only view a client sees — per-shard p99s can each pass while the
    /// union fails when one shard carries the tail.
    pub fn check_sharded(&self, report: &crate::serve::shard::ShardReport) -> Result<()> {
        self.check(&report.latency_ms())
    }

    /// Assert the SLO against a latency distribution; the error names
    /// the violated bound ([`Error::Slo`]).
    pub fn check(&self, latency_ms: &Percentiles) -> Result<()> {
        let q = latency_ms.pcts(&[50.0, 99.0]);
        if q[0] > self.p50_ms {
            return Err(Error::Slo(format!(
                "p50 {:.2} ms > bound {:.2} ms",
                q[0], self.p50_ms
            )));
        }
        if q[1] > self.p99_ms {
            return Err(Error::Slo(format!(
                "p99 {:.2} ms > bound {:.2} ms",
                q[1], self.p99_ms
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_matches_rate_and_is_deterministic() {
        let arr = Arrival::Poisson { rate: 1000.0 };
        let n = 8000;
        let a = arr.schedule(n, &mut Rng::new(5));
        let b = arr.schedule(n, &mut Rng::new(5));
        assert_eq!(a, b, "same seed → same schedule");
        // monotone non-decreasing offsets
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // mean inter-arrival ≈ 1/rate (±5%)
        let total = a.last().unwrap().as_secs_f64();
        let mean_gap = total / n as f64;
        assert!(
            (mean_gap * 1000.0 - 1.0).abs() < 0.05,
            "mean gap {mean_gap} at rate 1000"
        );
    }

    #[test]
    fn ramp_accelerates() {
        let arr = Arrival::Ramp { start: 100.0, end: 10_000.0 };
        let s = arr.schedule(4000, &mut Rng::new(9));
        // The first quarter must span much more time than the last.
        let q = s.len() / 4;
        let first = s[q].as_secs_f64();
        let last = s[s.len() - 1].as_secs_f64() - s[s.len() - 1 - q].as_secs_f64();
        assert!(
            first > 3.0 * last,
            "ramp did not accelerate: first-quarter {first}s, last-quarter {last}s"
        );
    }

    #[test]
    fn burst_alternates_density() {
        let arr = Arrival::Burst {
            base: 50.0,
            peak: 5000.0,
            period: Duration::from_millis(100),
            duty: 0.5,
        };
        let s = arr.schedule(3000, &mut Rng::new(11));
        // Count arrivals in-burst vs off-burst phases.
        let (mut hot, mut cold) = (0usize, 0usize);
        for d in &s {
            let phase = (d.as_secs_f64() % 0.1) / 0.1;
            if phase < 0.5 {
                hot += 1;
            } else {
                cold += 1;
            }
        }
        assert!(
            hot > 10 * cold.max(1),
            "bursts not visible: {hot} in-burst vs {cold} off-burst"
        );
    }

    #[test]
    fn slo_check_flags_the_right_bound() {
        let mut lat = Percentiles::new();
        for i in 0..100 {
            lat.push(i as f64); // p50 ≈ 50, p99 ≈ 99
        }
        assert!(Slo { p50_ms: 60.0, p99_ms: 120.0 }.check(&lat).is_ok());
        let e = Slo { p50_ms: 10.0, p99_ms: 120.0 }.check(&lat).unwrap_err();
        assert!(e.to_string().contains("p50"), "{e}");
        let e = Slo { p50_ms: 60.0, p99_ms: 80.0 }.check(&lat).unwrap_err();
        assert!(e.to_string().contains("p99"), "{e}");
    }
}
