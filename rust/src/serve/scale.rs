//! Queue-depth autoscaling for the per-level replica pools.
//!
//! The serve loop already tracks, per level, exactly the signals an
//! autoscaler needs: live queue depth (stage queue + batch queue),
//! snapshot lag, and per-worker `infer_ns`. This module turns the
//! depth signal into grow/shrink decisions for `replicas_per_level` at
//! runtime, under three hard rules:
//!
//! * **Bounds.** Replica count never leaves
//!   `[replicas_min, replicas_max]` (`ServeConfig::builder()` knobs,
//!   `--replicas-min/--replicas-max` on the CLI).
//! * **The learner authority is never scaled away.** Worker 0 owns the
//!   training trajectory; scale-down only ever removes the
//!   highest-index replica, and only when it has no batch in flight.
//!   (`mc::models::ScaleSpec` model-checks exactly this rule.)
//! * **No wall clock.** Hysteresis is counted in *observations*
//!   (dispatch sweeps), not seconds — the controller is a pure
//!   deterministic function of its input sequence, so autoscaled runs
//!   replay exactly and the module sits inside `ocl-lint`'s
//!   determinism scope.
//!
//! Hysteresis shape: a level must look overloaded (queue depth ≥
//! `up_depth` per replica) for `up_after` consecutive observations
//! before growing, and idle (depth ≤ `down_depth` per replica) for
//! `down_after` consecutive observations before shrinking; after any
//! scale event the controller holds for `cooldown` observations so the
//! pool's new capacity can drain the backlog before being re-judged.
//! Scale events are counted in `ServeReport::{scale_ups, scale_downs}`.

/// Hysteresis + bounds knobs for one level's [`ScaleController`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ScalePolicy {
    /// Floor on replicas (≥ 1: the authority itself).
    pub min_replicas: usize,
    /// Ceiling on replicas.
    pub max_replicas: usize,
    /// Per-replica queue depth considered overloaded.
    pub up_depth: usize,
    /// Per-replica queue depth considered idle.
    pub down_depth: usize,
    /// Consecutive overloaded observations before growing.
    pub up_after: u64,
    /// Consecutive idle observations before shrinking.
    pub down_after: u64,
    /// Observations held after any scale event.
    pub cooldown: u64,
}

/// Overloaded threshold default: one full dispatch batch queued per
/// replica means the pool is a whole sweep behind.
pub const DEFAULT_UP_DEPTH: usize = 8;
/// Idle threshold default: an empty queue.
pub const DEFAULT_DOWN_DEPTH: usize = 0;
/// Grow after this many consecutive overloaded sweeps.
pub const DEFAULT_UP_AFTER: u64 = 4;
/// Shrink after this many consecutive idle sweeps — deliberately slow,
/// so bursty streams don't thrash capacity.
pub const DEFAULT_DOWN_AFTER: u64 = 64;
/// Post-event hold, in sweeps.
pub const DEFAULT_COOLDOWN: u64 = 16;

impl ScalePolicy {
    /// Policy with default hysteresis over `[min, max]` replicas.
    /// `up_depth` is derived from the dispatch batch size so "one full
    /// batch queued per replica" means overloaded regardless of config.
    pub fn bounded(min_replicas: usize, max_replicas: usize, batch_max: usize) -> Self {
        ScalePolicy {
            min_replicas: min_replicas.max(1),
            max_replicas: max_replicas.max(min_replicas.max(1)),
            up_depth: batch_max.max(1),
            down_depth: DEFAULT_DOWN_DEPTH,
            up_after: DEFAULT_UP_AFTER,
            down_after: DEFAULT_DOWN_AFTER,
            cooldown: DEFAULT_COOLDOWN,
        }
    }
}

/// One observation's verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Add one replica.
    Up,
    /// Remove the highest-index idle replica (never the authority).
    Down,
    /// Do nothing this sweep.
    Hold,
}

/// Per-level hysteresis state machine. Feed it one
/// `(queue_depth, replicas)` observation per dispatch sweep; it emits
/// at most one scale event per `cooldown` window and never a decision
/// that would leave `[min_replicas, max_replicas]`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ScaleController {
    policy: ScalePolicy,
    high_streak: u64,
    low_streak: u64,
    cool: u64,
}

impl ScaleController {
    /// Fresh controller (no streaks, no cooldown).
    pub fn new(policy: ScalePolicy) -> Self {
        ScaleController { policy, high_streak: 0, low_streak: 0, cool: 0 }
    }

    /// The policy this controller enforces.
    pub fn policy(&self) -> &ScalePolicy {
        &self.policy
    }

    /// Observe one sweep's queue depth at the current replica count.
    pub fn decide(&mut self, queue_depth: usize, replicas: usize) -> ScaleDecision {
        // Bounds enforcement dominates hysteresis: a pool outside its
        // configured range (e.g. after a config-driven restart) walks
        // back in immediately.
        if replicas < self.policy.min_replicas {
            return ScaleDecision::Up;
        }
        if replicas > self.policy.max_replicas {
            return ScaleDecision::Down;
        }
        if self.cool > 0 {
            self.cool -= 1;
            return ScaleDecision::Hold;
        }
        let r = replicas.max(1);
        if queue_depth >= self.policy.up_depth.saturating_mul(r) {
            self.high_streak += 1;
            self.low_streak = 0;
        } else if queue_depth <= self.policy.down_depth.saturating_mul(r) {
            self.low_streak += 1;
            self.high_streak = 0;
        } else {
            self.high_streak = 0;
            self.low_streak = 0;
        }
        if self.high_streak >= self.policy.up_after && replicas < self.policy.max_replicas
        {
            self.high_streak = 0;
            self.low_streak = 0;
            self.cool = self.policy.cooldown;
            return ScaleDecision::Up;
        }
        if self.low_streak >= self.policy.down_after
            && replicas > self.policy.min_replicas
        {
            self.high_streak = 0;
            self.low_streak = 0;
            self.cool = self.policy.cooldown;
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(min: usize, max: usize) -> ScaleController {
        ScaleController::new(ScalePolicy {
            min_replicas: min,
            max_replicas: max,
            up_depth: 4,
            down_depth: 0,
            up_after: 2,
            down_after: 3,
            cooldown: 2,
        })
    }

    #[test]
    fn grows_under_sustained_load_within_bounds() {
        let mut c = quick(1, 3);
        let mut replicas = 1usize;
        let mut ups = 0;
        for _ in 0..100 {
            match c.decide(100, replicas) {
                ScaleDecision::Up => {
                    replicas += 1;
                    ups += 1;
                }
                ScaleDecision::Down => panic!("overloaded pool must never shrink"),
                ScaleDecision::Hold => {}
            }
            assert!(replicas <= 3, "must never exceed max");
        }
        assert_eq!(replicas, 3, "sustained overload must reach max");
        assert_eq!(ups, 2);
    }

    #[test]
    fn shrinks_when_idle_but_never_below_min() {
        let mut c = quick(2, 4);
        let mut replicas = 4usize;
        for _ in 0..200 {
            match c.decide(0, replicas) {
                ScaleDecision::Down => replicas -= 1,
                ScaleDecision::Up => panic!("idle pool must never grow"),
                ScaleDecision::Hold => {}
            }
            assert!(replicas >= 2, "must never drop below min");
        }
        assert_eq!(replicas, 2, "sustained idleness must reach min");
    }

    #[test]
    fn single_replica_floor_protects_the_authority() {
        // min defaults to ≥ 1 — an idle pool at one replica holds
        // forever rather than scaling the learner authority away.
        let mut c = quick(1, 2);
        for _ in 0..500 {
            assert_ne!(c.decide(0, 1), ScaleDecision::Down);
        }
    }

    #[test]
    fn hysteresis_needs_streaks_and_respects_cooldown() {
        let mut c = quick(1, 8);
        // Alternating load never builds the streak → never scales.
        for i in 0..100 {
            let depth = if i % 2 == 0 { 100 } else { 1 };
            assert_eq!(c.decide(depth, 1), ScaleDecision::Hold);
        }
        // Sustained load scales once, then the cooldown holds even
        // though the backlog is still high.
        let mut c = quick(1, 8);
        assert_eq!(c.decide(100, 1), ScaleDecision::Hold);
        assert_eq!(c.decide(100, 1), ScaleDecision::Up);
        assert_eq!(c.decide(100, 2), ScaleDecision::Hold);
        assert_eq!(c.decide(100, 2), ScaleDecision::Hold);
        // Cooldown over: streak rebuilds from zero.
        assert_eq!(c.decide(100, 2), ScaleDecision::Hold);
        assert_eq!(c.decide(100, 2), ScaleDecision::Up);
    }

    #[test]
    fn out_of_bounds_replica_counts_walk_back_in() {
        let mut c = quick(2, 3);
        assert_eq!(c.decide(0, 1), ScaleDecision::Up, "below min: grow now");
        assert_eq!(c.decide(100, 5), ScaleDecision::Down, "above max: shrink now");
    }

    #[test]
    fn bounded_policy_clamps_degenerate_inputs() {
        let p = ScalePolicy::bounded(0, 0, 0);
        assert_eq!(p.min_replicas, 1);
        assert_eq!(p.max_replicas, 1);
        assert_eq!(p.up_depth, 1);
    }
}
