//! Durable checkpoint/resume for the serving stack.
//!
//! A process restart used to reset every cascade level to fresh
//! weights, re-paying the LLM demonstration cost the online learner
//! had already amortized — exactly the cost OCL exists to avoid. This
//! module serializes the **full router learner state** to versioned
//! JSON files so a restarted `Server`/`ShardFront` continues the
//! no-regret trajectory instead of starting it over:
//!
//! * per-level model + calibrator [`Snapshot`]s (bit-for-bit, via the
//!   shortest-round-trip f64 printing in `codec::json`),
//! * DAgger β values (their decay state *is* the value — one multiply
//!   per admitted request),
//! * train/calib chunk counters and the per-level trigger cadence
//!   counters (`pendings`/`calib_pendings`), so the next training
//!   trigger fires at exactly the admission it would have,
//! * replay-cache and calibration-cache contents,
//! * the router RNG state, the probe-id allocator, and the cross-shard
//!   annotation sync cursor (`sync_staged`),
//! * cumulative serve counters and the stream cursor, so a resumed
//!   run's `ServeReport` continues the interrupted run's totals.
//!
//! **What is *not* captured:** in-flight batches, queued jobs, and
//! pending (admitted, unanswered) requests. Checkpoints are only
//! taken at *quiescent* points — the cadence checkpoint is a barrier
//! (the router stops admitting, drains, snapshots, resumes) and the
//! shutdown checkpoint happens after the drain — so at every
//! checkpoint the pending set is empty by construction. That is what
//! makes the resumed β/chunk-count trajectory bit-identical to an
//! uninterrupted run (pinned in `tests/test_ckpt.rs`): nothing
//! half-processed needs reconstructing, and the stream cursor is an
//! exact high-water mark.
//!
//! **Atomicity & layout.** Each shard's state is one JSON file written
//! via write-to-temp + rename. A checkpoint *commits* when a manifest
//! (also written atomically) referencing the current file of **every**
//! shard appears; `load_latest` only ever reads through a manifest, so
//! a crash mid-write leaves at worst an orphaned temp file, never a
//! torn checkpoint. Old checkpoints are pruned, keeping the two newest
//! manifests and the files they reference.
//!
//! **Resume semantics.** `shards = 1` resume continues the exact
//! learner trajectory. After a *graceful* shutdown it is also
//! at-most-once per request (the final quiescent cursor covers a
//! contiguous fully-answered prefix); after a SIGKILL, requests
//! answered between the last checkpoint and the kill are re-served —
//! at-least-once across the restart, exactly-once within each run.
//! With multiple shards, each shard checkpoints at its own quiescent
//! instants, so the global resume cursor is the minimum over shards
//! and shards that were ahead re-observe a few requests even on a
//! graceful restart (DESIGN.md §9).

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use crate::sync::{lock_unpoisoned, Arc, Mutex};

use crate::codec::{self, Json};
use crate::config::CascadeConfig;
use crate::error::{Error, Result};
use crate::models::{Featurized, Snapshot};

/// Checkpoint format version (the manifest's `version` field). v2
/// adds a per-shard `epochs` array (each shard file's own deposit
/// sequence number) so rolling restarts are auditable: a manifest can
/// legitimately mix shard files written at different instants, and the
/// epochs say exactly which. v1 manifests (no `epochs`) are still
/// read — the epochs are derived from the file names. Any *other*
/// version is a hard [`Error::Ckpt`], never a silent reinterpret.
pub const CKPT_VERSION: u64 = 2;

/// Oldest manifest version this build still reads.
pub const CKPT_VERSION_MIN: u64 = 1;

/// How `--resume` treats the checkpoint directory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResumeMode {
    /// The newest manifest must exist and fully validate; anything
    /// else (no checkpoint, truncated file, bad version, missing shard
    /// entry) is a hard error.
    Strict,
    /// Walk manifests newest-first and restore the first valid one;
    /// when none validates, fall back to a fresh start. This is the
    /// only mode that silently discards unusable checkpoints.
    BestEffort,
}

impl ResumeMode {
    /// Parse from CLI string.
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "strict" | "require" => Ok(ResumeMode::Strict),
            "best-effort" | "best_effort" => Ok(ResumeMode::BestEffort),
            _ => Err(Error::Usage(format!(
                "unknown resume mode '{s}' (strict|best-effort)"
            ))),
        }
    }
}

/// Checkpoint wiring for `ShardFront::with_ckpt`: where checkpoints
/// live and whether/how to restore from them at startup.
#[derive(Clone, Debug)]
pub struct CkptOptions {
    /// Checkpoint directory (created if missing).
    pub dir: String,
    /// `None` = start fresh but write checkpoints; `Some(mode)` =
    /// restore from the directory first.
    pub resume: Option<ResumeMode>,
}

/// One cascade level's durable state.
#[derive(Clone, Debug, PartialEq)]
pub struct LevelState {
    /// Level-model parameters.
    pub model: Snapshot,
    /// Deferral-calibrator parameters.
    pub calib: Snapshot,
    /// Cumulative 8-sample model-training chunks.
    pub train_chunks: u64,
    /// Cumulative 8-sample calibrator-training chunks.
    pub calib_chunks: u64,
    /// Model-training triggers sent (snapshot publish cadence cursor).
    pub train_sends: u64,
    /// Annotations since the last model-training trigger.
    pub pending: usize,
    /// Calibration examples since the last calibrator trigger.
    pub calib_pending: usize,
    /// Replay cache contents, oldest → newest.
    pub cache: Vec<(Arc<Featurized>, usize)>,
    /// Calibration cache contents, oldest → newest.
    pub calib_cache: Vec<(Vec<f32>, f32)>,
}

/// Everything one router shard needs to continue its trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardState {
    /// Which shard produced this state.
    pub shard: usize,
    /// Stream high-water mark: every request id below this has been
    /// fully absorbed (quiescent checkpoints make this exact).
    pub cursor: u64,
    /// Router RNG words (xoshiro256**).
    pub rng_s: [u64; 4],
    /// Cached Box–Muller half, if any.
    pub rng_cached: Option<f64>,
    /// Per-level DAgger β values (pre-decay for the next admission).
    pub betas: Vec<f64>,
    /// Cost-pressure knob.
    pub threshold_scale: f64,
    /// Probe-id allocator position.
    pub probe_seq: u64,
    /// Annotations staged for the cross-shard broadcast but not yet
    /// sent (the annotation sync cursor).
    pub sync_staged: Vec<(Arc<Featurized>, usize)>,
    /// Cumulative requests served.
    pub served: usize,
    /// Cumulative requests shed.
    pub shed: usize,
    /// Cumulative correct answers (accuracy numerator).
    pub correct: usize,
    /// Cumulative expert calls.
    pub llm_calls: u64,
    /// Cumulative per-level handled counts (last = expert).
    pub handled: Vec<usize>,
    /// Per-level durable state.
    pub levels: Vec<LevelState>,
}

fn bad(what: &str) -> Error {
    Error::Ckpt(format!("bad shard state: {what}"))
}

/// Encode a `(feature-index, label)` pair against the intern table.
fn fref(
    f: &Arc<Featurized>,
    y: usize,
    intern: &mut Vec<Json>,
    ids: &mut HashMap<usize, usize>,
) -> Json {
    let key = Arc::as_ptr(f) as usize;
    let idx = *ids.entry(key).or_insert_with(|| {
        intern.push(f.to_json());
        intern.len() - 1
    });
    Json::Arr(vec![Json::Num(idx as f64), Json::Num(y as f64)])
}

/// Decode a `(feature-index, label)` pair against the intern table.
fn unfref(v: &Json, features: &[Arc<Featurized>]) -> Result<(Arc<Featurized>, usize)> {
    let pair = v.as_arr().ok_or_else(|| bad("cache entry"))?;
    if pair.len() != 2 {
        return Err(bad("cache entry arity"));
    }
    let idx = pair[0].as_usize().ok_or_else(|| bad("cache feature index"))?;
    let y = pair[1].as_usize().ok_or_else(|| bad("cache label"))?;
    let f = features
        .get(idx)
        .ok_or_else(|| bad("cache feature index out of range"))?;
    Ok((f.clone(), y))
}

impl ShardState {
    /// JSON encoding. Featurized queries are interned: the same
    /// annotation lives in every level's replay cache (and possibly
    /// `sync_staged`), so each unique query is written once and caches
    /// store indices into the shared `features` table.
    pub fn to_json(&self) -> Json {
        let mut features: Vec<Json> = Vec::new();
        let mut ids: HashMap<usize, usize> = HashMap::new();
        let levels: Vec<Json> = self
            .levels
            .iter()
            .map(|l| {
                let cache: Vec<Json> = l
                    .cache
                    .iter()
                    .map(|(f, y)| fref(f, *y, &mut features, &mut ids))
                    .collect();
                let calib_cache: Vec<Json> = l
                    .calib_cache
                    .iter()
                    .map(|(p, z)| {
                        Json::Arr(vec![Json::f32_arr(p), Json::Num(*z as f64)])
                    })
                    .collect();
                Json::obj(vec![
                    ("model", l.model.to_json()),
                    ("calib", l.calib.to_json()),
                    ("train_chunks", Json::Num(l.train_chunks as f64)),
                    ("calib_chunks", Json::Num(l.calib_chunks as f64)),
                    ("train_sends", Json::Num(l.train_sends as f64)),
                    ("pending", Json::Num(l.pending as f64)),
                    ("calib_pending", Json::Num(l.calib_pending as f64)),
                    ("cache", Json::Arr(cache)),
                    ("calib_cache", Json::Arr(calib_cache)),
                ])
            })
            .collect();
        let staged: Vec<Json> = self
            .sync_staged
            .iter()
            .map(|(f, y)| fref(f, *y, &mut features, &mut ids))
            .collect();
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("cursor", Json::Num(self.cursor as f64)),
            (
                "rng",
                Json::Arr(self.rng_s.iter().map(|&w| Json::u64_hex(w)).collect()),
            ),
            (
                "rng_cached",
                match self.rng_cached {
                    Some(z) => Json::Num(z),
                    None => Json::Null,
                },
            ),
            (
                "betas",
                Json::Arr(self.betas.iter().map(|&b| Json::Num(b)).collect()),
            ),
            ("threshold_scale", Json::Num(self.threshold_scale)),
            ("probe_seq", Json::Num(self.probe_seq as f64)),
            ("sync_staged", Json::Arr(staged)),
            ("served", Json::Num(self.served as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("correct", Json::Num(self.correct as f64)),
            ("llm_calls", Json::Num(self.llm_calls as f64)),
            (
                "handled",
                Json::Arr(self.handled.iter().map(|&h| Json::Num(h as f64)).collect()),
            ),
            ("features", Json::Arr(features)),
            ("levels", Json::Arr(levels)),
        ])
    }

    /// Decode from [`ShardState::to_json`] output.
    pub fn from_json(v: &Json) -> Result<Self> {
        let features: Vec<Arc<Featurized>> = v
            .require("features")?
            .as_arr()
            .ok_or_else(|| bad("features"))?
            .iter()
            .map(|f| Featurized::from_json(f).map(Arc::new))
            .collect::<Result<_>>()?;
        let levels = v
            .require("levels")?
            .as_arr()
            .ok_or_else(|| bad("levels"))?
            .iter()
            .map(|l| {
                let cache = l
                    .require("cache")?
                    .as_arr()
                    .ok_or_else(|| bad("cache"))?
                    .iter()
                    .map(|e| unfref(e, &features))
                    .collect::<Result<_>>()?;
                let calib_cache = l
                    .require("calib_cache")?
                    .as_arr()
                    .ok_or_else(|| bad("calib_cache"))?
                    .iter()
                    .map(|e| {
                        let pair = e.as_arr().ok_or_else(|| bad("calib entry"))?;
                        if pair.len() != 2 {
                            return Err(bad("calib entry arity"));
                        }
                        let p = pair[0].as_f32_vec().ok_or_else(|| bad("calib probs"))?;
                        let z = pair[1].as_f64().ok_or_else(|| bad("calib z"))? as f32;
                        Ok((p, z))
                    })
                    .collect::<Result<_>>()?;
                Ok(LevelState {
                    model: Snapshot::from_json(l.require("model")?)?,
                    calib: Snapshot::from_json(l.require("calib")?)?,
                    train_chunks: num_u64(l, "train_chunks")?,
                    calib_chunks: num_u64(l, "calib_chunks")?,
                    train_sends: num_u64(l, "train_sends")?,
                    pending: num_usize(l, "pending")?,
                    calib_pending: num_usize(l, "calib_pending")?,
                    cache,
                    calib_cache,
                })
            })
            .collect::<Result<Vec<LevelState>>>()?;
        let rng_words: Vec<u64> = v
            .require("rng")?
            .as_arr()
            .ok_or_else(|| bad("rng"))?
            .iter()
            .map(|w| w.as_u64_hex())
            .collect::<Option<_>>()
            .ok_or_else(|| bad("rng word"))?;
        let rng_s: [u64; 4] =
            rng_words.try_into().map_err(|_| bad("rng word count"))?;
        let rng_cached = match v.require("rng_cached")? {
            Json::Null => None,
            other => Some(other.as_f64().ok_or_else(|| bad("rng_cached"))?),
        };
        let betas = v
            .require("betas")?
            .as_arr()
            .ok_or_else(|| bad("betas"))?
            .iter()
            .map(Json::as_f64)
            .collect::<Option<_>>()
            .ok_or_else(|| bad("beta value"))?;
        Ok(ShardState {
            shard: num_usize(v, "shard")?,
            cursor: num_u64(v, "cursor")?,
            rng_s,
            rng_cached,
            betas,
            threshold_scale: v
                .require("threshold_scale")?
                .as_f64()
                .ok_or_else(|| bad("threshold_scale"))?,
            probe_seq: num_u64(v, "probe_seq")?,
            sync_staged: v
                .require("sync_staged")?
                .as_arr()
                .ok_or_else(|| bad("sync_staged"))?
                .iter()
                .map(|e| unfref(e, &features))
                .collect::<Result<_>>()?,
            served: num_usize(v, "served")?,
            shed: num_usize(v, "shed")?,
            correct: num_usize(v, "correct")?,
            llm_calls: num_u64(v, "llm_calls")?,
            handled: v
                .require("handled")?
                .as_usize_vec()
                .ok_or_else(|| bad("handled"))?,
            levels,
        })
    }

    /// Validate this state against the cascade config it is about to
    /// be restored into — shape drift (level count, model kind, class
    /// count) is a clean error, never a silent partial restore.
    pub fn check_config(&self, cfg: &CascadeConfig, classes: usize) -> Result<()> {
        if self.levels.len() != cfg.levels.len() {
            return Err(Error::Ckpt(format!(
                "checkpoint has {} levels, config wants {}",
                self.levels.len(),
                cfg.levels.len()
            )));
        }
        if self.betas.len() != cfg.levels.len() {
            return Err(Error::Ckpt("β vector length mismatch".into()));
        }
        if self.handled.len() != cfg.levels.len() + 1 {
            return Err(Error::Ckpt("handled vector length mismatch".into()));
        }
        for (i, (l, lc)) in self.levels.iter().zip(&cfg.levels).enumerate() {
            if l.model.kind != lc.model.entry_prefix() || l.model.classes != classes {
                return Err(Error::Ckpt(format!(
                    "level {i}: checkpoint is '{}'/{} classes, config wants '{}'/{}",
                    l.model.kind,
                    l.model.classes,
                    lc.model.entry_prefix(),
                    classes
                )));
            }
        }
        Ok(())
    }
}

fn num_u64(v: &Json, key: &str) -> Result<u64> {
    let f = v
        .require(key)?
        .as_f64()
        .ok_or_else(|| bad(&format!("'{key}' must be a number")))?;
    if f < 0.0 || f.fract() != 0.0 {
        return Err(bad(&format!("'{key}' must be a non-negative integer")));
    }
    Ok(f as u64)
}

fn num_usize(v: &Json, key: &str) -> Result<usize> {
    v.require(key)?
        .as_usize()
        .ok_or_else(|| bad(&format!("'{key}' must be a non-negative integer")))
}

// --- on-disk layout --------------------------------------------------------

fn write_atomic(path: &Path, data: &str) -> Result<()> {
    use std::io::Write;
    let tmp = path.with_extension("tmp");
    let ioerr = |p: &Path, e: std::io::Error| Error::io(p.display().to_string(), e);
    let mut f = fs::File::create(&tmp).map_err(|e| ioerr(&tmp, e))?;
    f.write_all(data.as_bytes()).map_err(|e| ioerr(&tmp, e))?;
    // fsync *before* the rename: without it the rename's metadata can
    // reach disk ahead of the data blocks, and a power loss leaves a
    // committed-looking but torn file — exactly the state the
    // temp+rename dance exists to rule out.
    f.sync_all().map_err(|e| ioerr(&tmp, e))?;
    drop(f);
    fs::rename(&tmp, path).map_err(|e| ioerr(path, e))?;
    // Best-effort directory sync so the rename itself is durable.
    if let Some(parent) = path.parent() {
        if let Ok(d) = fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Trailing `-<seq>.json` sequence number of a checkpoint file name.
fn file_seq(name: &str) -> Option<u64> {
    name.strip_suffix(".json")?.rsplit('-').next()?.parse().ok()
}

fn manifest_name(seq: u64) -> String {
    format!("manifest-{seq:08}.json")
}

/// Best-effort read of a manifest's referenced file list (empty on any
/// defect — pruning then treats the manifest as protecting nothing).
fn manifest_files(dir: &Path, mname: &str) -> Vec<String> {
    fs::read_to_string(dir.join(mname))
        .ok()
        .and_then(|t| codec::parse(&t).ok())
        .and_then(|v| {
            v.get("files").and_then(|arr| arr.as_arr()).map(|arr| {
                arr.iter().filter_map(|f| f.as_str().map(String::from)).collect()
            })
        })
        .unwrap_or_default()
}

/// List `(seq, file name)` of every manifest in `dir`, newest first.
fn list_manifests(dir: &Path) -> Result<Vec<(u64, String)>> {
    let mut out = Vec::new();
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // missing dir = no checkpoints
    };
    for entry in rd.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if name.starts_with("manifest-") {
            if let Some(seq) = file_seq(&name) {
                out.push((seq, name));
            }
        }
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(out)
}

/// The checkpoint writer shared by every shard of one topology.
///
/// Shards deposit their state at their own (quiescent) instants; every
/// deposit atomically replaces that shard's file, and once all shards
/// have deposited at least once each further deposit commits a new
/// manifest covering the current file of every shard.
pub struct CkptSink {
    dir: PathBuf,
    inner: Mutex<SinkInner>,
}

struct SinkInner {
    seq: u64,
    /// Current file name per shard (None until its first deposit).
    latest: Vec<Option<String>>,
    /// Committed manifests: (seq, manifest name, referenced files).
    manifests: Vec<(u64, String, Vec<String>)>,
}

impl CkptSink {
    /// Open (creating if needed) a checkpoint directory for `shards`
    /// shards. Sequence numbering continues past any checkpoints
    /// already on disk, so "newest" stays monotone across restarts —
    /// and manifests already on disk are *adopted* into the prune
    /// list, so the keep-two-newest bound holds across process
    /// restarts, not just within one process's lifetime.
    pub fn create(dir: impl AsRef<Path>, shards: usize) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir).map_err(|e| Error::io(dir.display().to_string(), e))?;
        let mut seq = 0;
        for entry in fs::read_dir(&dir)
            .map_err(|e| Error::io(dir.display().to_string(), e))?
            .flatten()
        {
            if let Some(s) = file_seq(&entry.file_name().to_string_lossy()) {
                seq = seq.max(s);
            }
        }
        // Adopt prior-process manifests, oldest first (the prune order).
        // An unreadable manifest is adopted with no file list: pruning
        // will eventually delete the manifest itself, and any files
        // only it referenced are covered by the superseded-file sweep.
        let mut existing = list_manifests(&dir)?;
        existing.reverse();
        let manifests = existing
            .into_iter()
            .map(|(mseq, mname)| {
                let files = manifest_files(&dir, &mname);
                (mseq, mname, files)
            })
            .collect();
        Ok(Arc::new(CkptSink {
            dir,
            inner: Mutex::new(SinkInner {
                seq,
                latest: vec![None; shards],
                manifests,
            }),
        }))
    }

    /// Checkpoint directory path.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist one shard's state; commits a manifest when every shard
    /// has a current file. Returns whether a manifest was committed.
    ///
    /// In the one-process-per-shard topology (`ocl serve --shard-id`)
    /// every shard process holds its *own* `CkptSink` over the same
    /// directory, so the in-memory view only ever covers this
    /// process's shard. Each deposit therefore first adopts the peers'
    /// on-disk deposits, any peer-committed manifests, and the global
    /// sequence high-water mark — otherwise manifests would never
    /// commit (no single process sees "all shards deposited") and a
    /// shard could garbage-collect a superseded file that a *peer's*
    /// manifest still references. Concurrent deposits can still race
    /// two manifests onto the same sequence number; both cover a full,
    /// valid shard set and `write_atomic`'s rename makes the last one
    /// win, so the newest manifest on disk is always loadable.
    pub fn deposit(&self, shard: usize, state: &ShardState) -> Result<bool> {
        // A poisoned sink lock is recovered, not propagated: the disk
        // is the source of truth and `refresh_from_disk` re-adopts it
        // at the top of every deposit, so whatever in-memory state a
        // panicking depositor left behind is re-derived before use.
        let mut inner = lock_unpoisoned(&self.inner);
        self.refresh_from_disk(&mut inner, shard);
        inner.seq += 1;
        let seq = inner.seq;
        let fname = format!("shard{shard}-{seq:08}.json");
        write_atomic(&self.dir.join(&fname), &state.to_json().to_string_compact())?;
        let old = inner.latest[shard].replace(fname);
        let files: Vec<String> = inner.latest.iter().flatten().cloned().collect();
        let committed = if files.len() == inner.latest.len() {
            // v2: each shard's own deposit epoch rides along, parallel
            // to `files`. Under rolling restarts the per-shard epochs
            // legitimately differ — the array makes that explicit (and
            // auditable) instead of implicit in the file names.
            let epochs: Vec<Json> = files
                .iter()
                .map(|f| Json::Num(file_seq(f).unwrap_or(0) as f64))
                .collect();
            let manifest = Json::obj(vec![
                ("version", Json::Num(CKPT_VERSION as f64)),
                ("seq", Json::Num(seq as f64)),
                ("shards", Json::Num(files.len() as f64)),
                (
                    "files",
                    Json::Arr(files.iter().map(|f| Json::Str(f.clone())).collect()),
                ),
                ("epochs", Json::Arr(epochs)),
            ]);
            let mname = manifest_name(seq);
            write_atomic(&self.dir.join(&mname), &manifest.to_string_pretty())?;
            inner.manifests.push((seq, mname, files));
            self.prune(&mut inner);
            true
        } else {
            false
        };
        // A superseded shard file not referenced by any kept manifest
        // is garbage immediately.
        if let Some(old) = old {
            let referenced = inner
                .manifests
                .iter()
                .any(|(_, _, files)| files.contains(&old));
            if !referenced {
                let _ = fs::remove_file(self.dir.join(old));
            }
        }
        Ok(committed)
    }

    /// Adopt peer shard processes' on-disk state into the in-memory
    /// view: the sequence high-water mark, each *other* shard's newest
    /// deposit (this process is authoritative for its own slot), and
    /// any manifests committed by peers (so the superseded-file sweep
    /// never deletes a file a peer's manifest references).
    fn refresh_from_disk(&self, inner: &mut SinkInner, own: usize) {
        let Ok(rd) = fs::read_dir(&self.dir) else { return };
        let shards = inner.latest.len();
        let mut newest: Vec<Option<(u64, String)>> = vec![None; shards];
        for entry in rd.flatten() {
            let name = entry.file_name().to_string_lossy().to_string();
            let Some(seq) = file_seq(&name) else { continue };
            inner.seq = inner.seq.max(seq);
            let Some(rest) = name.strip_prefix("shard") else { continue };
            let Some((idx, _)) = rest.split_once('-') else { continue };
            let Ok(j) = idx.parse::<usize>() else { continue };
            if j >= shards {
                continue;
            }
            let better = match &newest[j] {
                Some((s, _)) => seq > *s,
                None => true,
            };
            if better {
                newest[j] = Some((seq, name));
            }
        }
        for (j, found) in newest.into_iter().enumerate() {
            if j == own {
                continue;
            }
            if let Some((seq, name)) = found {
                let held = inner.latest[j]
                    .as_deref()
                    .and_then(file_seq)
                    .unwrap_or(0);
                if seq > held {
                    inner.latest[j] = Some(name);
                }
            }
        }
        if let Ok(on_disk) = list_manifests(&self.dir) {
            for (mseq, mname) in on_disk.into_iter().rev() {
                if inner.manifests.iter().any(|(s, _, _)| *s == mseq) {
                    continue;
                }
                let files = manifest_files(&self.dir, &mname);
                inner.manifests.push((mseq, mname, files));
            }
            inner.manifests.sort_by_key(|(s, _, _)| *s);
        }
    }

    /// Keep the two newest manifests (and their files); delete older
    /// manifests and any shard files only they referenced.
    fn prune(&self, inner: &mut SinkInner) {
        while inner.manifests.len() > 2 {
            let (_, mname, files) = inner.manifests.remove(0);
            let keep: Vec<&String> = inner
                .manifests
                .iter()
                .flat_map(|(_, _, fs)| fs.iter())
                .chain(inner.latest.iter().flatten())
                .collect();
            for f in &files {
                if !keep.contains(&f) {
                    let _ = fs::remove_file(self.dir.join(f));
                }
            }
            let _ = fs::remove_file(self.dir.join(mname));
        }
    }
}

/// Restore the newest valid checkpoint from `dir` for a topology of
/// `expected_shards` shards. Returns `Ok(None)` only in
/// [`ResumeMode::BestEffort`] when nothing usable exists — strict mode
/// turns every defect (no checkpoint, truncated file, bad version,
/// missing shard entry, topology mismatch) into a clean [`Error::Ckpt`].
pub fn load_latest(
    dir: impl AsRef<Path>,
    mode: ResumeMode,
    expected_shards: usize,
) -> Result<Option<Vec<ShardState>>> {
    let dir = dir.as_ref();
    let manifests = list_manifests(dir)?;
    if manifests.is_empty() {
        return match mode {
            ResumeMode::Strict => Err(Error::Ckpt(format!(
                "no checkpoint manifest in '{}'",
                dir.display()
            ))),
            ResumeMode::BestEffort => Ok(None),
        };
    }
    for (_, mname) in &manifests {
        match load_manifest(dir, mname, expected_shards) {
            Ok(states) => return Ok(Some(states)),
            // Strict: the newest manifest must be the one we restore —
            // silently sliding back to an older checkpoint would mask
            // corruption and replay more stream than the operator asked
            // for.
            Err(e) if mode == ResumeMode::Strict => return Err(e),
            Err(_) => continue,
        }
    }
    Ok(None) // best-effort: nothing validated → fresh start
}

/// Shard count recorded in the newest manifest of `dir` — how
/// `ocl reshard` discovers the source topology N without being told.
pub fn latest_manifest_shards(dir: impl AsRef<Path>) -> Result<usize> {
    let dir = dir.as_ref();
    let manifests = list_manifests(dir)?;
    let (_, mname) = manifests.first().ok_or_else(|| {
        Error::Ckpt(format!("no checkpoint manifest in '{}'", dir.display()))
    })?;
    let path = dir.join(mname);
    let text = fs::read_to_string(&path)
        .map_err(|e| Error::Ckpt(format!("manifest '{}': {e}", path.display())))?;
    let v = codec::parse(&text)
        .map_err(|e| Error::Ckpt(format!("manifest '{}': {e}", path.display())))?;
    num_usize(&v, "shards")
}

fn load_manifest(dir: &Path, mname: &str, expected_shards: usize) -> Result<Vec<ShardState>> {
    let path = dir.join(mname);
    let text = fs::read_to_string(&path)
        .map_err(|e| Error::Ckpt(format!("manifest '{}': {e}", path.display())))?;
    let v = codec::parse(&text)
        .map_err(|e| Error::Ckpt(format!("manifest '{}': {e}", path.display())))?;
    let version = num_u64(&v, "version")
        .map_err(|_| Error::Ckpt(format!("manifest '{mname}': missing version")))?;
    if !(CKPT_VERSION_MIN..=CKPT_VERSION).contains(&version) {
        return Err(Error::Ckpt(format!(
            "unsupported checkpoint version {version} (this build reads \
             {CKPT_VERSION_MIN}..={CKPT_VERSION})"
        )));
    }
    let shards = num_usize(&v, "shards")?;
    if shards != expected_shards {
        return Err(Error::Ckpt(format!(
            "checkpoint covers {shards} shards, topology wants {expected_shards}"
        )));
    }
    let files = v
        .require("files")
        .map_err(|_| Error::Ckpt(format!("manifest '{mname}': missing files")))?
        .as_arr()
        .ok_or_else(|| Error::Ckpt(format!("manifest '{mname}': files must be an array")))?;
    if files.len() != shards {
        return Err(Error::Ckpt(format!(
            "manifest '{mname}' lists {} shard files for {shards} shards",
            files.len()
        )));
    }
    // v2 integrity: the epochs array must cover every shard and agree
    // with the file it annotates. A short array means the manifest was
    // truncated mid-write (or hand-edited); a disagreeing entry means
    // shard files from *different* checkpoints were spliced together —
    // both are torn states a restore must refuse, not paper over.
    // v1 manifests predate the array; their epochs are simply the file
    // names' sequence numbers, with nothing extra to cross-check.
    if version >= 2 {
        let epochs = v
            .require("epochs")
            .map_err(|_| Error::Ckpt(format!("manifest '{mname}': missing epochs")))?
            .as_arr()
            .ok_or_else(|| {
                Error::Ckpt(format!("manifest '{mname}': epochs must be an array"))
            })?;
        if epochs.len() != shards {
            return Err(Error::Ckpt(format!(
                "manifest '{mname}': truncated epochs array ({} entries for \
                 {shards} shards)",
                epochs.len()
            )));
        }
        for (i, (e, f)) in epochs.iter().zip(files).enumerate() {
            let epoch = e
                .as_f64()
                .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                .map(|x| x as u64);
            let epoch = epoch.ok_or_else(|| {
                Error::Ckpt(format!("manifest '{mname}': epoch {i} must be an integer"))
            })?;
            let from_name = f.as_str().and_then(file_seq);
            if from_name != Some(epoch) {
                return Err(Error::Ckpt(format!(
                    "manifest '{mname}': mixed-epoch shard entry {i} (epoch {epoch} \
                     vs file {:?})",
                    f.as_str().unwrap_or("<non-string>")
                )));
            }
        }
    }
    let mut states: Vec<Option<ShardState>> = (0..shards).map(|_| None).collect();
    for f in files {
        let fname = f
            .as_str()
            .ok_or_else(|| Error::Ckpt(format!("manifest '{mname}': bad file entry")))?;
        let fpath = dir.join(fname);
        let text = fs::read_to_string(&fpath).map_err(|e| {
            Error::Ckpt(format!("missing shard checkpoint '{}': {e}", fpath.display()))
        })?;
        let sv = codec::parse(&text).map_err(|e| {
            Error::Ckpt(format!("shard checkpoint '{}': {e}", fpath.display()))
        })?;
        let state = ShardState::from_json(&sv)?;
        let idx = state.shard;
        if idx >= shards || states[idx].is_some() {
            return Err(Error::Ckpt(format!(
                "manifest '{mname}': shard index {idx} out of range or duplicated"
            )));
        }
        states[idx] = Some(state);
    }
    // Infallible by counting (`files.len() == shards`, no duplicates,
    // every index in range), but surfaced as a typed error anyway.
    states
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            s.ok_or_else(|| {
                Error::Ckpt(format!("manifest '{mname}': shard {i} never placed"))
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Pipeline;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ocl-ckpt-test-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn demo_state(shard: usize, cursor: u64) -> ShardState {
        let p = Pipeline::default();
        let f1 = Arc::new(p.featurize("kw0x001 kw0x002"));
        let f2 = Arc::new(p.featurize("kw1x003"));
        let snap = |kind: &str, n: usize| Snapshot {
            kind: kind.into(),
            classes: 2,
            data: (0..n).map(|i| i as f32 * 0.5).collect(),
        };
        ShardState {
            shard,
            cursor,
            rng_s: [u64::MAX, 1, (1 << 60) + 7, 42],
            rng_cached: Some(-0.75),
            betas: vec![0.5, 0.25],
            threshold_scale: 0.7,
            probe_seq: 9,
            sync_staged: vec![(f1.clone(), 1)],
            served: 100,
            shed: 2,
            correct: 80,
            llm_calls: 30,
            handled: vec![50, 20, 30],
            levels: vec![
                LevelState {
                    model: snap("lr", 16),
                    calib: snap("mlp", 8),
                    train_chunks: 12,
                    calib_chunks: 7,
                    train_sends: 3,
                    pending: 5,
                    calib_pending: 2,
                    cache: vec![(f1.clone(), 1), (f2.clone(), 0), (f1.clone(), 1)],
                    calib_cache: vec![(vec![0.9, 0.1], 0.0), (vec![0.4, 0.6], 1.0)],
                },
                LevelState {
                    model: snap("tfm_base", 24),
                    calib: snap("mlp", 8),
                    train_chunks: 4,
                    calib_chunks: 4,
                    train_sends: 1,
                    pending: 0,
                    calib_pending: 7,
                    cache: vec![(f2, 0), (f1, 1)],
                    calib_cache: vec![],
                },
            ],
        }
    }

    #[test]
    fn shard_state_json_roundtrip_is_exact() {
        let s = demo_state(0, 123);
        let text = s.to_json().to_string_compact();
        let back = ShardState::from_json(&codec::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s, "every field must survive the JSON trip bit-for-bit");
        // interning: f1 appears 4× across caches/staged but is written once
        let v = codec::parse(&text).unwrap();
        assert_eq!(
            v.get("features").unwrap().as_arr().unwrap().len(),
            2,
            "shared Arc queries must be interned, not duplicated"
        );
    }

    #[test]
    fn sink_commits_manifests_and_prunes() {
        let dir = tmpdir("sink");
        let sink = CkptSink::create(&dir, 2).unwrap();
        // No manifest until every shard deposited once.
        assert!(!sink.deposit(0, &demo_state(0, 10)).unwrap());
        assert!(load_latest(&dir, ResumeMode::BestEffort, 2).unwrap().is_none());
        assert!(sink.deposit(1, &demo_state(1, 8)).unwrap());
        let states = load_latest(&dir, ResumeMode::Strict, 2).unwrap().unwrap();
        assert_eq!(states.len(), 2);
        assert_eq!(states[0].cursor, 10);
        assert_eq!(states[1].cursor, 8);
        // More deposits → newer manifests win; pruning keeps the dir bounded.
        for k in 0..5 {
            sink.deposit(0, &demo_state(0, 20 + k)).unwrap();
            sink.deposit(1, &demo_state(1, 20 + k)).unwrap();
        }
        let states = load_latest(&dir, ResumeMode::Strict, 2).unwrap().unwrap();
        assert_eq!(states[0].cursor, 24);
        let manifests = list_manifests(&dir).unwrap();
        assert!(manifests.len() <= 2, "pruning must bound manifests: {manifests:?}");
        // Seq numbering continues across sink restarts, and prior-run
        // manifests are adopted into the prune list — the directory
        // stays bounded across process restarts, not just within one.
        let sink2 = CkptSink::create(&dir, 2).unwrap();
        sink2.deposit(0, &demo_state(0, 99)).unwrap();
        sink2.deposit(1, &demo_state(1, 99)).unwrap();
        let states = load_latest(&dir, ResumeMode::Strict, 2).unwrap().unwrap();
        assert_eq!(states[0].cursor, 99, "a reopened sink must supersede, not shadow");
        let manifests = list_manifests(&dir).unwrap();
        assert!(
            manifests.len() <= 2,
            "pruning must cover manifests inherited from earlier processes: {manifests:?}"
        );
        let shard_files = fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("shard"))
            .count();
        assert!(
            shard_files <= 2 * 2 + 2,
            "stale shard files must be swept, got {shard_files}"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_checkpoints_error_cleanly() {
        let dir = tmpdir("corrupt");
        let sink = CkptSink::create(&dir, 1).unwrap();
        sink.deposit(0, &demo_state(0, 50)).unwrap();
        let manifests = list_manifests(&dir).unwrap();
        let (_, mname) = &manifests[0];
        let mtext = fs::read_to_string(dir.join(mname)).unwrap();
        let shard_file = {
            let v = codec::parse(&mtext).unwrap();
            v.get("files").unwrap().as_arr().unwrap()[0]
                .as_str()
                .unwrap()
                .to_string()
        };

        // 1. truncated shard file → strict errors, best-effort falls back fresh
        let full = fs::read_to_string(dir.join(&shard_file)).unwrap();
        fs::write(dir.join(&shard_file), &full[..full.len() / 2]).unwrap();
        let err = load_latest(&dir, ResumeMode::Strict, 1).unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
        assert!(load_latest(&dir, ResumeMode::BestEffort, 1).unwrap().is_none());
        fs::write(dir.join(&shard_file), &full).unwrap();
        assert!(load_latest(&dir, ResumeMode::Strict, 1).unwrap().is_some());

        // 2. bad version field → strict errors
        fs::write(dir.join(mname), mtext.replace("\"version\": 2", "\"version\": 99"))
            .unwrap();
        let err = load_latest(&dir, ResumeMode::Strict, 1).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        assert!(load_latest(&dir, ResumeMode::BestEffort, 1).unwrap().is_none());
        fs::write(dir.join(mname), &mtext).unwrap();

        // 3. missing shard file named by the manifest → strict errors
        fs::remove_file(dir.join(&shard_file)).unwrap();
        let err = load_latest(&dir, ResumeMode::Strict, 1).unwrap_err();
        assert!(err.to_string().contains("missing shard"), "{err}");
        assert!(load_latest(&dir, ResumeMode::BestEffort, 1).unwrap().is_none());

        // 4. topology mismatch → strict errors even on a valid file set
        fs::write(dir.join(&shard_file), &full).unwrap();
        let err = load_latest(&dir, ResumeMode::Strict, 2).unwrap_err();
        assert!(err.to_string().contains("topology"), "{err}");

        // 5. empty dir: strict errors, best-effort starts fresh
        let empty = tmpdir("empty");
        assert!(load_latest(&empty, ResumeMode::Strict, 1).is_err());
        assert!(load_latest(&empty, ResumeMode::BestEffort, 1).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
        let _ = fs::remove_dir_all(&empty);
    }

    #[test]
    fn v1_manifests_without_epochs_still_restore() {
        // Forward-compat: a checkpoint directory written by a v1 build
        // (no `epochs` array) restores under strict resume. The
        // committed fixture in tests/fixtures/ckpt_v1 pins the same
        // contract against a byte-frozen v1 file set.
        let dir = tmpdir("v1compat");
        let sink = CkptSink::create(&dir, 2).unwrap();
        sink.deposit(0, &demo_state(0, 10)).unwrap();
        sink.deposit(1, &demo_state(1, 8)).unwrap();
        let manifests = list_manifests(&dir).unwrap();
        let (_, mname) = &manifests[0];
        let mtext = fs::read_to_string(dir.join(mname)).unwrap();
        // Rewrite the manifest as a v1 build would have written it:
        // version 1, no epochs field.
        let v = codec::parse(&mtext).unwrap();
        let v1 = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("seq", v.get("seq").unwrap().clone()),
            ("shards", v.get("shards").unwrap().clone()),
            ("files", v.get("files").unwrap().clone()),
        ]);
        fs::write(dir.join(mname), v1.to_string_pretty()).unwrap();
        let states = load_latest(&dir, ResumeMode::Strict, 2).unwrap().unwrap();
        assert_eq!(states[0].cursor, 10, "v1 manifest must restore cleanly");
        assert_eq!(states[1].cursor, 8);
        assert_eq!(latest_manifest_shards(&dir).unwrap(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_and_mixed_epoch_manifests_are_rejected() {
        let dir = tmpdir("epochs");
        let sink = CkptSink::create(&dir, 2).unwrap();
        sink.deposit(0, &demo_state(0, 10)).unwrap();
        sink.deposit(1, &demo_state(1, 8)).unwrap();
        // A second committed manifest, so best-effort has somewhere
        // valid to walk back to once we corrupt the newest one.
        sink.deposit(0, &demo_state(0, 12)).unwrap();
        let manifests = list_manifests(&dir).unwrap();
        let (_, mname) = &manifests[0];
        let mtext = fs::read_to_string(dir.join(mname)).unwrap();
        let v = codec::parse(&mtext).unwrap();
        let rewrite = |epochs: Json| {
            Json::obj(vec![
                ("version", Json::Num(CKPT_VERSION as f64)),
                ("seq", v.get("seq").unwrap().clone()),
                ("shards", v.get("shards").unwrap().clone()),
                ("files", v.get("files").unwrap().clone()),
                ("epochs", epochs),
            ])
            .to_string_pretty()
        };
        let good: Vec<Json> = v.get("epochs").unwrap().as_arr().unwrap().to_vec();

        // Truncated epochs array (one entry for two shards).
        fs::write(dir.join(mname), rewrite(Json::Arr(good[..1].to_vec()))).unwrap();
        let err = load_latest(&dir, ResumeMode::Strict, 2).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");

        // Mixed-epoch entry: epoch disagrees with the file it annotates
        // (shard files spliced together from different checkpoints).
        let mut mixed = good.clone();
        mixed[1] = Json::Num(9999.0);
        fs::write(dir.join(mname), rewrite(Json::Arr(mixed))).unwrap();
        let err = load_latest(&dir, ResumeMode::Strict, 2).unwrap_err();
        assert!(err.to_string().contains("mixed-epoch"), "{err}");

        // Best-effort walks back past both defects instead of dying.
        assert!(load_latest(&dir, ResumeMode::BestEffort, 2).unwrap().is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_mode_parsing() {
        assert_eq!(ResumeMode::from_name("strict").unwrap(), ResumeMode::Strict);
        assert_eq!(
            ResumeMode::from_name("best-effort").unwrap(),
            ResumeMode::BestEffort
        );
        assert!(ResumeMode::from_name("maybe").is_err());
    }

    #[test]
    fn config_shape_mismatches_are_rejected() {
        use crate::config::{BenchmarkId, ExpertId};
        let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let mut s = demo_state(0, 1);
        s.check_config(&cfg, 2).unwrap();
        s.levels[1].model.kind = "lr".into();
        assert!(s.check_config(&cfg, 2).is_err(), "kind drift must be rejected");
        let mut s = demo_state(0, 1);
        s.betas.pop();
        assert!(s.check_config(&cfg, 2).is_err(), "β length drift must be rejected");
        let s = demo_state(0, 1);
        assert!(s.check_config(&cfg, 7).is_err(), "class drift must be rejected");
    }
}
