//! Per-level worker pools: one *learner authority* plus read-only
//! inference replicas, glued together by published model snapshots.
//!
//! **Why an authority.** Online learning must stay a single serialized
//! trajectory to preserve learner parity with [`crate::cascade::Cascade`]
//! (same batches, same order, same weights). So all `Train`/`TrainCalib`
//! messages go to worker 0 of each pool; replicas never train. The
//! authority periodically exports a [`Snapshot`] pair into a shared
//! [`SnapshotSlot`]; replicas install the latest snapshot lazily before
//! serving an inference batch. Replica predictions therefore lag the
//! authority by at most `publish_every` training triggers — the
//! staleness trade-off reported as [`LevelPool::snapshot_lag`].
//!
//! **Warm respawn.** A respawned worker (authority or replica)
//! restores the latest published snapshot at startup instead of
//! resetting to fresh initialization — the learned level weights are
//! the asset the pool exists to preserve. Only gradient steps after
//! the last publication are lost (and the router's replay caches
//! re-teach those on the next training trigger).

use std::time::Duration;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use crate::sync::thread::JoinHandle;
use crate::sync::{lock_unpoisoned, Arc, Mutex};

use crate::config::{Engine, ModelKind};
use crate::error::{Error, Result};
use crate::models::{build_calibrator, build_level, Featurized, Snapshot};

use super::Job;

/// One published (model, calibrator) state pair.
#[derive(Clone, Debug)]
pub struct LevelSnapshot {
    /// Publication sequence number (1-based; monotone per level).
    pub seq: u64,
    /// Level-model parameters.
    pub model: Snapshot,
    /// Deferral-calibrator parameters.
    pub calib: Snapshot,
}

/// Shared slot the authority publishes into and replicas/respawns read.
/// Lives in an `Arc` owned by the pool so it survives worker respawns.
///
/// **Verification.** The publish/install ordering (snapshot under the
/// mutex first, `published_chunks` next, `seq` bumped *last* with
/// Release so a reader that observes the new seq is guaranteed to
/// find a snapshot at least that fresh) is one of the three
/// model-checked cores: [`crate::mc::models::SlotSpec`] mirrors it
/// step-for-step and `tests/test_loom.rs` explores every interleaving
/// — including a deliberately broken store order the checker must
/// catch. Keep changes here in lockstep with the model.
pub(crate) struct SnapshotSlot {
    seq: AtomicU64,
    /// Authority `train_chunks` at the last publication (staleness
    /// accounting: lag = live chunks − published chunks).
    published_chunks: AtomicU64,
    latest: Mutex<Option<Arc<LevelSnapshot>>>,
}

impl SnapshotSlot {
    fn new() -> Self {
        SnapshotSlot {
            seq: AtomicU64::new(0),
            published_chunks: AtomicU64::new(0),
            latest: Mutex::new(None),
        }
    }

    /// Latest publication sequence (0 = never published).
    pub fn seq(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }

    /// The latest published snapshot, if any.
    ///
    /// A poisoned lock is *recovered*, not propagated: a worker that
    /// panicked while holding the slot must not cascade-kill the
    /// supervisor (or the replacement workers it spawns). Recovery is
    /// sound because the slot's value is replaced whole under the lock
    /// — it is either the old `Arc` or the new one, never torn — and
    /// the panic itself is already accounted as a restart by the
    /// respawn path ([`LevelPool::respawn`]).
    pub fn latest(&self) -> Option<Arc<LevelSnapshot>> {
        lock_unpoisoned(&self.latest).clone()
    }

    fn publish(&self, model: Snapshot, calib: Snapshot, chunks: u64) {
        let seq = self.seq.load(Ordering::Acquire) + 1;
        let snap = Arc::new(LevelSnapshot { seq, model, calib });
        *lock_unpoisoned(&self.latest) = Some(snap);
        self.published_chunks.store(chunks, Ordering::Release);
        // seq is bumped last: a reader that observes the new seq is
        // guaranteed to find the new snapshot in the slot.
        self.seq.store(seq, Ordering::Release);
    }
}

pub(crate) enum WorkerMsg {
    Infer(Vec<Job>),
    Train(Vec<(Arc<Featurized>, usize)>, f32),
    TrainCalib(Vec<(Vec<f32>, f32)>, f32),
    /// Authority only: export current weights into the shared slot.
    Publish,
    /// Authority only: reply with the live (model, calibrator)
    /// snapshots over the provided one-shot channel (checkpointing).
    /// Queued behind any in-flight `Train`, so the export captures
    /// every training trigger sent before it.
    Export(Sender<(Option<Snapshot>, Option<Snapshot>)>),
    /// Simulated crash (supervision tests): the worker thread exits
    /// without replying, exactly like a panic would leave it.
    Crash,
    Shutdown,
}

pub(crate) struct WorkerReply {
    pub level: usize,
    /// Which pool member answered (0 = authority).
    pub replica: usize,
    /// Worker generation — replies from a generation the supervisor
    /// already replaced are dropped (their jobs were requeued).
    pub epoch: u64,
    /// (req_id, probe-job?, speculative?, probs, score) — the probe
    /// and speculation flags are echoed from [`Job::probe`] /
    /// [`Job::spec`] so the router never has to guess which id space a
    /// reply belongs to, nor whether a result may be consumed before
    /// the real gate decides.
    pub results: Vec<(u64, bool, bool, Vec<f32>, f32)>,
}

/// Training-work counters shared router ↔ authority (survive respawns:
/// the supervisor re-hands the same `Arc` to the replacement worker).
#[derive(Default)]
pub(crate) struct WorkerStats {
    pub train_chunks: AtomicU64,
    pub calib_chunks: AtomicU64,
    /// Cumulative wall-clock nanoseconds spent in batched inference
    /// (predict + calibrator scoring) across all of this pool's
    /// workers. Report-only: never checkpointed, never replayed.
    pub infer_ns: AtomicU64,
}

/// Authority state restored from a durable checkpoint. Seeds the
/// pool's snapshot slot *before* any worker spawns, so the first spawn
/// of every member (authority included) warm-starts from the
/// checkpointed weights, and seeds the shared chunk counters so
/// train/calib accounting continues across the restart.
pub(crate) struct PoolInit {
    /// Level-model parameters at the checkpoint.
    pub model: Snapshot,
    /// Calibrator parameters at the checkpoint.
    pub calib: Snapshot,
    /// Cumulative 8-sample model-training chunks at the checkpoint.
    pub train_chunks: u64,
    /// Cumulative 8-sample calibrator-training chunks at the checkpoint.
    pub calib_chunks: u64,
    /// Model-training triggers sent (publish-cadence continuity).
    pub train_sends: u64,
}

/// Everything needed to (re)build one pool worker.
#[derive(Clone)]
pub(crate) struct WorkerSpec {
    pub level: usize,
    pub kind: ModelKind,
    pub classes: usize,
    pub seed: u64,
    pub engine: Engine,
    pub artifacts_dir: String,
}

/// Handle to one worker thread.
pub(crate) struct Worker {
    pub tx: Sender<WorkerMsg>,
    pub handle: JoinHandle<()>,
    pub epoch: u64,
}

fn spawn_worker(
    spec: &WorkerSpec,
    replica: usize,
    epoch: u64,
    reply_tx: Sender<WorkerReply>,
    stats: Arc<WorkerStats>,
    slot: Arc<SnapshotSlot>,
) -> Worker {
    let (tx, rx): (Sender<WorkerMsg>, Receiver<WorkerMsg>) = channel();
    let spec = spec.clone();
    let handle = crate::sync::thread::spawn(move || {
        // The engine is constructed on this thread (PjRtClient is !Send).
        let is_pjrt = spec.engine.is_pjrt();
        let pjrt = if is_pjrt {
            Some(crate::runtime::worker_engine(&spec.artifacts_dir))
        } else {
            None
        };
        // lint: allow(unwrap) — a worker-thread panic IS the supervised
        // crash path: the router detects the dead thread and respawns
        // (warm, from the latest snapshot); nothing above this thread
        // unwinds. Same for the restore expects below.
        let mut model = build_level(pjrt.as_ref(), spec.kind, spec.classes, spec.seed)
            .expect("worker model");
        // lint: allow(unwrap) — supervised worker thread (see above).
        let mut calib = build_calibrator(pjrt.as_ref(), spec.classes, spec.seed)
            .expect("worker calibrator");
        // Warm start: every spawn (first or respawn, authority or
        // replica) resumes from the latest published weights.
        let mut installed = 0u64;
        if let Some(s) = slot.latest() {
            // lint: allow(unwrap) — supervised worker thread (see above).
            model.restore(&s.model).expect("warm-start model restore");
            // lint: allow(unwrap) — supervised worker thread (see above).
            calib.restore(&s.calib).expect("warm-start calibrator restore");
            installed = s.seq;
        }
        while let Ok(msg) = rx.recv() {
            match msg {
                WorkerMsg::Infer(jobs) => {
                    // Replicas track the slot; the authority's live
                    // weights are always at least as fresh as it.
                    if replica > 0 && slot.seq() > installed {
                        if let Some(s) = slot.latest() {
                            // lint: allow(unwrap) — supervised worker
                            // thread; a failed install is a crash the
                            // router respawns from (see spawn header).
                            model.restore(&s.model).expect("replica model install");
                            // lint: allow(unwrap) — supervised worker
                            // thread (see above).
                            calib.restore(&s.calib).expect("replica calib install");
                            installed = s.seq;
                        }
                    }
                    let fs: Vec<&Featurized> =
                        jobs.iter().map(|j| j.f.as_ref()).collect();
                    let t0 = std::time::Instant::now();
                    let probs = model.predict_batch(&fs);
                    let results = jobs
                        .iter()
                        .zip(probs)
                        .map(|(j, p)| {
                            let s = calib.score(&p);
                            (j.req_id, j.probe, j.spec, p, s)
                        })
                        .collect();
                    stats
                        .infer_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let reply =
                        WorkerReply { level: spec.level, replica, epoch, results };
                    if reply_tx.send(reply).is_err() {
                        break;
                    }
                }
                WorkerMsg::Train(batch, lr) => {
                    for chunk in batch.chunks(8) {
                        if chunk.len() < 8 && is_pjrt {
                            break; // pjrt step executables are fixed at batch 8
                        }
                        let b: Vec<(&Featurized, usize)> =
                            chunk.iter().map(|(f, y)| (f.as_ref(), *y)).collect();
                        model.train(&b, lr);
                        stats.train_chunks.fetch_add(1, Ordering::Relaxed);
                    }
                }
                WorkerMsg::TrainCalib(batch, lr) => {
                    for chunk in batch.chunks(8) {
                        if chunk.len() < 8 && is_pjrt {
                            break; // same fixed-batch constraint as Train
                        }
                        let b: Vec<(&[f32], f32)> =
                            chunk.iter().map(|(p, z)| (p.as_slice(), *z)).collect();
                        calib.train(&b, lr);
                        stats.calib_chunks.fetch_add(1, Ordering::Relaxed);
                    }
                }
                WorkerMsg::Publish => {
                    // Backends that cannot export state (no host
                    // mirror) simply skip publication — replicas then
                    // keep serving their init weights and respawns are
                    // cold, which is the pre-pool behavior.
                    if let (Some(m), Some(c)) = (model.snapshot(), calib.snapshot()) {
                        slot.publish(m, c, stats.train_chunks.load(Ordering::Relaxed));
                    }
                }
                WorkerMsg::Export(reply) => {
                    let _ = reply.send((model.snapshot(), calib.snapshot()));
                }
                WorkerMsg::Crash => return,
                WorkerMsg::Shutdown => break,
            }
        }
    });
    Worker { tx, handle, epoch }
}

/// The worker pool for one cascade level: authority + replicas +
/// snapshot slot + supervision bookkeeping.
pub(crate) struct LevelPool {
    spec: WorkerSpec,
    pub workers: Vec<Worker>,
    pub stats: Arc<WorkerStats>,
    slot: Arc<SnapshotSlot>,
    reply_tx: Sender<WorkerReply>,
    /// Respawns so far (all pool members count toward the level cap).
    pub restarts: usize,
    /// Respawns that installed a published snapshot (vs cold resets).
    pub warm_respawns: usize,
    /// Inference jobs dispatched per pool member.
    pub replica_jobs: Vec<u64>,
    /// Jobs that were dispatched to replicas since removed by
    /// scale-down — keeps the dispatched-job total conserved across
    /// elastic resizing (`Σ replica_jobs + retired_jobs` is invariant).
    pub retired_jobs: u64,
    /// Model-training triggers sent to the authority.
    train_sends: u64,
    /// Training triggers between snapshot publications (0 = never).
    publish_every: usize,
}

impl LevelPool {
    pub fn new(
        spec: WorkerSpec,
        replicas: usize,
        publish_every: usize,
        reply_tx: Sender<WorkerReply>,
        init: Option<PoolInit>,
    ) -> Self {
        assert!(replicas >= 1, "a pool needs at least the authority");
        let stats = Arc::new(WorkerStats::default());
        let slot = Arc::new(SnapshotSlot::new());
        let mut train_sends = 0;
        if let Some(init) = init {
            // Checkpoint restore: seed the slot before any spawn so the
            // authority itself warm-starts from the checkpointed
            // weights (counts as publication #1 in `published()`).
            stats.train_chunks.store(init.train_chunks, Ordering::Relaxed);
            stats.calib_chunks.store(init.calib_chunks, Ordering::Relaxed);
            train_sends = init.train_sends;
            slot.publish(init.model, init.calib, init.train_chunks);
        }
        let workers = (0..replicas)
            .map(|r| spawn_worker(&spec, r, 0, reply_tx.clone(), stats.clone(), slot.clone()))
            .collect();
        LevelPool {
            spec,
            workers,
            stats,
            slot,
            reply_tx,
            restarts: 0,
            warm_respawns: 0,
            replica_jobs: vec![0; replicas],
            retired_jobs: 0,
            train_sends,
            publish_every,
        }
    }

    /// Grow the pool by one replica (autoscale-up). The newcomer is an
    /// ordinary read-only replica at the next index: it warm-starts
    /// from the latest published snapshot and installs newer ones
    /// lazily, exactly like a warm respawn. Its epoch is strictly
    /// above every live member's so a reply from any previously
    /// removed worker at this index can never be mistaken for it.
    pub fn add_replica(&mut self) {
        let epoch = self.workers.iter().map(|w| w.epoch).max().unwrap_or(0) + 1;
        let replica = self.workers.len();
        let fresh = spawn_worker(
            &self.spec,
            replica,
            epoch,
            self.reply_tx.clone(),
            self.stats.clone(),
            self.slot.clone(),
        );
        self.workers.push(fresh);
        self.replica_jobs.push(0);
    }

    /// Shrink the pool by one replica (autoscale-down): shut down and
    /// join the highest-index member. Never removes worker 0 — the
    /// learner authority owns the training trajectory and is not
    /// elastic capacity. Returns `false` (and does nothing) when only
    /// the authority remains. The caller must ensure the victim has no
    /// batch in flight; its dispatched-job count is folded into
    /// [`LevelPool::retired_jobs`] so totals stay conserved.
    pub fn remove_replica(&mut self) -> bool {
        if self.workers.len() <= 1 {
            return false;
        }
        // lint: allow(unwrap) — guarded by the len() check above: both
        // vectors always hold one entry per pool member.
        let victim = self.workers.pop().expect("len checked above");
        let _ = victim.tx.send(WorkerMsg::Shutdown);
        drop(victim.tx);
        let _ = victim.handle.join();
        self.retired_jobs += self.replica_jobs.pop().unwrap_or(0);
        true
    }

    /// Synchronously export the authority's live (model, calibrator)
    /// parameters for checkpointing. Blocks (up to `timeout`) until the
    /// authority drains everything queued ahead of the request, so the
    /// export reflects every training trigger sent before this call.
    ///
    /// `Ok(None)` means the authority is *alive but slow* — it did not
    /// answer within `timeout` but its thread is still running. The
    /// caller must treat that as "abort this checkpoint attempt", not
    /// as a death: conflating the two (the pre-fix behavior) let a
    /// slow authority wedge the checkpoint barrier — the supervisor
    /// saw `Error::Worker`, left the barrier armed, and admission
    /// stayed paused forever while the never-respawned worker kept
    /// running.
    pub fn export(&self, timeout: Duration) -> Result<Option<(Snapshot, Snapshot)>> {
        let (tx, rx) = channel();
        self.workers[0]
            .tx
            .send(WorkerMsg::Export(tx))
            .map_err(|_| {
                Error::Worker(format!(
                    "level {} authority gone at checkpoint export",
                    self.spec.level
                ))
            })?;
        match rx.recv_timeout(timeout) {
            Ok((Some(model), Some(calib))) => Ok(Some((model, calib))),
            Ok(_) => Err(Error::Ckpt(format!(
                "level {} backend cannot snapshot its state",
                self.spec.level
            ))),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Worker(format!(
                "level {} authority died during checkpoint export",
                self.spec.level
            ))),
            Err(RecvTimeoutError::Timeout) => {
                if self.workers[0].handle.is_finished() {
                    Err(Error::Worker(format!(
                        "level {} authority died during checkpoint export",
                        self.spec.level
                    )))
                } else {
                    Ok(None)
                }
            }
        }
    }

    /// Model-training triggers sent so far (publish-cadence cursor,
    /// persisted in checkpoints).
    pub fn train_sends(&self) -> u64 {
        self.train_sends
    }

    /// Pool capacity (authority + replicas).
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch an inference batch to pool member `replica`; returns
    /// false when the worker is gone (caller respawns + requeues).
    pub fn send_infer(&mut self, replica: usize, jobs: Vec<Job>) -> bool {
        let n = jobs.len() as u64;
        let ok = self.workers[replica].tx.send(WorkerMsg::Infer(jobs)).is_ok();
        if ok {
            self.replica_jobs[replica] += n;
        }
        ok
    }

    /// Send a model-training trigger to the learner authority, and a
    /// snapshot publication on the configured cadence.
    pub fn send_train(&mut self, batch: Vec<(Arc<Featurized>, usize)>, lr: f32) {
        let _ = self.workers[0].tx.send(WorkerMsg::Train(batch, lr));
        self.train_sends += 1;
        if self.publish_every > 0 && self.train_sends % self.publish_every as u64 == 0 {
            let _ = self.workers[0].tx.send(WorkerMsg::Publish);
        }
    }

    /// Send a calibrator-training trigger to the learner authority.
    pub fn send_train_calib(&mut self, batch: Vec<(Vec<f32>, f32)>, lr: f32) {
        let _ = self.workers[0].tx.send(WorkerMsg::TrainCalib(batch, lr));
    }

    /// Inject a crash into pool member `replica` (best-effort).
    pub fn crash(&self, replica: usize) {
        let _ = self.workers[replica].tx.send(WorkerMsg::Crash);
    }

    /// Replace a dead pool member: fresh thread from the same spec,
    /// bumped epoch (stale replies get dropped). The replacement warm
    /// starts from the latest published snapshot when one exists.
    pub fn respawn(&mut self, replica: usize, cap: usize) -> Result<()> {
        self.restarts += 1;
        if self.restarts > cap {
            return Err(Error::Worker(format!(
                "level {} worker pool exceeded {cap} restarts",
                self.spec.level
            )));
        }
        if self.slot.seq() > 0 {
            self.warm_respawns += 1;
        }
        let epoch = self.workers[replica].epoch + 1;
        let fresh = spawn_worker(
            &self.spec,
            replica,
            epoch,
            self.reply_tx.clone(),
            self.stats.clone(),
            self.slot.clone(),
        );
        let old = std::mem::replace(&mut self.workers[replica], fresh);
        drop(old.tx);
        // The old thread has already exited (that is how we got here),
        // so this join returns immediately; it reaps panics too.
        let _ = old.handle.join();
        Ok(())
    }

    /// Shut down every pool member and join the threads.
    pub fn shutdown(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.handle.join();
        }
    }

    /// Snapshot publications so far.
    pub fn published(&self) -> u64 {
        self.slot.seq()
    }

    /// The latest published snapshot (tests, external checkpointing).
    pub fn latest_snapshot(&self) -> Option<Arc<LevelSnapshot>> {
        self.slot.latest()
    }

    /// Snapshot staleness: authority training chunks not yet captured
    /// by a publication (what a replica or warm respawn would lose).
    pub fn snapshot_lag(&self) -> u64 {
        self.stats
            .train_chunks
            .load(Ordering::Relaxed)
            .saturating_sub(self.slot.published_chunks.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    use crate::models::{HostCalibrator, HostLrLevel, LevelModel, Pipeline};

    fn spec() -> WorkerSpec {
        WorkerSpec {
            level: 0,
            kind: ModelKind::Lr,
            classes: 2,
            seed: 7,
            engine: Engine::Host,
            artifacts_dir: "artifacts".into(),
        }
    }

    fn train_batch(p: &Pipeline) -> Vec<(Arc<Featurized>, usize)> {
        (0..8)
            .map(|i| {
                let text = if i % 2 == 0 { "kw0x001 kw0x002" } else { "kw1x001 kw1x002" };
                (Arc::new(p.featurize(text)), i % 2)
            })
            .collect()
    }

    fn wait_for<F: Fn() -> bool>(what: &str, f: F) {
        let t0 = Instant::now();
        while !f() {
            assert!(t0.elapsed() < Duration::from_secs(10), "timeout waiting for {what}");
            crate::sync::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn killed_worker_resumes_from_its_latest_snapshot() {
        // The warm-respawn contract: train the authority, publish, kill
        // it, respawn — the replacement must serve predictions
        // bit-for-bit equal to a host model restored from the slot,
        // not fresh-initialization predictions.
        let (reply_tx, reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 1, 1, reply_tx, None);
        let p = Pipeline::default();
        pool.send_train(train_batch(&p), 0.5); // publish_every = 1 → publishes
        wait_for("publication", || pool.published() >= 1);
        let snap = pool.latest_snapshot().expect("published snapshot");

        pool.crash(0);
        wait_for("crash", || pool.workers[0].handle.is_finished());
        pool.respawn(0, 16).unwrap();
        assert_eq!(pool.restarts, 1);
        assert_eq!(pool.warm_respawns, 1, "respawn with a snapshot must be warm");

        let probe = Arc::new(p.featurize("kw0x001 kw1x003"));
        assert!(pool.send_infer(0, vec![Job {
            req_id: 99,
            probe: false,
            spec: false,
            f: probe.clone(),
            enq: Instant::now(),
        }]));
        let reply = reply_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.epoch, 1);
        let (_, _, _, probs, score) = &reply.results[0];

        let mut expect_model = HostLrLevel::new(2);
        expect_model.restore(&snap.model).unwrap();
        let mut expect_calib = HostCalibrator::new(2, 7);
        crate::models::Calibrator::restore(&mut expect_calib, &snap.calib).unwrap();
        let want = expect_model.predict(&probe);
        assert_ne!(
            HostLrLevel::new(2).predict(&probe),
            want,
            "trained weights must differ from fresh init for this test to mean anything"
        );
        assert_eq!(probs, &want, "respawned worker must serve the snapshot weights");
        assert_eq!(
            *score,
            crate::models::Calibrator::score(&mut expect_calib, probs),
            "calibrator state must warm-restore too"
        );
        pool.shutdown();
    }

    #[test]
    fn poisoned_snapshot_slot_recovers_instead_of_cascading() {
        // ISSUE 7 satellite: a worker panicking while it holds the
        // SnapshotSlot mutex used to poison it for everyone — the
        // supervisor's next `latest()` (or a respawned worker's warm
        // start) would then panic too, cascading one worker death into
        // a router death. The slot now recovers the lock (its value is
        // replaced whole, so recovery cannot observe torn state) and
        // the original death is still counted as a restart.
        let (reply_tx, reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 1, 1, reply_tx, None);
        let p = Pipeline::default();
        pool.send_train(train_batch(&p), 0.5); // publish_every = 1 → publishes
        wait_for("publication", || pool.published() >= 1);

        // Poison the slot exactly as a mid-publish panic would.
        let slot = pool.slot.clone();
        let dying = crate::sync::thread::spawn(move || {
            let _guard = slot.latest.lock().expect("fresh lock");
            panic!("worker dies while holding the snapshot slot");
        });
        assert!(dying.join().is_err(), "the poisoning thread must panic");

        // Supervisor-side reads recover rather than propagate…
        assert!(pool.latest_snapshot().is_some());
        assert_eq!(pool.published(), 1);

        // …and the supervised lifecycle continues: the dead worker is
        // respawned (counted in restarts) and the replacement installs
        // from the recovered slot and serves.
        pool.crash(0);
        wait_for("crash", || pool.workers[0].handle.is_finished());
        pool.respawn(0, 16).expect("respawn past a poisoned slot");
        assert_eq!(pool.restarts, 1, "the death around the poisoning is counted");
        assert_eq!(pool.warm_respawns, 1, "recovered slot still warm-starts");
        let probe = Arc::new(p.featurize("kw0x001"));
        assert!(pool.send_infer(0, vec![Job {
            req_id: 7,
            probe: false,
            spec: false,
            f: probe,
            enq: Instant::now(),
        }]));
        let reply = reply_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.epoch, 1);

        // A post-poisoning publication also goes through.
        pool.send_train(train_batch(&p), 0.5);
        wait_for("re-publication", || pool.published() >= 2);
        pool.shutdown();
    }

    #[test]
    fn replicas_install_published_snapshots() {
        let (reply_tx, reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 2, 1, reply_tx, None);
        let p = Pipeline::default();
        pool.send_train(train_batch(&p), 0.5);
        wait_for("publication", || pool.published() >= 1);
        let snap = pool.latest_snapshot().unwrap();

        let probe = Arc::new(p.featurize("kw0x001"));
        assert!(pool.send_infer(1, vec![Job {
            req_id: 1,
            probe: false,
            spec: false,
            f: probe.clone(),
            enq: Instant::now(),
        }]));
        let reply = reply_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(reply.replica, 1);
        let mut expect = HostLrLevel::new(2);
        expect.restore(&snap.model).unwrap();
        assert_eq!(
            reply.results[0].3,
            expect.predict(&probe),
            "replica must serve the published (trained) weights, not init"
        );
        assert_eq!(pool.replica_jobs, vec![0, 1]);
        assert_eq!(pool.snapshot_lag(), 0, "everything trained is published");
        pool.shutdown();
    }

    #[test]
    fn export_then_seed_restores_the_exact_weights() {
        // The checkpoint contract at the pool layer: export the trained
        // authority, rebuild a pool from that state, and the fresh
        // authority must serve bit-identical predictions with counters
        // continuing from the export point.
        let (reply_tx, _reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 1, 0, reply_tx, None);
        let p = Pipeline::default();
        pool.send_train(train_batch(&p), 0.5);
        let (model, calib) = pool
            .export(Duration::from_secs(60))
            .expect("export after train")
            .expect("authority answered within the bound");
        let chunks = pool.stats.train_chunks.load(Ordering::Relaxed);
        assert_eq!(chunks, 1, "one 8-sample chunk trained before export");
        pool.shutdown();

        let (reply_tx2, reply_rx2) = channel();
        let mut pool2 = LevelPool::new(
            spec(),
            1,
            0,
            reply_tx2,
            Some(PoolInit {
                model: model.clone(),
                calib,
                train_chunks: chunks,
                calib_chunks: 0,
                train_sends: 1,
            }),
        );
        assert_eq!(pool2.stats.train_chunks.load(Ordering::Relaxed), chunks);
        assert_eq!(pool2.train_sends(), 1);
        assert_eq!(pool2.snapshot_lag(), 0, "seeded slot covers restored chunks");
        let probe = Arc::new(p.featurize("kw0x001 kw1x003"));
        assert!(pool2.send_infer(0, vec![Job {
            req_id: 5,
            probe: false,
            spec: false,
            f: probe.clone(),
            enq: Instant::now(),
        }]));
        let reply = reply_rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        let mut expect = HostLrLevel::new(2);
        expect.restore(&model).unwrap();
        assert_eq!(
            reply.results[0].3,
            expect.predict(&probe),
            "restored authority must serve the exported weights"
        );
        pool2.shutdown();
    }

    #[test]
    fn export_timeout_on_a_live_authority_aborts_not_kills() {
        // The liveness-bug regression at the pool layer: an export that
        // times out while the authority thread is still running must
        // come back `Ok(None)` (abort the attempt), not the
        // authority-died `Error::Worker` that wedged the checkpoint
        // barrier. Queued training makes the zero bound deterministic —
        // the export cannot possibly be answered before it expires.
        let (reply_tx, _reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 1, 0, reply_tx, None);
        let p = Pipeline::default();
        for _ in 0..3 {
            pool.send_train(train_batch(&p), 0.5);
        }
        let got = pool.export(Duration::ZERO).expect("live authority must not error");
        assert!(got.is_none(), "timeout on a live authority aborts the attempt");
        // The pool is untouched by the abort: a patient export succeeds.
        let got = pool.export(Duration::from_secs(60)).expect("patient export");
        assert!(got.is_some(), "the same authority answers a patient export");
        pool.shutdown();
    }

    #[test]
    fn elastic_resize_joins_cleanly_and_conserves_counters() {
        // Autoscale at the pool layer, under real threads: grow to 16
        // members, drive inference through every member while growing
        // and shrinking, and assert (a) every dispatched job is
        // answered — no orphaned in-flight work from a scale-down —
        // and (b) the dispatched-job total is conserved across
        // removals (live replica_jobs + retired_jobs).
        let (reply_tx, reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 1, 1, reply_tx, None);
        let p = Pipeline::default();
        pool.send_train(train_batch(&p), 0.5); // publish so newcomers warm-start
        wait_for("publication", || pool.published() >= 1);

        let probe = Arc::new(p.featurize("kw0x001 kw1x002"));
        let job = |id: u64| Job {
            req_id: id,
            probe: false,
            spec: false,
            f: probe.clone(),
            enq: Instant::now(),
        };

        let mut dispatched = 0u64;
        let mut answered = 0u64;
        let mut next_id = 0u64;
        // Grow 1 → 16, dispatching one batch to every member per step.
        while pool.replicas() < 16 {
            pool.add_replica();
            for r in 0..pool.replicas() {
                assert!(pool.send_infer(r, vec![job(next_id), job(next_id + 1)]));
                next_id += 2;
                dispatched += 2;
            }
        }
        assert_eq!(pool.replicas(), 16);
        // Drain everything in flight, then shrink 16 → 1. Draining
        // first is the router's contract too: a victim is only removed
        // once its in-flight slot is empty.
        while answered < dispatched {
            let reply = reply_rx.recv_timeout(Duration::from_secs(30)).unwrap();
            answered += reply.results.len() as u64;
        }
        while pool.replicas() > 1 {
            assert!(pool.remove_replica(), "non-authority members must be removable");
            // Interleave more work on the survivors mid-shrink.
            for r in 0..pool.replicas() {
                assert!(pool.send_infer(r, vec![job(next_id)]));
                next_id += 1;
                dispatched += 1;
            }
            while answered < dispatched {
                let reply = reply_rx.recv_timeout(Duration::from_secs(30)).unwrap();
                answered += reply.results.len() as u64;
            }
        }
        assert_eq!(answered, dispatched, "no job may be orphaned by a scale-down");
        assert!(
            !pool.remove_replica(),
            "the learner authority must never be scaled away"
        );
        assert_eq!(pool.replicas(), 1);
        let live: u64 = pool.replica_jobs.iter().sum();
        assert_eq!(
            live + pool.retired_jobs,
            dispatched,
            "dispatched-job accounting must be conserved across resizes"
        );
        // The authority (and its trained weights) survived the churn.
        assert_eq!(pool.published(), 1);
        assert!(pool.latest_snapshot().is_some());
        pool.shutdown();
    }

    #[test]
    fn publish_cadence_and_lag_accounting() {
        let (reply_tx, _reply_rx) = channel();
        let mut pool = LevelPool::new(spec(), 1, 2, reply_tx, None);
        let p = Pipeline::default();
        pool.send_train(train_batch(&p), 0.5); // 1st trigger: no publish
        pool.send_train(train_batch(&p), 0.5); // 2nd trigger: publish
        pool.send_train(train_batch(&p), 0.5); // 3rd trigger: lag grows
        wait_for("publication", || pool.published() >= 1);
        // Wait for the 3rd train to finish (train is serialized after
        // the publish on the authority's channel).
        wait_for("training", || {
            pool.stats.train_chunks.load(Ordering::Relaxed) >= 3
        });
        assert_eq!(pool.published(), 1);
        assert_eq!(pool.snapshot_lag(), 1, "one trigger past the last publication");
        pool.shutdown();
    }
}
