//! `ocl` — launcher for the Online Cascade Learning reproduction.
//!
//! Subcommands map 1:1 onto the paper's tables and figures (DESIGN.md
//! §5) plus a serving mode and a self-test. `make reproduce` drives
//! everything into `reports/`.

use ocl::cli::{Command, ServeArgs};
use ocl::config::{BenchmarkId, CascadeConfig, Engine, ExpertId};
use ocl::error::{Error, Result};
use ocl::eval::{self, Harness};
use ocl::report;
use ocl::serve::shard::{ShardFront, ShardReport};
use ocl::serve::{load, net};

fn commands() -> Vec<Command> {
    vec![
        Command::new("run", "run online cascade learning on one benchmark stream")
            .opt("benchmark", "imdb", "imdb|hatespeech|isear|fever")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("scale", "0.2", "stream scale vs the paper's dataset size")
            .opt("budget", "0", "LLM-call budget (0 = unlimited)")
            .opt("seed", "0", "rng seed")
            .opt("engine", "host", "host|pjrt")
            .switch("large", "use the 4-level cascade (adds BERT-large)"),
        Command::new("table1", "reproduce Table 1 (all methods x budgets)")
            .opt("scale", "0.1", "stream scale")
            .opt("seed", "0", "rng seed")
            .opt("out", "reports", "output directory")
            .switch("full", "run both experts (default: gpt35 only)"),
        Command::new("curves", "reproduce Figs 3/4/10/11 cost-accuracy curves")
            .opt("benchmark", "imdb", "benchmark (or 'all')")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("scale", "0.1", "stream scale")
            .opt("seed", "0", "rng seed")
            .opt("out", "reports", "output directory")
            .switch("large", "4-level cascade (Fig 11)"),
        Command::new("case", "reproduce Figs 5-8 case-analysis time series")
            .opt("benchmark", "imdb", "benchmark (or 'all')")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("scale", "0.2", "stream scale")
            .opt("seed", "0", "rng seed")
            .opt("out", "reports", "output directory"),
        Command::new("shift", "reproduce Fig 9 + Table 2 distribution shifts")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("scale", "0.1", "stream scale")
            .opt("seed", "0", "rng seed")
            .opt("out", "reports", "output directory"),
        Command::new("table5", "reproduce Table 5 (accuracy by length bucket)")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("scale", "0.3", "stream scale")
            .opt("seed", "0", "rng seed")
            .opt("out", "reports", "output directory"),
        Command::new("costmodel", "reproduce App. B.1/C.1 cost analyses")
            .opt("out", "reports", "output directory"),
        Command::new("reproduce", "regenerate the paper-vs-measured record (DESIGN.md §10)")
            .opt("benchmark", "all", "imdb|hatespeech|isear|fever|all")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("profile", "full", "quick|full; overridden runs write *-custom files")
            .opt("scale", "", "stream scale override (default: the profile's pin)")
            .opt("seeds", "", "comma-separated seed list override, e.g. 1,2,3")
            .opt("out", "reports", "output directory")
            .switch("check", "schema-validate the existing report file instead of running"),
        // The serve flag table lives in `cli::ServeArgs` — shared with
        // the wire client and `examples/serve_stream.rs` so the three
        // surfaces cannot drift.
        ServeArgs::command(),
        Command::new("reshard", "rewrite a checkpoint directory to a new shard count")
            .opt("src", "ckpt", "source checkpoint directory (any shard count)")
            .opt("dst", "ckpt-resharded", "destination directory (must hold no manifest)")
            .opt("shards", "1", "target shard count"),
        Command::new("selftest", "quick end-to-end smoke test"),
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn usage(cmds: &[Command]) -> String {
    let mut s = String::from(
        "ocl — Online Cascade Learning (ICML 2024) reproduction\n\nsubcommands:\n",
    );
    for c in cmds {
        s.push_str(&format!("  {:<10} {}\n", c.name, c.about));
    }
    s.push_str("\nuse `ocl <subcommand> --help` for flags\n");
    s
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmds = commands();
    let Some(sub) = argv.first() else {
        print!("{}", usage(&cmds));
        return Ok(());
    };
    if sub == "--help" || sub == "-h" || sub == "help" {
        print!("{}", usage(&cmds));
        return Ok(());
    }
    let cmd = cmds
        .iter()
        .find(|c| c.name == sub.as_str())
        .ok_or_else(|| Error::Usage(format!("unknown subcommand '{sub}'")))?;
    if argv.iter().any(|a| a == "--help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let args = cmd.parse(&argv[1..])?;

    match cmd.name {
        "run" => {
            let bench = BenchmarkId::from_name(args.get("benchmark"))?;
            let expert = ExpertId::from_name(args.get("expert"))?;
            let mut h = Harness::new(args.parse("scale")?, args.parse("seed")?);
            let engine = Engine::from_name(args.get("engine"))?;
            if engine.is_pjrt() {
                h.engine = engine;
                #[cfg(feature = "pjrt")]
                {
                    h.pjrt = Some(std::rc::Rc::new(ocl::runtime::PjrtEngine::from_dir(
                        ocl::runtime::DEFAULT_ARTIFACTS_DIR,
                    )?));
                }
            }
            let budget: u64 = args.parse("budget")?;
            let budget = if budget == 0 { None } else { Some(budget) };
            let (r, _) = h.run_ocl(
                bench,
                expert,
                budget,
                args.switch("large"),
                ocl::data::StreamOrder::Natural,
            )?;
            println!(
                "bench={} expert={} acc={:.2}% recall={:.2}% llm_calls={} \
                 expert_acc={:.2}% flops={:.3e}",
                bench.name(),
                expert.name(),
                r.accuracy * 100.0,
                r.recall * 100.0,
                r.llm_calls,
                r.expert_accuracy * 100.0,
                r.flops
            );
            Ok(())
        }
        "table1" => {
            let h = Harness::new(args.parse("scale")?, args.parse("seed")?);
            let experts: Vec<ExpertId> = if args.switch("full") {
                ExpertId::ALL.to_vec()
            } else {
                vec![ExpertId::Gpt35]
            };
            let s = eval::table1(&h, &experts)?;
            eval::emit(args.get("out"), "table1.txt", &s)
        }
        "curves" => {
            let h = Harness::new(args.parse("scale")?, args.parse("seed")?);
            let expert = ExpertId::from_name(args.get("expert"))?;
            let large = args.switch("large");
            let benches: Vec<BenchmarkId> = if args.get("benchmark") == "all" {
                BenchmarkId::ALL.to_vec()
            } else {
                vec![BenchmarkId::from_name(args.get("benchmark"))?]
            };
            for bench in benches {
                let s = eval::curves(&h, bench, expert, large)?;
                let tag = if large { "fig11" } else { "fig_curves" };
                eval::emit(
                    args.get("out"),
                    &format!("{tag}_{}_{}.txt", bench.name(), expert.name()),
                    &s,
                )?;
            }
            Ok(())
        }
        "case" => {
            let h = Harness::new(args.parse("scale")?, args.parse("seed")?);
            let expert = ExpertId::from_name(args.get("expert"))?;
            let benches: Vec<BenchmarkId> = if args.get("benchmark") == "all" {
                BenchmarkId::ALL.to_vec()
            } else {
                vec![BenchmarkId::from_name(args.get("benchmark"))?]
            };
            for bench in benches {
                let s = eval::case_analysis(&h, bench, expert)?;
                eval::emit(
                    args.get("out"),
                    &format!("fig_case_{}.txt", bench.name()),
                    &s,
                )?;
            }
            Ok(())
        }
        "shift" => {
            let h = Harness::new(args.parse("scale")?, args.parse("seed")?);
            let expert = ExpertId::from_name(args.get("expert"))?;
            let s = eval::shift(&h, expert)?;
            eval::emit(args.get("out"), "fig9_table2_shift.txt", &s)
        }
        "table5" => {
            let h = Harness::new(args.parse("scale")?, args.parse("seed")?);
            let expert = ExpertId::from_name(args.get("expert"))?;
            let s = eval::table5(&h, expert)?;
            eval::emit(args.get("out"), "table5.txt", &s)
        }
        "costmodel" => {
            let s = eval::costmodel();
            eval::emit(args.get("out"), "costmodel.txt", &s)
        }
        "reproduce" => {
            let mut opts = report::ReproduceOpts::for_profile(args.get("profile"))?;
            let customized = args.get("benchmark") != "all"
                || args.get("expert") != "gpt35"
                || !args.get("scale").is_empty()
                || !args.get("seeds").is_empty();
            opts.expert = ExpertId::from_name(args.get("expert"))?;
            if args.get("benchmark") != "all" {
                opts.benches = vec![BenchmarkId::from_name(args.get("benchmark"))?];
            }
            if !args.get("scale").is_empty() {
                opts.scale = args.parse("scale")?;
            }
            if !args.get("seeds").is_empty() {
                opts.seeds = report::parse_seed_list(args.get("seeds"))?;
            }
            if args.switch("check") {
                let path = std::path::Path::new(args.get("out"))
                    .join(format!("reproduce_{}.json", opts.profile));
                let rep = report::check_file(&path)?;
                println!(
                    "schema v{} ok: {} ({} sections, {} rows, {})",
                    report::SCHEMA_VERSION,
                    path.display(),
                    rep.sections.len(),
                    rep.rows(),
                    if rep.passed() { "all bands pass" } else { "band FAILURES" }
                );
                // The verdict is part of the contract: a record whose
                // rows fail their tolerance bands fails the check (a
                // reproduction bound is an SLO like any latency bound).
                if !rep.passed() {
                    return Err(Error::Slo(format!(
                        "tolerance-band failures in {}",
                        path.display()
                    )));
                }
                return Ok(());
            }
            // Overridden runs must not clobber the pinned record files
            // the CI drift gate and the §10 splice are tied to.
            if customized {
                opts.profile.push_str("-custom");
            }
            let rep = report::reproduce(&opts)?;
            let (jp, mp) = rep.write(args.get("out"))?;
            println!("{}", rep.to_markdown());
            eprintln!("[wrote {} and {}]", jp.display(), mp.display());
            if !rep.passed() {
                eprintln!("warning: tolerance-band failures; see {}", mp.display());
            }
            Ok(())
        }
        "serve" => {
            let sa = ServeArgs::from_args(&args)?;
            let bench = BenchmarkId::from_name(&sa.benchmark)?;
            let expert = ExpertId::from_name(&sa.expert)?;
            let n = sa.requests;
            let rate = sa.rate;
            let seed = sa.seed;
            // `ocl serve` pins the host engine unless told otherwise
            // (the serve_stream example is the auto-detecting surface).
            let engine = Engine::from_name(sa.engine.as_deref().unwrap_or("host"))?;
            let shards = sa.shards;

            // Wire-client mode: no local cascade at all — connect to a
            // --listen / --front process and drive it over the socket.
            if let Some(addr) = &sa.connect {
                return serve_client(&sa, bench, expert, addr);
            }
            // Thin front process: also cascade-free; it hash-dispatches
            // to already-running shard processes.
            if let Some(addrs) = &sa.front {
                let listen = sa.listen.as_deref().ok_or_else(|| {
                    Error::Usage("--front requires --listen <bind addr>".into())
                })?;
                let listener = std::net::TcpListener::bind(listen)
                    .map_err(|e| Error::io(listen, e))?;
                let peers: Vec<String> = addrs
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                eprintln!("[front on {listen} over {} shard(s)]", peers.len());
                let merged = net::run_front(&peers, listener)?;
                println!("front: {}", merged.to_string_compact());
                return Ok(());
            }
            if sa.shard_id.is_some() && sa.listen.is_none() {
                return Err(Error::Usage("--shard-id requires --listen".into()));
            }

            let h = Harness::new(sa.scale, seed);
            let (b, e) = h.setup(bench, expert);
            let mut cfg = CascadeConfig::small(bench, expert);
            cfg.engine = engine;
            cfg.seed = seed;
            // Validated construction: nonsense knob combos fail here,
            // before any worker thread spawns. (A single-shard front
            // has no peers to sync with — the broadcast is only wired
            // when shards > 1.)
            let serve_cfg = sa.serve_config()?;
            let ckpt = sa.ckpt_options()?;

            // One shard process of a multi-process deployment: a single
            // Server behind a socket, the shared checkpoint directory
            // as durable state, sync relayed by the front.
            if let (Some(listen), Some(k)) = (sa.listen.as_deref(), sa.shard_id) {
                let listener = std::net::TcpListener::bind(listen)
                    .map_err(|e| Error::io(listen, e))?;
                let (mut srv, cursor) = net::build_shard_server(
                    cfg,
                    b.classes,
                    e,
                    serve_cfg,
                    &sa.artifacts,
                    net::ShardSlot { id: k, of: shards },
                    ckpt,
                )?;
                srv.set_threshold_scale(eval::BUDGETED_SCALE);
                eprintln!("[shard {k}/{shards} on {listen}]");
                let r = net::serve_shard(srv, cursor, k, listener)?;
                print_shard_line(k, &r);
                println!(
                    "shard-process {k}/{shards}: served_total={} shed={} \
                     llm_calls={} resumed={} resume_cursor={cursor} ckpts={}",
                    r.served, r.shed, r.llm_calls, r.resumed, r.ckpts
                );
                return Ok(());
            }

            let mut front = ShardFront::with_ckpt(
                cfg,
                b.classes,
                e,
                serve_cfg,
                &sa.artifacts,
                ckpt,
            )?;
            front.set_threshold_scale(eval::BUDGETED_SCALE);

            // Single-process TCP serving: the whole ShardFront (global
            // admission gate included) behind one accept loop; clients
            // bring their own stream.
            if let Some(listen) = sa.listen.as_deref() {
                let cursor = front.resume_cursor() as usize;
                let listener = std::net::TcpListener::bind(listen)
                    .map_err(|e| Error::io(listen, e))?;
                eprintln!("[serving on {listen}]");
                let report = net::serve(front, listener)?;
                let drained = report.served() + report.shed();
                print_serve_summary(&report, drained, cursor);
                return Ok(());
            }

            // Resume: requests below the cursor were already absorbed
            // by the interrupted run — resubmit only the stream tail,
            // with its original ids (shard hashing + cursor continuity).
            let cursor = (front.resume_cursor() as usize).min(n);
            let (req_tx, req_rx) = ocl::sync::mpsc::channel();
            let (resp_tx, resp_rx) = ocl::sync::mpsc::channel();
            let samples: Vec<_> =
                b.samples.iter().take(n).skip(cursor).cloned().collect();
            let arrival = load::Arrival::Poisson {
                rate: if rate > 0.0 { rate } else { 1e9 },
            };
            let submit =
                load::drive_from(samples, arrival, seed ^ 0xA, req_tx, cursor as u64);
            let drain = ocl::sync::thread::spawn(move || resp_rx.iter().count());
            let report = front.serve(req_rx, resp_tx)?;
            submit.join().ok();
            let drained = drain.join().unwrap_or(0);
            print_serve_summary(&report, drained, cursor);
            Ok(())
        }
        "reshard" => {
            let src = args.get("src");
            let dst = args.get("dst");
            let to: usize = args.parse("shards")?;
            let summary = ocl::serve::reshard::reshard(src, dst, to)?;
            println!("{}", summary.describe());
            Ok(())
        }
        "selftest" => {
            let h = Harness::new(0.02, 1);
            let (r, _) = h.run_ocl(
                BenchmarkId::Imdb,
                ExpertId::Gpt35,
                Some(150),
                false,
                ocl::data::StreamOrder::Natural,
            )?;
            println!(
                "selftest: acc={:.2}% llm_calls={} — OK",
                r.accuracy * 100.0,
                r.llm_calls
            );
            Ok(())
        }
        _ => unreachable!(),
    }
}

/// The one-line run summary + per-shard detail lines shared by the
/// in-process and `--listen` serving paths (CI smoke jobs grep these).
fn print_serve_summary(report: &ShardReport, drained: usize, cursor: usize) {
    let lat = report.latency_ms();
    println!(
        "shards={} served_total={} shed={} drained={} acc={:.2}% thr={:.0} req/s \
         p50={:.2}ms p95={:.2}ms p99={:.2}ms llm_calls={} max_snapshot_lag={} \
         resumed={} resume_cursor={cursor} ckpts={} \
         p99_direct={:.2}ms p99_deferred={:.2}ms spec_hits={} spec_wasted={}",
        report.shards.len(),
        report.served(),
        report.shed(),
        drained,
        report.accuracy() * 100.0,
        report.throughput(),
        lat.pct(50.0),
        lat.pct(95.0),
        lat.pct(99.0),
        report.llm_calls(),
        report.max_snapshot_lag(),
        report.resumed(),
        report.ckpts(),
        report.latency_direct_ms().pct(99.0),
        report.latency_deferred_ms().pct(99.0),
        report.spec_hits(),
        report.spec_wasted()
    );
    for (i, r) in report.shards.iter().enumerate() {
        print_shard_line(i, r);
    }
}

/// One shard's detail line (`final_betas` is what the crash tests and
/// ckpt-smoke compare bit-for-bit across resume).
fn print_shard_line(i: usize, r: &ocl::serve::ServeReport) {
    println!(
        "shard {i}: served={} handled={:?} restarts={:?} (cap {}) \
         warm_respawns={:?} snapshots={:?} snapshot_lag={:?} \
         replica_jobs={:?} final_betas={:?} infer_ns={:?} queue_depth={:?}",
        r.served,
        r.handled,
        r.restarts,
        r.restart_cap,
        r.warm_respawns,
        r.snapshots,
        r.snapshot_lag,
        r.replica_jobs,
        r.final_betas,
        r.infer_ns,
        r.queue_depth
    );
}

/// `ocl serve --connect`: the wire-client mode. Builds the benchmark
/// stream locally, resubmits from the server's announced resume
/// cursor, and (optionally) asserts client-observed latency SLOs —
/// measured where they matter, on the far side of the socket.
fn serve_client(
    sa: &ServeArgs,
    bench: BenchmarkId,
    expert: ExpertId,
    addr: &str,
) -> Result<()> {
    let (n, rate, seed) = (sa.requests, sa.rate, sa.seed);
    let h = Harness::new(sa.scale, seed);
    let (b, _expert) = h.setup(bench, expert);
    let client = net::Client::connect_retry(addr, std::time::Duration::from_secs(30))?;
    let cursor = (client.cursor() as usize).min(n);
    let samples: Vec<_> = b.samples.iter().take(n).skip(cursor).cloned().collect();
    let arrival = load::Arrival::Poisson {
        rate: if rate > 0.0 { rate } else { 1e9 },
    };
    let submit = load::drive_from(
        samples,
        arrival,
        seed ^ 0xA,
        client.request_sender(),
        cursor as u64,
    );
    let submitted = submit.join().unwrap_or(0);
    let (responses, report) = client.finish()?;
    let mut lat = ocl::util::Percentiles::new();
    let mut shed = 0usize;
    let mut correct = 0usize;
    for r in &responses {
        if r.shed {
            shed += 1;
            continue;
        }
        lat.push(r.latency.as_secs_f64() * 1000.0);
        if r.pred == r.truth {
            correct += 1;
        }
    }
    let served = responses.len() - shed;
    println!(
        "client: submitted={submitted} responses={} served={served} shed={shed} \
         acc={:.2}% p50={:.2}ms p99={:.2}ms resume_cursor={cursor}",
        responses.len(),
        if served > 0 { correct as f64 / served as f64 * 100.0 } else { 0.0 },
        lat.pct(50.0),
        lat.pct(99.0),
    );
    if let Some(rep) = &report {
        println!("server report: {}", rep.to_string_compact());
    }
    let (p50, p99) = (sa.slo_p50, sa.slo_p99);
    if p50 > 0.0 || p99 > 0.0 {
        let slo = load::Slo {
            p50_ms: if p50 > 0.0 { p50 } else { f64::INFINITY },
            p99_ms: if p99 > 0.0 { p99 } else { f64::INFINITY },
        };
        slo.check(&lat)?;
        println!("slo: ok (p50<={p50}ms p99<={p99}ms)");
    }
    Ok(())
}
