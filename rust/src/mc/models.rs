//! Model-checkable specs of the serve layer's three concurrency
//! protocol cores, explored by [`crate::mc::Explorer`] in
//! `tests/test_loom.rs`.
//!
//! Each spec mirrors its production counterpart *step-for-step at
//! atomic granularity* so the explored interleavings are the ones real
//! threads can produce (under sequential consistency — see the
//! [`crate::mc`] module docs for the weak-memory caveat):
//!
//! * [`GateSpec`] — the [`crate::serve::AdmissionGate`] CAS loop
//!   (acquire / release / shed). Checks exactly-once admission
//!   accounting and no lost or duplicated permits.
//! * [`SlotSpec`] — the snapshot slot's publish/install ordering
//!   (`crate::serve::pool`'s `SnapshotSlot`): payload and chunk count
//!   are stored *before* the sequence number is released. Checks that
//!   a reader observing sequence `s` always installs a payload at
//!   least that fresh.
//! * [`BarrierSpec`] — the checkpoint barrier's
//!   pause → drain → export → resume machine. Drives the *production*
//!   [`CkptBarrier`] type inside the model state — not a
//!   re-implementation — against arrival, router, arming, export, and
//!   respawn actors, including the slow-authority timeout arm and the
//!   dead-authority respawn-and-retry arm.
//! * [`ScaleSpec`] — the serve loop's elasticity step: the production
//!   [`ScaleController`] hysteresis driving grow/shrink of a replica
//!   vector while job actors claim and release replicas. Checks the
//!   `[min, max]` bounds, replica-count accounting, that no busy
//!   replica is ever removed, and that worker 0 (the learner
//!   authority) is never scaled away.
//!
//! [`GateSpec`], [`SlotSpec`], and [`ScaleSpec`] also carry a
//! deliberately-broken mode (a blind store instead of a CAS; sequence
//! released before the payload; a scale-down victim rule that can
//! select the authority). These exist so the test suite can prove the
//! checker *finds* the classic bugs — a model checker that has never
//! caught a planted bug is just a slow `Ok(())`.

use crate::mc::Spec;
use crate::serve::barrier::{CkptBarrier, ExportOutcome};
use crate::serve::scale::{ScaleController, ScaleDecision, ScalePolicy};

// ---------------------------------------------------------------------------
// Admission gate
// ---------------------------------------------------------------------------

/// Per-client program counter in [`GateSpec`]. Each variant boundary
/// is one atomic instruction in `AdmissionGate::try_admit` /
/// `release`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GatePc {
    /// About to load the current in-flight count.
    Load,
    /// Loaded `observed`; about to compare it against the cap.
    Check {
        /// The in-flight count this client last observed.
        observed: i64,
    },
    /// Passed the cap check; about to CAS `observed → observed + 1`.
    Cas {
        /// The expected value for the compare-and-swap.
        observed: i64,
    },
    /// CAS succeeded; about to `fetch_max` the peak gauge.
    Peak {
        /// The in-flight count this client just installed minus one.
        observed: i64,
    },
    /// Admitted and holding a permit (the request is in flight).
    Work,
    /// About to decrement the in-flight count.
    Release,
    /// Finished: was admitted and released its permit.
    Admitted,
    /// Finished: shed at the cap check.
    Shed,
}

/// Shared + per-client state of the admission-gate model.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct GateState {
    /// The `cur` atomic: permits currently held (i64 so the checker
    /// reports underflow instead of wrapping).
    pub cur: i64,
    /// The `peak` gauge (`fetch_max` mirror).
    pub peak: i64,
    /// One program counter per client.
    pub pcs: Vec<GatePc>,
}

impl GateState {
    fn in_system(&self) -> i64 {
        self.pcs
            .iter()
            .filter(|pc| matches!(pc, GatePc::Peak { .. } | GatePc::Work | GatePc::Release))
            .count() as i64
    }
    fn admitted(&self) -> usize {
        self.pcs.iter().filter(|pc| matches!(pc, GatePc::Admitted)).count()
    }
    fn shed(&self) -> usize {
        self.pcs.iter().filter(|pc| matches!(pc, GatePc::Shed)).count()
    }
}

/// Model of [`crate::serve::AdmissionGate`]: `clients` concurrent
/// callers racing `try_admit` (CAS loop) and `release` against a
/// `cap`-sized gate.
///
/// Invariants checked after every atomic step: the permit count
/// exactly equals the number of clients between CAS success and
/// release (no lost, duplicated, or phantom permits), never exceeds
/// the cap, and never goes negative. Final-state checks: all permits
/// returned, admitted + shed covers every client, nobody sheds when
/// `clients <= cap`, and at least one client is admitted.
#[derive(Debug, Clone, Copy)]
pub struct GateSpec {
    /// Number of concurrent clients.
    pub clients: usize,
    /// Gate capacity (`ServeConfig::max_pending` in production).
    pub cap: i64,
    /// Replace the CAS with a blind `store(observed + 1)` — the bug
    /// the CAS exists to prevent. The checker must catch this
    /// (meta-test in `tests/test_loom.rs`).
    pub blind_store: bool,
}

impl Spec for GateSpec {
    type State = GateState;

    fn init(&self) -> GateState {
        GateState { cur: 0, peak: 0, pcs: vec![GatePc::Load; self.clients] }
    }

    fn actors(&self) -> usize {
        self.clients
    }

    fn enabled(&self, s: &GateState, a: usize) -> bool {
        !matches!(s.pcs[a], GatePc::Admitted | GatePc::Shed)
    }

    fn done(&self, s: &GateState, a: usize) -> bool {
        matches!(s.pcs[a], GatePc::Admitted | GatePc::Shed)
    }

    fn step(&self, s: &mut GateState, a: usize) {
        s.pcs[a] = match s.pcs[a] {
            GatePc::Load => GatePc::Check { observed: s.cur },
            GatePc::Check { observed } => {
                if observed >= self.cap {
                    GatePc::Shed
                } else {
                    GatePc::Cas { observed }
                }
            }
            GatePc::Cas { observed } => {
                if self.blind_store {
                    s.cur = observed + 1;
                    GatePc::Peak { observed }
                } else if s.cur == observed {
                    s.cur = observed + 1;
                    GatePc::Peak { observed }
                } else {
                    // CAS failure hands back the actual value — retry
                    // from the cap check, exactly like the real loop.
                    GatePc::Check { observed: s.cur }
                }
            }
            GatePc::Peak { observed } => {
                s.peak = s.peak.max(observed + 1);
                GatePc::Work
            }
            GatePc::Work => GatePc::Release,
            GatePc::Release => {
                s.cur -= 1;
                GatePc::Admitted
            }
            GatePc::Admitted | GatePc::Shed => unreachable!("stepped a finished client"),
        };
    }

    fn check(&self, s: &GateState) -> std::result::Result<(), String> {
        if s.cur < 0 {
            return Err(format!("permit underflow: cur = {}", s.cur));
        }
        if s.cur > self.cap {
            return Err(format!("over-admission: cur = {} > cap = {}", s.cur, self.cap));
        }
        let in_system = s.in_system();
        if s.cur != in_system {
            return Err(format!(
                "permit accounting broken: cur = {} but {} clients hold permits",
                s.cur, in_system
            ));
        }
        if s.peak > self.cap {
            return Err(format!("peak gauge {} exceeds cap {}", s.peak, self.cap));
        }
        Ok(())
    }

    fn check_final(&self, s: &GateState) -> std::result::Result<(), String> {
        if s.cur != 0 {
            return Err(format!("permits leaked: cur = {} at quiescence", s.cur));
        }
        let (admitted, shed) = (s.admitted(), s.shed());
        if admitted + shed != self.clients {
            return Err(format!(
                "lost client: {admitted} admitted + {shed} shed != {} clients",
                self.clients
            ));
        }
        if self.clients as i64 <= self.cap && shed > 0 {
            return Err(format!(
                "spurious shed: {shed} shed with only {} clients against cap {}",
                self.clients, self.cap
            ));
        }
        if self.clients > 0 && self.cap > 0 && admitted == 0 {
            return Err("livelock-shed: nobody was admitted".to_string());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Snapshot slot
// ---------------------------------------------------------------------------

/// Authority-side program counter in [`SlotSpec`] — the three stores
/// of one publication.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AuthPc {
    /// About to write the snapshot payload under the slot mutex.
    WritePayload,
    /// About to store the published chunk count.
    StoreChunks,
    /// About to store (release) the sequence number.
    StoreSeq,
    /// All publications issued.
    Idle,
}

/// Reader-side program counter in [`SlotSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReaderPc {
    /// About to (acquire-)load the sequence number.
    LoadSeq,
    /// Observed a nonzero sequence; about to lock and read the payload.
    Install {
        /// The sequence number this reader observed.
        observed: u64,
    },
    /// Finished; carries what was observed vs what was installed so
    /// the invariant can audit the pair.
    Done {
        /// The sequence number this reader observed (0 = none yet).
        observed: u64,
        /// The payload publication number it then installed.
        installed: u64,
    },
}

/// Shared + per-actor state of the snapshot-slot model.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SlotState {
    /// Payload slot (publication number stored under the mutex; 0 = none).
    pub payload: u64,
    /// Published chunk-count mirror.
    pub chunks: u64,
    /// The atomic sequence number (stored last on the good path).
    pub seq: u64,
    /// Which publication the authority is currently issuing (1-based).
    pub auth_k: u64,
    /// Authority program counter.
    pub auth_pc: AuthPc,
    /// One program counter per reader.
    pub readers: Vec<ReaderPc>,
}

/// Model of the snapshot slot (`crate::serve::pool::SnapshotSlot`):
/// one authority issuing `pubs` publications — payload, chunk count,
/// then sequence number, in that order — racing `readers` concurrent
/// warm-respawn installers that load the sequence and then read the
/// payload.
///
/// Invariant: a reader that observed sequence `s` must install a
/// payload from publication `>= s`. With `seq_first: true` the store
/// order is inverted (sequence released before the payload lands) and
/// the checker must find the stale-install interleaving.
#[derive(Debug, Clone, Copy)]
pub struct SlotSpec {
    /// Number of publications the authority issues.
    pub pubs: u64,
    /// Number of concurrent readers.
    pub readers: usize,
    /// Invert the store order (the planted bug): sequence number first,
    /// payload after.
    pub seq_first: bool,
}

impl Spec for SlotSpec {
    type State = SlotState;

    fn init(&self) -> SlotState {
        SlotState {
            payload: 0,
            chunks: 0,
            seq: 0,
            auth_k: 1,
            auth_pc: if self.pubs == 0 {
                AuthPc::Idle
            } else if self.seq_first {
                AuthPc::StoreSeq
            } else {
                AuthPc::WritePayload
            },
            readers: vec![ReaderPc::LoadSeq; self.readers],
        }
    }

    fn actors(&self) -> usize {
        1 + self.readers
    }

    fn enabled(&self, s: &SlotState, a: usize) -> bool {
        if a == 0 {
            s.auth_pc != AuthPc::Idle
        } else {
            !matches!(s.readers[a - 1], ReaderPc::Done { .. })
        }
    }

    fn done(&self, s: &SlotState, a: usize) -> bool {
        !self.enabled(s, a)
    }

    fn step(&self, s: &mut SlotState, a: usize) {
        if a == 0 {
            // One publication is three stores; on the good path the
            // sequence number is last, on the broken path it is first.
            let next_pub = |s: &mut SlotState| {
                if s.auth_k < self.pubs {
                    s.auth_k += 1;
                    if self.seq_first { AuthPc::StoreSeq } else { AuthPc::WritePayload }
                } else {
                    AuthPc::Idle
                }
            };
            s.auth_pc = match s.auth_pc {
                AuthPc::WritePayload => {
                    s.payload = s.auth_k;
                    AuthPc::StoreChunks
                }
                AuthPc::StoreChunks => {
                    s.chunks = s.auth_k;
                    if self.seq_first { next_pub(s) } else { AuthPc::StoreSeq }
                }
                AuthPc::StoreSeq => {
                    s.seq = s.auth_k;
                    if self.seq_first { AuthPc::WritePayload } else { next_pub(s) }
                }
                AuthPc::Idle => unreachable!("stepped an idle authority"),
            };
        } else {
            let r = a - 1;
            s.readers[r] = match s.readers[r] {
                ReaderPc::LoadSeq => {
                    let observed = s.seq;
                    if observed == 0 {
                        // Nothing published yet — the real reader keeps
                        // its cold state.
                        ReaderPc::Done { observed: 0, installed: 0 }
                    } else {
                        ReaderPc::Install { observed }
                    }
                }
                ReaderPc::Install { observed } => {
                    // Locked critical section: read the payload.
                    ReaderPc::Done { observed, installed: s.payload }
                }
                ReaderPc::Done { .. } => unreachable!("stepped a finished reader"),
            };
        }
    }

    fn check(&self, s: &SlotState) -> std::result::Result<(), String> {
        for (i, r) in s.readers.iter().enumerate() {
            if let ReaderPc::Done { observed, installed } = r {
                if *observed > 0 && installed < observed {
                    return Err(format!(
                        "stale install: reader {i} observed seq {observed} \
                         but installed publication {installed}"
                    ));
                }
            }
        }
        Ok(())
    }

    fn check_final(&self, s: &SlotState) -> std::result::Result<(), String> {
        if s.payload != self.pubs || s.seq != self.pubs || s.chunks != self.pubs {
            return Err(format!(
                "incomplete publication: payload {} chunks {} seq {} after {} pubs",
                s.payload, s.chunks, s.seq, self.pubs
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Checkpoint barrier
// ---------------------------------------------------------------------------

/// Actor indices of [`BarrierSpec`] (fixed cast of five).
pub mod barrier_actors {
    /// Admits one request when the barrier is open.
    pub const ARRIVE: usize = 0;
    /// Completes one in-flight request and counts its annotation.
    pub const ROUTE: usize = 1;
    /// The serve loop's `maybe_arm` poll.
    pub const ARM: usize = 2;
    /// Attempts the export at quiescence and records the outcome.
    pub const EXPORT: usize = 3;
    /// The supervision sweep respawning a dead authority.
    pub const RESPAWN: usize = 4;
}

/// Model state of [`BarrierSpec`]; embeds the **production**
/// [`CkptBarrier`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BarrierState {
    /// The real barrier under test.
    pub barrier: CkptBarrier,
    /// Requests admitted so far.
    pub arrived: usize,
    /// Requests in flight (admitted, not yet completed).
    pub pending: usize,
    /// Export outcomes consumed from the script.
    pub exported: usize,
    /// A level authority is dead and awaits respawn.
    pub dead: bool,
}

/// Model of the quiescent checkpoint barrier: five actors (arrival,
/// router, arm poll, export, respawn) drive the production
/// [`CkptBarrier`] with a scripted sequence of per-attempt
/// [`ExportOutcome`]s.
///
/// By construction of the enabled-conditions, exports happen only at
/// quiescence (`pending == 0`) and only while armed — mirroring the
/// serve loop. The checked invariants are the ones the barrier itself
/// must uphold across every interleaving: its write/abort counters
/// match the consumed script exactly (at most one write per arm), and
/// a dead authority never disarms it (respawn-and-retry happens under
/// the same arm). Final checks: all requests completed, admission is
/// re-opened, and at least one export resolved whenever the cadence
/// was reachable. A script that strands an armed barrier with no
/// resolving outcome is reported as wedged admission — `test_loom`'s
/// meta-test relies on that.
#[derive(Debug, Clone)]
pub struct BarrierSpec {
    /// Total requests the arrival actor admits.
    pub requests: usize,
    /// Cadence (annotations per checkpoint), `ServeConfig::ckpt_every`.
    pub every: usize,
    /// Outcome of each successive export attempt. Every
    /// [`ExportOutcome::AuthorityDead`] must eventually be followed by
    /// a resolving outcome, or the model (correctly) wedges.
    pub outcomes: Vec<ExportOutcome>,
}

impl BarrierSpec {
    fn scripted(&self, upto: usize, which: ExportOutcome) -> u64 {
        self.outcomes[..upto].iter().filter(|o| **o == which).count() as u64
    }
}

impl Spec for BarrierSpec {
    type State = BarrierState;

    fn init(&self) -> BarrierState {
        BarrierState {
            barrier: CkptBarrier::new(self.every),
            arrived: 0,
            pending: 0,
            exported: 0,
            dead: false,
        }
    }

    fn actors(&self) -> usize {
        5
    }

    fn enabled(&self, s: &BarrierState, a: usize) -> bool {
        match a {
            barrier_actors::ARRIVE => s.arrived < self.requests && !s.barrier.paused(),
            barrier_actors::ROUTE => s.pending > 0,
            barrier_actors::ARM => {
                !s.barrier.paused()
                    && self.every > 0
                    && s.barrier.anns_since() >= self.every
                    && s.exported < self.outcomes.len()
            }
            barrier_actors::EXPORT => {
                s.barrier.paused()
                    && s.pending == 0
                    && !s.dead
                    && s.exported < self.outcomes.len()
            }
            barrier_actors::RESPAWN => s.dead,
            _ => false,
        }
    }

    fn done(&self, s: &BarrierState, a: usize) -> bool {
        match a {
            barrier_actors::ARRIVE => s.arrived == self.requests,
            barrier_actors::ROUTE => s.pending == 0,
            // The daemon actors are done whenever they have nothing to
            // do; a wedged ARRIVE/ROUTE is what flags a stuck barrier.
            _ => !self.enabled(s, a),
        }
    }

    fn step(&self, s: &mut BarrierState, a: usize) {
        match a {
            barrier_actors::ARRIVE => {
                s.arrived += 1;
                s.pending += 1;
            }
            barrier_actors::ROUTE => {
                s.pending -= 1;
                s.barrier.note_annotation();
            }
            barrier_actors::ARM => {
                s.barrier.maybe_arm();
            }
            barrier_actors::EXPORT => {
                let outcome = self.outcomes[s.exported];
                s.exported += 1;
                s.barrier.record(outcome);
                if outcome == ExportOutcome::AuthorityDead {
                    s.dead = true;
                }
            }
            barrier_actors::RESPAWN => {
                s.dead = false;
            }
            _ => unreachable!("unknown actor {a}"),
        }
    }

    fn check(&self, s: &BarrierState) -> std::result::Result<(), String> {
        let want_writes = self.scripted(s.exported, ExportOutcome::Written);
        let want_aborts = self.scripted(s.exported, ExportOutcome::TimedOut);
        if s.barrier.writes() != want_writes {
            return Err(format!(
                "write counter diverged: barrier says {} but the script resolved {}",
                s.barrier.writes(),
                want_writes
            ));
        }
        if s.barrier.aborts() != want_aborts {
            return Err(format!(
                "abort counter diverged: barrier says {} but the script timed out {}",
                s.barrier.aborts(),
                want_aborts
            ));
        }
        if s.dead && !s.barrier.paused() {
            return Err("dead authority disarmed the barrier: a respawn-and-retry \
                        would export a non-quiescent state"
                .to_string());
        }
        Ok(())
    }

    fn check_final(&self, s: &BarrierState) -> std::result::Result<(), String> {
        if s.arrived != self.requests || s.pending != 0 {
            return Err(format!(
                "stream incomplete: {}/{} arrived, {} pending",
                s.arrived, self.requests, s.pending
            ));
        }
        if s.dead {
            return Err("authority left dead at shutdown".to_string());
        }
        if s.barrier.paused() {
            return Err("admission wedged: barrier still armed at quiescence \
                        with no resolving export outcome"
                .to_string());
        }
        let reachable =
            self.every > 0 && self.requests >= self.every && !self.outcomes.is_empty();
        if reachable && s.exported == 0 {
            return Err("cadence was reachable but no export was ever attempted".to_string());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Autoscaler
// ---------------------------------------------------------------------------

/// One replica slot in [`ScaleSpec`]: its birth identity and the job
/// it is currently running, if any.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Replica {
    /// Birth order (0 = the learner authority, worker 0).
    pub id: usize,
    /// Index of the job this replica is running (`None` = idle).
    pub job: Option<usize>,
}

/// Per-job program counter in [`ScaleSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScaleJobPc {
    /// Waiting in the level queue.
    Queued,
    /// Dispatched to a replica.
    Running {
        /// Birth id of the replica running this job.
        replica: usize,
    },
    /// Completed.
    Done,
}

/// Shared + per-actor state of the autoscaler model; embeds the
/// **production** [`ScaleController`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ScaleState {
    /// The real hysteresis controller under test.
    pub ctrl: ScaleController,
    /// Live replicas in pool order (index 0 must stay the authority).
    pub members: Vec<Replica>,
    /// Birth id the next grown replica gets.
    pub next_id: usize,
    /// Dispatch sweeps the controller actor has run.
    pub swept: usize,
    /// Scale-up events applied.
    pub ups: usize,
    /// Scale-down events applied.
    pub downs: usize,
    /// One program counter per job.
    pub jobs: Vec<ScaleJobPc>,
}

impl ScaleState {
    fn queued(&self) -> usize {
        self.jobs.iter().filter(|j| matches!(j, ScaleJobPc::Queued)).count()
    }
}

/// Model of the serve loop's elasticity step: one controller actor
/// running the **production** [`ScaleController`] against the live
/// queue depth and applying its decisions to the replica vector,
/// racing `jobs` job actors that claim idle replicas, run, and
/// release them.
///
/// Invariants checked after every step: the replica count never
/// leaves `[min_replicas, max_replicas]` and always equals
/// `min + ups - downs`, index 0 is always the original authority
/// (worker 0), and no busy replica is ever removed. With
/// `remove_authority: true` the scale-down victim selection is broken
/// — first idle replica, which can be worker 0, instead of the
/// production highest-index-only rule — and the checker must catch
/// the authority removal (meta-test in `tests/test_loom.rs`).
#[derive(Debug, Clone)]
pub struct ScaleSpec {
    /// Number of job actors.
    pub jobs: usize,
    /// Dispatch sweeps the controller actor runs.
    pub sweeps: usize,
    /// Controller policy; the model starts at `min_replicas`.
    pub policy: ScalePolicy,
    /// Planted bug: pick the first idle replica as the scale-down
    /// victim instead of the highest-index replica only.
    pub remove_authority: bool,
}

impl Spec for ScaleSpec {
    type State = ScaleState;

    fn init(&self) -> ScaleState {
        let start = self.policy.min_replicas;
        ScaleState {
            ctrl: ScaleController::new(self.policy),
            members: (0..start).map(|id| Replica { id, job: None }).collect(),
            next_id: start,
            swept: 0,
            ups: 0,
            downs: 0,
            jobs: vec![ScaleJobPc::Queued; self.jobs],
        }
    }

    fn actors(&self) -> usize {
        1 + self.jobs
    }

    fn enabled(&self, s: &ScaleState, a: usize) -> bool {
        if a == 0 {
            s.swept < self.sweeps
        } else {
            match s.jobs[a - 1] {
                ScaleJobPc::Queued => s.members.iter().any(|m| m.job.is_none()),
                ScaleJobPc::Running { .. } => true,
                ScaleJobPc::Done => false,
            }
        }
    }

    fn done(&self, s: &ScaleState, a: usize) -> bool {
        if a == 0 {
            s.swept == self.sweeps
        } else {
            matches!(s.jobs[a - 1], ScaleJobPc::Done)
        }
    }

    fn step(&self, s: &mut ScaleState, a: usize) {
        if a == 0 {
            // One dispatch sweep: observe, decide, apply under the
            // production guards (or the planted-bug victim rule).
            let depth = s.queued();
            let replicas = s.members.len();
            match s.ctrl.decide(depth, replicas) {
                ScaleDecision::Up => {
                    s.members.push(Replica { id: s.next_id, job: None });
                    s.next_id += 1;
                    s.ups += 1;
                }
                ScaleDecision::Down => {
                    let victim = if self.remove_authority {
                        s.members.iter().position(|m| m.job.is_none())
                    } else {
                        let last = s.members.len() - 1;
                        (last > 0 && s.members[last].job.is_none()).then_some(last)
                    };
                    // A busy (or absent) victim skips the event — the
                    // decision is consumed without a removal, exactly
                    // like the serve loop.
                    if let Some(v) = victim {
                        s.members.remove(v);
                        s.downs += 1;
                    }
                }
                ScaleDecision::Hold => {}
            }
            s.swept += 1;
        } else {
            let j = a - 1;
            s.jobs[j] = match s.jobs[j] {
                ScaleJobPc::Queued => {
                    // Claim the lowest-index idle replica, like the
                    // dispatch loop's free_replica scan.
                    let m = s
                        .members
                        .iter()
                        .position(|m| m.job.is_none())
                        .expect("enabled only with an idle replica");
                    s.members[m].job = Some(j);
                    ScaleJobPc::Running { replica: s.members[m].id }
                }
                ScaleJobPc::Running { replica } => {
                    if let Some(m) = s.members.iter_mut().find(|m| m.id == replica) {
                        m.job = None;
                    }
                    ScaleJobPc::Done
                }
                ScaleJobPc::Done => unreachable!("stepped a finished job"),
            };
        }
    }

    fn check(&self, s: &ScaleState) -> std::result::Result<(), String> {
        let n = s.members.len();
        if n < self.policy.min_replicas || n > self.policy.max_replicas {
            return Err(format!(
                "replica count {n} left the bounds [{}, {}]",
                self.policy.min_replicas, self.policy.max_replicas
            ));
        }
        match s.members.first() {
            Some(m) if m.id == 0 => {}
            _ => {
                return Err("the learner authority (worker 0) was scaled away".to_string());
            }
        }
        if n + s.downs != self.policy.min_replicas + s.ups {
            return Err(format!(
                "replica accounting broken: {n} members after {} ups / {} downs from {}",
                s.ups, s.downs, self.policy.min_replicas
            ));
        }
        for (j, pc) in s.jobs.iter().enumerate() {
            if let ScaleJobPc::Running { replica } = pc {
                if !s.members.iter().any(|m| m.id == *replica && m.job == Some(j)) {
                    return Err(format!(
                        "job {j} in flight on replica {replica}, which was removed"
                    ));
                }
            }
        }
        Ok(())
    }

    fn check_final(&self, s: &ScaleState) -> std::result::Result<(), String> {
        if let Some(j) = s.jobs.iter().position(|p| !matches!(p, ScaleJobPc::Done)) {
            return Err(format!("job {j} never completed"));
        }
        if let Some(m) = s.members.iter().find(|m| m.job.is_some()) {
            return Err(format!("replica {} still holds a job at quiescence", m.id));
        }
        Ok(())
    }
}
