//! In-tree exhaustive interleaving explorer for the serve layer's
//! concurrency protocol cores (the crate's loom-style model checker).
//!
//! **Why in-tree.** The crate's contract is a zero-dependency default
//! build that compiles fully offline; pulling the `loom` crate in —
//! even dev- or cfg-gated — would break offline resolution. So this
//! module provides the part of loom the protocols need: model each
//! actor as an explicit step machine over shared cloneable state, and
//! have [`Explorer`] drive a depth-first search over *every* schedule
//! of atomic steps, checking invariants after each step, detecting
//! deadlocks (a non-final state where no actor can move), and
//! asserting final-state properties on every terminal schedule.
//!
//! **Granularity and honesty.** A "step" is one atomic action
//! (one load, one CAS, one store, one locked critical section), which
//! makes the explored space the *sequentially consistent* one. Real
//! loom additionally models C11 weak-memory reorderings; the serve
//! protocols compensate by using conservative orderings at their
//! publication edges (`Release` stores / `Acquire` loads, AcqRel CAS)
//! and by backing the models with real-thread stress + ThreadSanitizer
//! CI jobs (see DESIGN.md §11). Swapping in real loom later is a
//! [`crate::sync`]-only change; these models and their invariants
//! carry over unchanged.
//!
//! The three protocol models live in [`models`]: the admission gate's
//! acquire/release/shed CAS loop, the snapshot slot's publish/install
//! ordering, and the checkpoint barrier's pause→drain→export→resume
//! machine (which drives the *production* [`crate::serve::barrier::CkptBarrier`],
//! not a re-implementation). `tests/test_loom.rs` explores them
//! bounded under plain `cargo test` and exhaustively under
//! `--cfg loom` (`RUSTFLAGS="--cfg loom"`), where it additionally
//! asserts the exploration completed with no truncation.

pub mod models;

use std::collections::HashSet;
use std::hash::Hash;

/// A concurrent protocol modeled as actors taking atomic steps over
/// shared state.
///
/// The explorer owns the schedule: it picks which enabled actor steps
/// next and branches over every choice. Implementations must keep each
/// `step` *atomic* (one load/store/CAS/critical-section) — that is
/// what makes the explored interleavings meaningful.
pub trait Spec {
    /// Full system state (shared + every actor's program counter).
    /// `Eq + Hash` lets the explorer prune states it has already
    /// fully verified.
    type State: Clone + Eq + Hash;

    /// The initial state.
    fn init(&self) -> Self::State;

    /// Number of actors (schedulable threads) in the model.
    fn actors(&self) -> usize;

    /// Can `actor` take a step in `state`? A `false` for every actor
    /// makes the state terminal: legal if every actor is [`Spec::done`],
    /// a deadlock otherwise.
    fn enabled(&self, state: &Self::State, actor: usize) -> bool;

    /// Has `actor` finished its program in `state`?
    fn done(&self, state: &Self::State, actor: usize) -> bool;

    /// Execute one atomic step of `actor`. Only called when
    /// [`Spec::enabled`] returned `true` for it.
    fn step(&self, state: &mut Self::State, actor: usize);

    /// Safety invariant, checked on the initial state and after every
    /// step of every explored schedule. `Err(msg)` fails the run.
    fn check(&self, state: &Self::State) -> std::result::Result<(), String>;

    /// Terminal-state property, checked on every legal terminal state.
    fn check_final(&self, state: &Self::State) -> std::result::Result<(), String>;
}

/// A property violation found by [`Explorer::explore`], carrying the
/// schedule (sequence of actor indices) that reproduces it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// [`Spec::check`] failed after some step.
    Invariant {
        /// The failure message from the spec.
        msg: String,
        /// Actor schedule from the initial state to the bad state.
        trace: Vec<usize>,
    },
    /// A reachable state where no actor can move but not all are done.
    Deadlock {
        /// Actor schedule from the initial state to the stuck state.
        trace: Vec<usize>,
    },
    /// [`Spec::check_final`] failed on a legal terminal state.
    Final {
        /// The failure message from the spec.
        msg: String,
        /// Actor schedule from the initial state to the terminal state.
        trace: Vec<usize>,
    },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::Invariant { msg, trace } => {
                write!(f, "invariant violated: {msg} (schedule {trace:?})")
            }
            Violation::Deadlock { trace } => {
                write!(f, "deadlock reached (schedule {trace:?})")
            }
            Violation::Final { msg, trace } => {
                write!(f, "final-state check failed: {msg} (schedule {trace:?})")
            }
        }
    }
}

/// Statistics from a successful exploration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Exploration {
    /// Distinct states proven (memoized subtree roots).
    pub states: usize,
    /// Total atomic steps executed across all schedules.
    pub steps: u64,
    /// `true` when the whole interleaving space was explored;
    /// `false` when the step budget truncated the search. Exhaustive
    /// runs (`--cfg loom`) must see `true`.
    pub complete: bool,
}

/// Depth-first scheduler over every interleaving of a [`Spec`].
#[derive(Debug, Clone, Copy)]
pub struct Explorer {
    /// Step budget; the search reports `complete: false` when it runs
    /// out instead of failing.
    pub max_steps: u64,
}

impl Explorer {
    /// A budgeted explorer for quick default-profile runs.
    pub fn bounded(max_steps: u64) -> Self {
        Explorer { max_steps }
    }

    /// An unbudgeted explorer: explores the entire space (the
    /// `--cfg loom` profile).
    pub fn exhaustive() -> Self {
        Explorer { max_steps: u64::MAX }
    }

    /// Explore every schedule of `spec`, returning statistics or the
    /// first [`Violation`] found (with its reproducing schedule).
    pub fn explore<S: Spec>(&self, spec: &S) -> std::result::Result<Exploration, Violation> {
        let init = spec.init();
        spec.check(&init)
            .map_err(|msg| Violation::Invariant { msg, trace: Vec::new() })?;
        let mut cx = Cx {
            seen: HashSet::new(),
            path: HashSet::new(),
            steps: 0,
            complete: true,
            trace: Vec::new(),
        };
        self.dfs(spec, init, &mut cx)?;
        Ok(Exploration { states: cx.seen.len(), steps: cx.steps, complete: cx.complete })
    }

    fn dfs<S: Spec>(
        &self,
        spec: &S,
        state: S::State,
        cx: &mut Cx<S::State>,
    ) -> std::result::Result<(), Violation> {
        if cx.seen.contains(&state) || cx.path.contains(&state) {
            // Already proven, or a cycle back to a state currently on
            // the stack (whose successors the ancestor call explores).
            return Ok(());
        }
        let enabled: Vec<usize> =
            (0..spec.actors()).filter(|&a| spec.enabled(&state, a)).collect();
        if enabled.is_empty() {
            if (0..spec.actors()).all(|a| spec.done(&state, a)) {
                spec.check_final(&state).map_err(|msg| Violation::Final {
                    msg,
                    trace: cx.trace.clone(),
                })?;
            } else {
                return Err(Violation::Deadlock { trace: cx.trace.clone() });
            }
            cx.seen.insert(state);
            return Ok(());
        }
        cx.path.insert(state.clone());
        for a in enabled {
            if cx.steps >= self.max_steps {
                cx.complete = false;
                cx.path.remove(&state);
                return Ok(());
            }
            let mut next = state.clone();
            spec.step(&mut next, a);
            cx.steps += 1;
            cx.trace.push(a);
            spec.check(&next).map_err(|msg| Violation::Invariant {
                msg,
                trace: cx.trace.clone(),
            })?;
            self.dfs(spec, next, cx)?;
            cx.trace.pop();
        }
        cx.path.remove(&state);
        // Memoize only subtrees proven in full — a budget-truncated
        // subtree must not masquerade as verified.
        if cx.complete {
            cx.seen.insert(state);
        }
        Ok(())
    }
}

struct Cx<S> {
    seen: HashSet<S>,
    path: HashSet<S>,
    steps: u64,
    complete: bool,
    trace: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two actors increment a shared counter through a modeled
    /// load-then-store (non-atomic) — the classic lost update. The
    /// checker must find the schedule where an update is lost when the
    /// final check demands both increments landed.
    struct LostUpdate;

    #[derive(Clone, PartialEq, Eq, Hash)]
    struct LuState {
        n: u64,
        pcs: [LuPc; 2],
    }

    #[derive(Clone, Copy, PartialEq, Eq, Hash)]
    enum LuPc {
        Load,
        Store(u64),
        Done,
    }

    impl Spec for LostUpdate {
        type State = LuState;
        fn init(&self) -> LuState {
            LuState { n: 0, pcs: [LuPc::Load; 2] }
        }
        fn actors(&self) -> usize {
            2
        }
        fn enabled(&self, s: &LuState, a: usize) -> bool {
            s.pcs[a] != LuPc::Done
        }
        fn done(&self, s: &LuState, a: usize) -> bool {
            s.pcs[a] == LuPc::Done
        }
        fn step(&self, s: &mut LuState, a: usize) {
            s.pcs[a] = match s.pcs[a] {
                LuPc::Load => LuPc::Store(s.n),
                LuPc::Store(seen) => {
                    s.n = seen + 1;
                    LuPc::Done
                }
                LuPc::Done => unreachable!("stepped a done actor"),
            };
        }
        fn check(&self, _s: &LuState) -> std::result::Result<(), String> {
            Ok(())
        }
        fn check_final(&self, s: &LuState) -> std::result::Result<(), String> {
            if s.n == 2 {
                Ok(())
            } else {
                Err(format!("lost update: n = {} after two increments", s.n))
            }
        }
    }

    #[test]
    fn finds_the_lost_update_interleaving() {
        let err = Explorer::exhaustive().explore(&LostUpdate).unwrap_err();
        match err {
            Violation::Final { msg, trace } => {
                assert!(msg.contains("lost update"), "{msg}");
                assert_eq!(trace.len(), 4, "both actors ran to completion");
            }
            other => panic!("expected a final-state violation, got {other}"),
        }
    }

    #[test]
    fn budget_truncation_reports_incomplete_not_verified() {
        let e = Explorer::bounded(1).explore(&LostUpdate);
        // With a 1-step budget the bad schedule is unreachable; the
        // result must be an *incomplete* pass, never a claimed proof.
        match e {
            Ok(x) => assert!(!x.complete, "1 step cannot cover the space"),
            Err(_) => {} // finding the violation early is also legal
        }
    }
}
