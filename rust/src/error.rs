//! Crate-wide error type.
//!
//! Hand-written `Display`/`Error` impls (no `thiserror`): the default
//! build of this crate has zero external dependencies and must compile
//! fully offline.

use std::fmt;

/// Unified error for every subsystem in the crate.
#[derive(Debug)]
pub enum Error {
    /// Artifact manifest missing, malformed, or inconsistent.
    Manifest(String),

    /// JSON parse/serialize failure (codec substrate).
    Json {
        /// Byte offset of the failure in the input.
        offset: usize,
        /// What went wrong.
        msg: String,
    },

    /// Configuration error (unknown preset, invalid value, ...).
    Config(String),

    /// CLI usage error.
    Usage(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// A model worker thread died or a channel closed unexpectedly.
    Worker(String),

    /// A latency/throughput service-level objective was violated
    /// (serve-layer load harness assertions).
    Slo(String),

    /// Checkpoint save/restore failure (missing, truncated, or
    /// version-incompatible checkpoint state; see `serve::ckpt`).
    Ckpt(String),

    /// Data/benchmark construction failure.
    Data(String),

    /// Wire-protocol violation (bad frame version/tag, oversized or
    /// malformed frame; see `serve::net`). Distinct from [`Error::Json`]
    /// so a server can drop one bad connection without conflating it
    /// with a corrupt local artifact.
    Wire(String),

    /// I/O error with path context.
    Io {
        /// Path the operation touched.
        path: String,
        /// Underlying OS error.
        source: std::io::Error,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Worker(m) => write!(f, "worker error: {m}"),
            Error::Slo(m) => write!(f, "slo violation: {m}"),
            Error::Ckpt(m) => write!(f, "checkpoint error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Wire(m) => write!(f, "wire error: {m}"),
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Attach a path to an `std::io::Error`.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_thiserror_era_messages() {
        assert_eq!(Error::Manifest("x".into()).to_string(), "manifest error: x");
        assert_eq!(
            Error::Json { offset: 7, msg: "bad".into() }.to_string(),
            "json error at byte 7: bad"
        );
        assert_eq!(Error::Config("c".into()).to_string(), "config error: c");
        assert_eq!(Error::Usage("u".into()).to_string(), "usage error: u");
        assert_eq!(Error::Runtime("r".into()).to_string(), "runtime error: r");
        assert_eq!(Error::Worker("w".into()).to_string(), "worker error: w");
        assert_eq!(Error::Slo("s".into()).to_string(), "slo violation: s");
        assert_eq!(Error::Ckpt("k".into()).to_string(), "checkpoint error: k");
        assert_eq!(Error::Data("d".into()).to_string(), "data error: d");
        assert_eq!(Error::Wire("n".into()).to_string(), "wire error: n");
    }

    #[test]
    fn io_variant_carries_path_and_source() {
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let s = e.to_string();
        assert!(s.starts_with("io error on /tmp/x:"), "{s}");
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::Data("d".into())).is_none());
    }
}
