//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every subsystem in the crate.
#[derive(Error, Debug)]
pub enum Error {
    /// Artifact manifest missing, malformed, or inconsistent.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// JSON parse/serialize failure (codec substrate).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Configuration error (unknown preset, invalid value, ...).
    #[error("config error: {0}")]
    Config(String),

    /// CLI usage error.
    #[error("usage error: {0}")]
    Usage(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// A model worker thread died or a channel closed unexpectedly.
    #[error("worker error: {0}")]
    Worker(String),

    /// Data/benchmark construction failure.
    #[error("data error: {0}")]
    Data(String),

    /// I/O error with path context.
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Attach a path to an `std::io::Error`.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
