//! Baselines the paper compares against (§4): online ensemble
//! learning (the deferral-policy ablation) and knowledge distillation
//! (the offline-learning comparator). The static confidence-threshold
//! cascade lives in [`crate::cascade::DeferralRule`].

use std::rc::Rc;

use crate::config::{CascadeConfig, ModelKind};
use crate::data::Sample;
use crate::error::Result;
use crate::models::{build_level, Featurized, LevelModel, Pipeline};
use crate::prng::Rng;
use crate::sim::cost::CostModel;
use crate::sim::Expert;
use crate::util::{argmax, Ring};

use crate::cascade::metrics::StreamMetrics;

/// Online ensemble learning (paper §4, Thm 3.1 setting): all models
/// vote with learned static mixing weights; the LLM is consulted at a
/// budget-matching annotation rate, and small models train online on
/// its annotations — the ablation that removes deferral-policy
/// learning from OCL.
pub struct OnlineEnsemble {
    models: Vec<Box<dyn LevelModel>>,
    /// Multiplicative-weights mixture over the models.
    weights: Vec<f64>,
    /// Per-model annotation ring caches (same replay design as OCL).
    caches: Vec<Ring<(Rc<Featurized>, usize)>>,
    pendings: Vec<usize>,
    lrs: Vec<f32>,
    batch: usize,
    /// Probability of consulting the expert on a given query.
    annotate_rate: f64,
    expert: Expert,
    pipeline: Pipeline,
    rng: Rng,
    classes: usize,
    /// Evaluation metrics (same schema as the cascade's).
    pub metrics: StreamMetrics,
    eta: f64,
}

impl OnlineEnsemble {
    /// Build the ensemble from the same config the cascade uses.
    /// `annotate_rate` ≈ budget / stream-length (the paper matches
    /// budgets across methods).
    pub fn new(
        cfg: &CascadeConfig,
        classes: usize,
        expert: Expert,
        annotate_rate: f64,
        pjrt: Option<&Rc<crate::runtime::PjrtEngine>>,
    ) -> Result<Self> {
        let engine_ref = if cfg.engine.is_pjrt() { pjrt } else { None };
        let mut models = Vec::new();
        let mut caches = Vec::new();
        let mut lrs = Vec::new();
        for (i, lc) in cfg.levels.iter().enumerate() {
            models.push(build_level(
                engine_ref,
                lc.model,
                classes,
                cfg.seed ^ (0xE5E + i as u64),
            )?);
            caches.push(Ring::new(lc.cache_size.max(lc.batch_size) * 16));
            lrs.push(lc.model_lr);
        }
        let n = models.len();
        Ok(OnlineEnsemble {
            models,
            weights: vec![1.0 / n as f64; n],
            caches,
            pendings: vec![0; n],
            lrs,
            batch: 8,
            annotate_rate: annotate_rate.clamp(0.0, 1.0),
            expert,
            pipeline: Pipeline::default(),
            rng: Rng::new(cfg.seed ^ 0x0E15),
            classes,
            metrics: StreamMetrics::new(n + 1, classes, usize::MAX / 2),
            eta: 0.5,
        })
    }

    /// Process one query.
    pub fn process(&mut self, sample: &Sample) -> usize {
        let f = Rc::new(self.pipeline.featurize(&sample.text));
        let mut flops = 0.0;
        let preds: Vec<Vec<f32>> = self
            .models
            .iter_mut()
            .map(|m| {
                let p = m.predict(&f);
                p
            })
            .collect();
        for m in &self.models {
            flops += CostModel::infer_flops(m.kind());
        }
        // Weighted mixture vote.
        let mut mix = vec![0.0f32; self.classes];
        for (w, p) in self.weights.iter().zip(&preds) {
            for (mv, &pv) in mix.iter_mut().zip(p) {
                *mv += *w as f32 * pv;
            }
        }
        let consult = self.rng.coin(self.annotate_rate);
        let (pred, expert_called) = if consult {
            match self.expert.annotate(sample, self.classes) {
                Some(y_star) => {
                    flops += self.expert.flops_per_call();
                    // Multiplicative-weights update against the
                    // annotation + online model updates.
                    for (i, p) in preds.iter().enumerate() {
                        let correct = argmax(p) == y_star;
                        if !correct {
                            self.weights[i] *= (-self.eta).exp();
                        }
                        self.caches[i].push((f.clone(), y_star));
                        self.pendings[i] += 1;
                        if self.pendings[i] >= self.batch {
                            flops += self.train_model(i);
                            self.pendings[i] = 0;
                        }
                    }
                    let total: f64 = self.weights.iter().sum();
                    for w in &mut self.weights {
                        *w /= total;
                    }
                    (y_star, true)
                }
                None => (argmax(&mix), false),
            }
        } else {
            (argmax(&mix), false)
        };
        let expert_would = self.expert.peek(sample, self.classes) == sample.label;
        self.metrics.record(
            pred,
            sample.label,
            if expert_called { self.models.len() } else { 0 },
            expert_called,
            expert_would,
            flops,
        );
        pred
    }

    fn train_model(&mut self, i: usize) -> f64 {
        let items = self.caches[i].to_vec();
        if items.len() < self.batch {
            return 0.0;
        }
        let mut picked: Vec<usize> =
            (items.len() - self.batch / 2..items.len()).collect();
        picked.extend(self.rng.sample_indices(items.len(), self.batch - self.batch / 2));
        let mut flops = 0.0;
        for chunk in picked.chunks(8) {
            if chunk.len() < 8 {
                break;
            }
            let b: Vec<(&Featurized, usize)> =
                chunk.iter().map(|&j| (items[j].0.as_ref(), items[j].1)).collect();
            self.models[i].train(&b, self.lrs[i]);
            flops += CostModel::train_flops(self.models[i].kind()) * 8.0;
        }
        flops
    }

    /// Run a whole stream; returns final accuracy.
    pub fn run_stream(&mut self, stream: &[&Sample]) -> f64 {
        for s in stream {
            self.process(s);
        }
        self.metrics.finalize();
        self.metrics.accuracy()
    }

    /// Reset evaluation metrics, keeping all learned state (the
    /// test-half protocol — see `Cascade::reset_metrics`).
    pub fn reset_metrics(&mut self) {
        self.metrics =
            StreamMetrics::new(self.models.len() + 1, self.classes, usize::MAX / 2);
    }

    /// Learned mixture weights (diagnostics).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

/// Knowledge distillation (paper §4): spend the whole annotation
/// budget on a train prefix (the paper splits 50/50), fine-tune one
/// small model on the LLM labels for several epochs, then evaluate
/// frozen on the test half.
pub struct Distillation {
    /// Which model is distilled (the paper reports LR and BERT-base).
    pub kind: ModelKind,
    model: Box<dyn LevelModel>,
    pipeline: Pipeline,
    rng: Rng,
    classes: usize,
    epochs: usize,
    lr: f32,
    /// Evaluation metrics over the test half.
    pub metrics: StreamMetrics,
}

impl Distillation {
    /// Build a distillation baseline.
    pub fn new(
        kind: ModelKind,
        classes: usize,
        seed: u64,
        pjrt: Option<&Rc<crate::runtime::PjrtEngine>>,
    ) -> Result<Self> {
        Ok(Distillation {
            kind,
            model: build_level(pjrt, kind, classes, seed ^ 0xD157)?,
            pipeline: Pipeline::default(),
            rng: Rng::new(seed ^ 0xD157_111),
            classes,
            // Paper B.3: batch 8, 5 epochs for BERT distillation.
            epochs: 5,
            lr: match kind {
                ModelKind::Lr => 0.5,
                _ => 2e-3,
            },
            metrics: StreamMetrics::new(2, classes, usize::MAX / 2),
        })
    }

    /// Train on up to `budget` expert-annotated samples from
    /// `train_half`, then evaluate on `test_half`. Returns accuracy.
    pub fn run(
        &mut self,
        expert: &Expert,
        train_half: &[&Sample],
        test_half: &[&Sample],
        budget: usize,
    ) -> f64 {
        // Annotate a budget-sized prefix (the stream arrives in order).
        let take = budget.min(train_half.len());
        let mut annotated: Vec<(Featurized, usize)> = Vec::with_capacity(take);
        for s in &train_half[..take] {
            if let Some(y) = expert.annotate(s, self.classes) {
                annotated.push((self.pipeline.featurize(&s.text), y));
            }
        }
        // Epoch training with shuffling.
        for _ in 0..self.epochs {
            let order = self.rng.permutation(annotated.len());
            for chunk in order.chunks(8) {
                if chunk.len() < 8 {
                    break;
                }
                let batch: Vec<(&Featurized, usize)> =
                    chunk.iter().map(|&j| (&annotated[j].0, annotated[j].1)).collect();
                self.model.train(&batch, self.lr);
            }
        }
        // Frozen evaluation.
        for s in test_half {
            let f = self.pipeline.featurize(&s.text);
            let pred = argmax(&self.model.predict(&f));
            let expert_would = expert.peek(s, self.classes) == s.label;
            self.metrics.record(
                pred,
                s.label,
                0,
                false,
                expert_would,
                CostModel::infer_flops(self.kind),
            );
        }
        self.metrics.finalize();
        self.metrics.accuracy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BenchmarkId, ExpertId};
    use crate::data::Benchmark;
    use crate::sim::ExpertProfile;

    fn fixture(n: usize, seed: u64) -> (Benchmark, Expert) {
        let b = Benchmark::build_sized(BenchmarkId::Imdb, seed, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let e = Expert::new(
            ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
            b.strata_fractions(),
            mean_len,
            seed,
        );
        (b, e)
    }

    #[test]
    fn ensemble_learns_and_respects_rate() {
        let (b, e) = fixture(2000, 21);
        let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let mut oel = OnlineEnsemble::new(&cfg, 2, e, 0.3, None).unwrap();
        let acc = oel.run_stream(&b.stream());
        let calls = oel.metrics.llm_calls() as f64;
        assert!((calls / 2000.0 - 0.3).abs() < 0.05, "rate {}", calls / 2000.0);
        assert!(acc > 0.6, "oel acc {acc}");
        // weights remain a distribution
        let s: f64 = oel.weights().iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn distilled_lr_beats_chance_on_imdb() {
        let (b, e) = fixture(2400, 22);
        let stream = b.stream();
        let (train, test) = stream.split_at(1200);
        let mut d = Distillation::new(ModelKind::Lr, 2, 22, None).unwrap();
        let acc = d.run(&e, train, test, 1200);
        assert!(acc > 0.65, "distilled lr {acc}");
    }

    #[test]
    fn distillation_budget_is_respected() {
        let (b, e) = fixture(600, 23);
        let stream = b.stream();
        let (train, test) = stream.split_at(300);
        let before = e.calls();
        let mut d = Distillation::new(ModelKind::Lr, 2, 23, None).unwrap();
        d.run(&e, train, test, 100);
        // exactly 100 annotation calls (plus peeks which don't count)
        assert_eq!(e.calls() - before, 100);
    }
}
