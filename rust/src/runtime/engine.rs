//! PJRT execution engine: compile-and-cache HLO entry points, execute
//! them with literal arguments, thread updated parameters back.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArgSpec, Dtype, Manifest};

/// A PJRT CPU engine bound to one artifacts directory.
///
/// Not `Send`: `PjRtClient` is `Rc`-based. Each worker thread builds
/// its own engine (compilation is cached per engine).
pub struct PjrtEngine {
    client: PjRtClient,
    manifest: Rc<Manifest>,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    /// Create a CPU engine over a loaded manifest.
    pub fn new(manifest: Rc<Manifest>) -> Result<Self> {
        let client = PjRtClient::cpu()?;
        Ok(PjrtEngine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Convenience: load the manifest from `dir` and build the engine.
    pub fn from_dir(dir: &str) -> Result<Self> {
        PjrtEngine::new(Rc::new(Manifest::load(dir)?))
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an entry point.
    pub fn executable(&self, entry: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(entry) {
            return Ok(e.clone());
        }
        let meta = self.manifest.entry(entry)?;
        let path = self.manifest.root().join(&meta.hlo);
        let proto = HloModuleProto::from_text_file(&path).map_err(|e| {
            Error::Runtime(format!("load {}: {e}", path.display()))
        })?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(entry.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry point with the given argument literals.
    ///
    /// Arity and (cheaply checkable) element counts are validated
    /// against the manifest. Returns the decomposed output tuple (the
    /// graphs lower with `return_tuple=True`).
    pub fn run(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let meta = self.manifest.entry(entry)?;
        if args.len() != meta.args.len() {
            return Err(Error::Runtime(format!(
                "{entry}: got {} args, manifest wants {}",
                args.len(),
                meta.args.len()
            )));
        }
        for (i, (a, spec)) in args.iter().zip(&meta.args).enumerate() {
            if a.element_count() != spec.elems() {
                return Err(Error::Runtime(format!(
                    "{entry} arg {i}: {} elements, manifest wants {}",
                    a.element_count(),
                    spec.elems()
                )));
            }
        }
        let exe = self.executable(entry)?;
        let result = exe.execute::<&Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut tuple = tuple;
        Ok(tuple.decompose_tuple()?)
    }

    /// Number of compiled executables held in the cache.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Build a literal for one manifest arg spec from host data.
pub fn literal_f32(spec: &ArgSpec, data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(spec.dtype, Dtype::F32);
    if data.len() != spec.elems() {
        return Err(Error::Runtime(format!(
            "literal_f32: {} values for shape {:?}",
            data.len(),
            spec.shape
        )));
    }
    if spec.shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal for one manifest arg spec.
pub fn literal_i32(spec: &ArgSpec, data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(spec.dtype, Dtype::S32);
    if data.len() != spec.elems() {
        return Err(Error::Runtime(format!(
            "literal_i32: {} values for shape {:?}",
            data.len(),
            spec.shape
        )));
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Load a parameter group's init blob as literals (one per tensor).
pub fn load_group_literals(manifest: &Manifest, group: &str) -> Result<Vec<Literal>> {
    let tensors = manifest.load_group_tensors(group)?;
    let mut out = Vec::with_capacity(tensors.len());
    for (_, shape, data) in tensors {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        out.push(if dims.is_empty() {
            Literal::scalar(data[0])
        } else {
            Literal::vec1(&data).reshape(&dims)?
        });
    }
    Ok(out)
}
