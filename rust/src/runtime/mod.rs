//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them through the `xla` crate's PJRT CPU client.
//!
//! This is the production request path: Python runs once at build time
//! (`make artifacts`), and everything here is plain rust + the PJRT C
//! API. `PjRtClient` is `Rc`-based (not `Send`), so each engine lives
//! on the thread that created it; the serving layer gives every model
//! worker thread its own [`PjrtEngine`] (vLLM-style leader/worker).

pub mod engine;
pub mod manifest;

pub use engine::PjrtEngine;
pub use manifest::{ArgSpec, Dtype, EntryMeta, Manifest, ParamGroup};

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True when AOT artifacts exist (integration tests gate on this).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
