//! Runtime layer: artifact manifests plus the engine-backend seam.
//!
//! The artifact *manifest* machinery is pure rust and always compiled.
//! The PJRT execution engine — which loads the AOT artifacts
//! (`artifacts/*.hlo.txt`) and executes them through the `xla` crate's
//! PJRT CPU client — is gated behind the `pjrt` cargo feature; the
//! default build runs entirely on the host models
//! ([`crate::hostmodel`]).
//!
//! With the feature on, this is the production request path: Python
//! runs once at build time (`make artifacts`), and everything here is
//! plain rust + the PJRT C API. `PjRtClient` is `Rc`-based (not
//! `Send`), so each engine lives on the thread that created it; the
//! serving layer gives every model worker thread its own
//! [`PjrtEngine`] (vLLM-style leader/worker).

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(feature = "pjrt")]
pub use engine::PjrtEngine;
pub use manifest::{ArgSpec, Dtype, EntryMeta, Manifest, ParamGroup};

/// Engine-backend seam for builds without the `pjrt` feature: an
/// *uninhabited* placeholder, so every `Option<Rc<PjrtEngine>>`
/// threaded through the coordinator / serving / eval layers is
/// statically `None` and the pure-rust host models are the only
/// backend. No value of this type can ever exist.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub enum PjrtEngine {}

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True when AOT artifacts exist (integration tests gate on this).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}

/// Build the PJRT engine for a worker thread (each worker owns its
/// engine because `PjRtClient` is not `Send`). Panics on engine
/// construction failure — a worker without its engine cannot serve.
#[cfg(feature = "pjrt")]
pub fn worker_engine(dir: &str) -> std::rc::Rc<PjrtEngine> {
    std::rc::Rc::new(PjrtEngine::from_dir(dir).expect("worker engine"))
}

/// Feature-off twin of [`worker_engine`]. Statically unreachable:
/// without the `pjrt` feature, `config::Engine` has no `Pjrt` variant,
/// so no caller can select the PJRT path.
#[cfg(not(feature = "pjrt"))]
pub fn worker_engine(_dir: &str) -> std::rc::Rc<PjrtEngine> {
    unreachable!("Engine::Pjrt cannot be selected without the `pjrt` cargo feature")
}
