//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime (entry points, argument shapes/dtypes, parameter
//! groups + init blobs, dimension constants).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::codec::parse;
use crate::config::dims;
use crate::error::{Error, Result};

/// Element dtype of an executable argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed int.
    S32,
}

impl Dtype {
    fn from_tag(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            _ => Err(Error::Manifest(format!("unknown dtype '{s}'"))),
        }
    }
}

/// Shape + dtype of one executable argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl ArgSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One entry point (one HLO file).
#[derive(Clone, Debug)]
pub struct EntryMeta {
    /// HLO text file name relative to the artifacts dir.
    pub hlo: String,
    /// All argument specs in call order.
    pub args: Vec<ArgSpec>,
    /// Index of the first parameter argument.
    pub params_at: usize,
    /// Parameter group feeding `args[params_at..]`.
    pub group: String,
}

impl EntryMeta {
    /// True for step entries (trailing scalar learning-rate argument).
    pub fn is_step(&self, group_len: usize) -> bool {
        self.params_at + group_len < self.args.len()
    }
}

/// A named parameter group (one init blob).
#[derive(Clone, Debug)]
pub struct ParamGroup {
    /// Blob file relative to the artifacts dir (f32 little-endian).
    pub file: String,
    /// (tensor name, shape) in blob order.
    pub tensors: Vec<(String, Vec<usize>)>,
}

impl ParamGroup {
    /// Total f32 element count of the blob.
    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    root: PathBuf,
    /// Entry points by name.
    pub entries: BTreeMap<String, EntryMeta>,
    /// Parameter groups by name.
    pub params: BTreeMap<String, ParamGroup>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let v = parse(&text)?;
        if v.require("version")?.as_usize() != Some(1) {
            return Err(Error::Manifest("unsupported manifest version".into()));
        }
        // Dimension agreement with the compiled-in constants.
        let d = v.require("dims")?;
        let check = |key: &str, want: usize| -> Result<()> {
            let got = d
                .require(key)?
                .as_usize()
                .ok_or_else(|| Error::Manifest(format!("dims.{key} not usize")))?;
            if got != want {
                return Err(Error::Manifest(format!(
                    "dims.{key}: manifest {got} != crate {want} — \
                     rebuild artifacts (make artifacts)"
                )));
            }
            Ok(())
        };
        check("hash_dim", dims::HASH_DIM)?;
        check("seq_len", dims::SEQ_LEN)?;
        check("vocab", dims::VOCAB)?;
        check("batch_step", dims::BATCH_STEP)?;

        let mut params = BTreeMap::new();
        for (name, g) in v
            .require("params")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("params not an object".into()))?
        {
            let file = g
                .require("file")?
                .as_str()
                .ok_or_else(|| Error::Manifest("param file not a string".into()))?
                .to_string();
            let mut tensors = Vec::new();
            for t in g
                .require("tensors")?
                .as_arr()
                .ok_or_else(|| Error::Manifest("tensors not an array".into()))?
            {
                let tname = t
                    .require("name")?
                    .as_str()
                    .ok_or_else(|| Error::Manifest("tensor name".into()))?
                    .to_string();
                let shape = t
                    .require("shape")?
                    .as_usize_vec()
                    .ok_or_else(|| Error::Manifest("tensor shape".into()))?;
                tensors.push((tname, shape));
            }
            params.insert(name.clone(), ParamGroup { file, tensors });
        }

        let mut entries = BTreeMap::new();
        for (name, e) in v
            .require("entries")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("entries not an object".into()))?
        {
            let hlo = e
                .require("hlo")?
                .as_str()
                .ok_or_else(|| Error::Manifest("entry hlo".into()))?
                .to_string();
            let mut args = Vec::new();
            for a in e
                .require("args")?
                .as_arr()
                .ok_or_else(|| Error::Manifest("entry args".into()))?
            {
                let shape = a
                    .require("shape")?
                    .as_usize_vec()
                    .ok_or_else(|| Error::Manifest("arg shape".into()))?;
                let dtype = Dtype::from_tag(
                    a.require("dtype")?
                        .as_str()
                        .ok_or_else(|| Error::Manifest("arg dtype".into()))?,
                )?;
                args.push(ArgSpec { shape, dtype });
            }
            let params_at = e
                .require("params_at")?
                .as_usize()
                .ok_or_else(|| Error::Manifest("params_at".into()))?;
            let group = e
                .require("group")?
                .as_str()
                .ok_or_else(|| Error::Manifest("entry group".into()))?
                .to_string();
            if !params.contains_key(&group) {
                return Err(Error::Manifest(format!(
                    "entry {name} references unknown group {group}"
                )));
            }
            entries.insert(name.clone(), EntryMeta { hlo, args, params_at, group });
        }
        Ok(Manifest { root, entries, params })
    }

    /// Artifacts root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Entry metadata by name.
    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown entry '{name}'")))
    }

    /// Parameter group by name.
    pub fn group(&self, name: &str) -> Result<&ParamGroup> {
        self.params
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown group '{name}'")))
    }

    /// Read a group's init blob as a flat f32 vec (validated length).
    pub fn load_group_flat(&self, name: &str) -> Result<Vec<f32>> {
        let g = self.group(name)?;
        let path = self.root.join(&g.file);
        let mut bytes = Vec::new();
        std::fs::File::open(&path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        if bytes.len() != g.total_elems() * 4 {
            return Err(Error::Manifest(format!(
                "blob {name}: {} bytes, expected {}",
                bytes.len(),
                g.total_elems() * 4
            )));
        }
        let mut out = Vec::with_capacity(g.total_elems());
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    /// Read a group's blob split per tensor.
    pub fn load_group_tensors(&self, name: &str) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        let flat = self.load_group_flat(name)?;
        let g = self.group(name)?;
        let mut out = Vec::with_capacity(g.tensors.len());
        let mut off = 0usize;
        for (tname, shape) in &g.tensors {
            let n: usize = shape.iter().product();
            out.push((tname.clone(), shape.clone(), flat[off..off + n].to_vec()));
            off += n;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthesize a tiny manifest on disk for parser tests.
    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir.join("init")).unwrap();
        let blob: Vec<u8> =
            (0..6u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        std::fs::write(dir.join("init/g.bin"), blob).unwrap();
        let manifest = format!(
            r#"{{
 "version": 1,
 "dims": {{"hash_dim": {}, "seq_len": {}, "vocab": {}, "batch_step": {}}},
 "params": {{"g": {{"file": "init/g.bin",
   "tensors": [{{"name": "w", "shape": [2, 2]}}, {{"name": "b", "shape": [2]}}]}}}},
 "entries": {{"e_fwd": {{"hlo": "e.hlo.txt", "params_at": 1, "group": "g",
   "args": [{{"shape": [1, 4], "dtype": "f32"}},
            {{"shape": [2, 2], "dtype": "f32"}},
            {{"shape": [2], "dtype": "f32"}}]}}}}
}}"#,
            dims::HASH_DIM,
            dims::SEQ_LEN,
            dims::VOCAB,
            dims::BATCH_STEP
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ocl_manifest_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn loads_fixture() {
        let d = tmpdir("ok");
        write_fixture(&d);
        let m = Manifest::load(&d).unwrap();
        let e = m.entry("e_fwd").unwrap();
        assert_eq!(e.args.len(), 3);
        assert_eq!(e.args[0].shape, vec![1, 4]);
        assert_eq!(e.args[0].dtype, Dtype::F32);
        assert_eq!(e.params_at, 1);
        let flat = m.load_group_flat("g").unwrap();
        assert_eq!(flat, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let ts = m.load_group_tensors("g").unwrap();
        assert_eq!(ts[0].2, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(ts[1].2, vec![4.0, 5.0]);
    }

    #[test]
    fn rejects_dim_mismatch() {
        let d = tmpdir("dims");
        write_fixture(&d);
        let bad = std::fs::read_to_string(d.join("manifest.json"))
            .unwrap()
            .replace(&format!("\"hash_dim\": {}", dims::HASH_DIM), "\"hash_dim\": 999");
        std::fs::write(d.join("manifest.json"), bad).unwrap();
        let err = Manifest::load(&d).unwrap_err();
        assert!(err.to_string().contains("hash_dim"), "{err}");
    }

    #[test]
    fn rejects_truncated_blob() {
        let d = tmpdir("blob");
        write_fixture(&d);
        std::fs::write(d.join("init/g.bin"), [0u8; 8]).unwrap();
        let m = Manifest::load(&d).unwrap();
        assert!(m.load_group_flat("g").is_err());
    }

    #[test]
    fn unknown_lookups_error() {
        let d = tmpdir("lookup");
        write_fixture(&d);
        let m = Manifest::load(&d).unwrap();
        assert!(m.entry("nope").is_err());
        assert!(m.group("nope").is_err());
    }

    #[test]
    fn real_artifacts_parse_when_present() {
        if !crate::runtime::artifacts_available("artifacts") {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.entries.contains_key("lr_fwd_c2_b1"));
        assert!(m.entries.contains_key("tfm_base_step_c7_b8"));
        let g = m.group("tfm_base_c2").unwrap();
        assert_eq!(g.tensors[0].0, "embed");
        let flat = m.load_group_flat("lr_c2").unwrap();
        assert_eq!(flat.len(), dims::HASH_DIM * 2 + 2);
        assert!(flat.iter().all(|&x| x == 0.0)); // LR zero-init
    }
}
