//! Declarative CLI argument parser (no `clap` in the offline image).
//!
//! Supports `ocl <subcommand> [--key value] [--key=value] [--flag]`.
//! Unknown flags are errors; every flag documents itself for `--help`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Flag name without leading dashes, e.g. `benchmark`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Default value (`None` for boolean switches).
    pub default: Option<&'static str>,
    /// True for boolean switches that take no value.
    pub is_switch: bool,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    vals: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

impl Args {
    /// String value of `name` (declared options always resolve).
    pub fn get(&self, name: &str) -> &str {
        self.vals.get(name).map(String::as_str).unwrap_or("")
    }

    /// Parse the value as `T`, erroring with flag context.
    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        self.get(name).parse::<T>().map_err(|_| {
            Error::Usage(format!("--{name}: cannot parse '{}'", self.get(name)))
        })
    }

    /// Value of `name` if set to something non-empty — the idiom for
    /// optional flags whose declared default is `""` (e.g. the serve
    /// subcommand's `--listen` / `--connect` / `--front`).
    pub fn get_opt(&self, name: &str) -> Option<&str> {
        Some(self.get(name)).filter(|v| !v.is_empty())
    }

    /// Boolean switch state.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// A subcommand with declared options.
pub struct Command {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description for help output.
    pub about: &'static str,
    /// Declared options.
    pub opts: Vec<OptSpec>,
}

impl Command {
    /// New subcommand.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    /// Declare a value option with default.
    pub fn opt(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_switch: false });
        self
    }

    /// Declare a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_switch: true });
        self
    }

    /// Parse raw argv (after the subcommand token).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.vals.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let body = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Usage(format!("unexpected argument '{a}'")))?;
            // `--key=value` and `--key value` are equivalent.
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v)),
                None => (body, None),
            };
            let spec = self
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| Error::Usage(format!("unknown flag --{name}")))?;
            if spec.is_switch {
                if inline.is_some() {
                    return Err(Error::Usage(format!("--{name} takes no value")));
                }
                args.switches.insert(name.to_string(), true);
                i += 1;
            } else if let Some(v) = inline {
                args.vals.insert(name.to_string(), v.to_string());
                i += 1;
            } else {
                let v = argv.get(i + 1).ok_or_else(|| {
                    Error::Usage(format!("--{name} requires a value"))
                })?;
                args.vals.insert(name.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(args)
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n", self.name, self.about);
        for o in &self.opts {
            let d = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }
}

/// Typed view of the serve flag table — the single source of truth for
/// `ocl serve`, its `--connect` wire-client mode, and
/// `examples/serve_stream.rs`. All three surfaces parse through
/// [`ServeArgs::command`], so flags, defaults, and help lines can no
/// longer drift apart. The pipeline/speculation knobs (`--pipeline`,
/// `--spec-threshold`, `--stage-depth`) exist only here and on
/// [`crate::config::ServeConfig::builder`].
#[derive(Clone, Debug)]
pub struct ServeArgs {
    /// Benchmark stream to serve.
    pub benchmark: String,
    /// Expert model identity.
    pub expert: String,
    /// Number of requests to submit.
    pub requests: usize,
    /// Open-loop arrival rate in req/s (0 = unpaced).
    pub rate: f64,
    /// Stream scale vs the paper's dataset size.
    pub scale: f64,
    /// Engine name; `None` = surface-specific default (`ocl serve`
    /// pins host, the serve_stream example auto-detects PJRT).
    pub engine: Option<String>,
    /// RNG seed.
    pub seed: u64,
    /// Artifacts directory (PJRT engine).
    pub artifacts: String,
    /// Router shards behind the front dispatcher.
    pub shards: usize,
    /// Worker-pool capacity per cascade level.
    pub replicas: usize,
    /// Cross-shard annotation broadcast interval (0 = off).
    pub sync: usize,
    /// Checkpoint directory (`None` = durability off).
    pub ckpt_dir: Option<String>,
    /// Expert annotations between checkpoints (0 = shutdown only).
    pub ckpt_every: usize,
    /// Resume mode name: off|strict|best-effort.
    pub resume: String,
    /// Pipelined level execution (bounded stage queues).
    pub pipeline: bool,
    /// Speculative-dispatch threshold in (0, 1]; 1 disables.
    pub spec_threshold: f64,
    /// Per-level stage-queue capacity for the pipelined path.
    pub stage_depth: usize,
    /// Queue-driven autoscaling of the per-level replica pools.
    pub autoscale: bool,
    /// Autoscale floor on replicas per level.
    pub replicas_min: usize,
    /// Autoscale ceiling on replicas per level.
    pub replicas_max: usize,
    /// TCP bind address (serving over the wire).
    pub listen: Option<String>,
    /// With `listen`: run as one shard process of `shards`.
    pub shard_id: Option<usize>,
    /// Thin-front mode: comma-separated shard addresses.
    pub front: Option<String>,
    /// Wire-client mode: address of a `--listen`/`--front` process.
    pub connect: Option<String>,
    /// Client-side p50 latency SLO in ms (0 = off).
    pub slo_p50: f64,
    /// Client-side p99 latency SLO in ms (0 = off).
    pub slo_p99: f64,
}

impl ServeArgs {
    /// The declarative flag table (parses and renders `--help`).
    pub fn command() -> Command {
        Command::new("serve", "run the streaming serving mode (router+batcher)")
            .opt("benchmark", "imdb", "benchmark")
            .opt("expert", "gpt35", "gpt35|llama70b")
            .opt("requests", "2000", "number of requests")
            .opt("rate", "0", "open-loop arrival rate, req/s (0 = unpaced)")
            .opt("scale", "1", "stream scale vs the paper's dataset size")
            .opt("engine", "", "host|pjrt (empty: host, or auto-detect in serve_stream)")
            .opt("seed", "0", "rng seed")
            .opt("artifacts", "artifacts", "artifacts dir (pjrt engine)")
            .opt("shards", "1", "router shards behind the front dispatcher")
            .opt("replicas", "1", "worker-pool capacity per cascade level")
            .opt("sync", "16", "cross-shard annotation broadcast interval (0 = off)")
            .opt("ckpt-dir", "", "checkpoint directory (empty = durability off)")
            .opt(
                "ckpt-every",
                "64",
                "expert annotations between checkpoints (0 = shutdown only)",
            )
            .opt("resume", "off", "off|strict|best-effort: restore from --ckpt-dir")
            .switch("pipeline", "pipelined level execution (bounded stage queues)")
            .opt(
                "spec-threshold",
                "1",
                "speculate past the gate above this calibrated score, (0,1]; 1 = off",
            )
            .opt("stage-depth", "64", "per-level stage-queue capacity (pipelined path)")
            .switch("autoscale", "grow/shrink level replicas off live queue depth")
            .opt("replicas-min", "1", "autoscale floor on replicas per level")
            .opt("replicas-max", "1", "autoscale ceiling on replicas per level")
            .opt("listen", "", "serve over TCP: bind address (e.g. 127.0.0.1:4100)")
            .opt("shard-id", "", "with --listen: run as one shard process (0..--shards)")
            .opt("front", "", "run the thin front over comma-separated shard addresses")
            .opt("connect", "", "run as a load client against a --listen/--front address")
            .opt("slo-p50", "0", "client: fail if p50 latency exceeds this many ms (0 = off)")
            .opt("slo-p99", "0", "client: fail if p99 latency exceeds this many ms (0 = off)")
    }

    /// Typed extraction from already-parsed [`Args`] (the `ocl`
    /// launcher parses once for subcommand dispatch, then calls this).
    pub fn from_args(a: &Args) -> Result<ServeArgs> {
        Ok(ServeArgs {
            benchmark: a.get("benchmark").to_string(),
            expert: a.get("expert").to_string(),
            requests: a.parse("requests")?,
            rate: a.parse("rate")?,
            scale: a.parse("scale")?,
            engine: a.get_opt("engine").map(str::to_string),
            seed: a.parse("seed")?,
            artifacts: a.get("artifacts").to_string(),
            shards: a.parse("shards")?,
            replicas: a.parse("replicas")?,
            sync: a.parse("sync")?,
            ckpt_dir: a.get_opt("ckpt-dir").map(str::to_string),
            ckpt_every: a.parse("ckpt-every")?,
            resume: a.get("resume").to_string(),
            pipeline: a.switch("pipeline"),
            spec_threshold: a.parse("spec-threshold")?,
            stage_depth: a.parse("stage-depth")?,
            autoscale: a.switch("autoscale"),
            replicas_min: a.parse("replicas-min")?,
            replicas_max: a.parse("replicas-max")?,
            listen: a.get_opt("listen").map(str::to_string),
            shard_id: match a.get_opt("shard-id") {
                Some(s) => Some(s.parse().map_err(|_| {
                    Error::Usage(format!("--shard-id: cannot parse '{s}'"))
                })?),
                None => None,
            },
            front: a.get_opt("front").map(str::to_string),
            connect: a.get_opt("connect").map(str::to_string),
            slo_p50: a.parse("slo-p50")?,
            slo_p99: a.parse("slo-p99")?,
        })
    }

    /// Parse raw argv straight into typed serve flags (the example's
    /// entry — no subcommand dispatch in front of it).
    pub fn parse(argv: &[String]) -> Result<ServeArgs> {
        Self::from_args(&Self::command().parse(argv)?)
    }

    /// Build the validated [`crate::config::ServeConfig`] these flags
    /// describe; suspicious-but-legal combinations are printed to
    /// stderr as warnings rather than silently accepted.
    pub fn serve_config(&self) -> Result<crate::config::ServeConfig> {
        let (cfg, warnings) = crate::config::ServeConfig::builder()
            .ckpt_every(self.ckpt_every)
            .shards(self.shards)
            .replicas_per_level(self.replicas)
            .sync_interval(self.sync)
            .pipeline(self.pipeline)
            .spec_threshold(self.spec_threshold)
            .stage_queue_depth(self.stage_depth)
            .autoscale(self.autoscale)
            .replicas_min(self.replicas_min)
            .replicas_max(self.replicas_max)
            .build_with_warnings()?;
        for w in &warnings {
            eprintln!("warning: {w}");
        }
        Ok(cfg)
    }

    /// Durability options implied by `--ckpt-dir`/`--resume`
    /// (`--resume` without a directory is a usage error).
    pub fn ckpt_options(&self) -> Result<Option<crate::serve::ckpt::CkptOptions>> {
        match &self.ckpt_dir {
            None => {
                if self.resume != "off" {
                    return Err(Error::Usage("--resume requires --ckpt-dir".into()));
                }
                Ok(None)
            }
            Some(dir) => {
                let resume = match self.resume.as_str() {
                    "off" => None,
                    m => Some(crate::serve::ckpt::ResumeMode::from_name(m)?),
                };
                Ok(Some(crate::serve::ckpt::CkptOptions {
                    dir: dir.clone(),
                    resume,
                }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "test")
            .opt("benchmark", "imdb", "benchmark name")
            .opt("n", "100", "sample count")
            .switch("verbose", "noisy output")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&v(&[])).unwrap();
        assert_eq!(a.get("benchmark"), "imdb");
        assert_eq!(a.parse::<usize>("n").unwrap(), 100);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn values_and_switches() {
        let a = cmd()
            .parse(&v(&["--n", "5", "--verbose", "--benchmark", "fever"]))
            .unwrap();
        assert_eq!(a.parse::<usize>("n").unwrap(), 5);
        assert!(a.switch("verbose"));
        assert_eq!(a.get("benchmark"), "fever");
    }

    #[test]
    fn get_opt_treats_empty_default_as_unset() {
        let c = Command::new("serve", "test").opt("listen", "", "bind address");
        let a = c.parse(&v(&[])).unwrap();
        assert_eq!(a.get_opt("listen"), None);
        let a = c.parse(&v(&["--listen", "127.0.0.1:4000"])).unwrap();
        assert_eq!(a.get_opt("listen"), Some("127.0.0.1:4000"));
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&v(&["--bogus", "1"])).is_err());
        assert!(cmd().parse(&v(&["--n"])).is_err());
        assert!(cmd().parse(&v(&["positional"])).is_err());
        let a = cmd().parse(&v(&["--n", "abc"])).unwrap();
        assert!(a.parse::<usize>("n").is_err());
    }

    #[test]
    fn equals_syntax() {
        let a = cmd()
            .parse(&v(&["--n=7", "--benchmark=isear", "--verbose"]))
            .unwrap();
        assert_eq!(a.parse::<usize>("n").unwrap(), 7);
        assert_eq!(a.get("benchmark"), "isear");
        assert!(a.switch("verbose"));
        // values may themselves contain '=' (only the first splits)
        let a = cmd().parse(&v(&["--benchmark=a=b"])).unwrap();
        assert_eq!(a.get("benchmark"), "a=b");
        // switches reject inline values; unknown keys still error
        assert!(cmd().parse(&v(&["--verbose=1"])).is_err());
        assert!(cmd().parse(&v(&["--bogus=1"])).is_err());
    }

    #[test]
    fn help_mentions_flags() {
        let h = cmd().help();
        assert!(h.contains("--benchmark"));
        assert!(h.contains("default: 100"));
    }

    #[test]
    fn serve_args_defaults_match_serve_config_defaults() {
        let sa = ServeArgs::parse(&v(&[])).unwrap();
        assert_eq!(sa.requests, 2000);
        assert_eq!(sa.engine, None, "empty engine means surface default");
        assert!(!sa.pipeline);
        assert_eq!(sa.spec_threshold, 1.0);
        assert_eq!(sa.stage_depth, 64);
        assert!(!sa.autoscale);
        assert_eq!(sa.replicas_min, 1);
        assert_eq!(sa.replicas_max, 1);
        let cfg = sa.serve_config().unwrap();
        assert_eq!(cfg, crate::config::ServeConfig::default());
        assert!(sa.ckpt_options().unwrap().is_none());
    }

    #[test]
    fn serve_args_pipeline_knobs_flow_into_config() {
        let sa = ServeArgs::parse(&v(&[
            "--pipeline",
            "--spec-threshold",
            "0.6",
            "--stage-depth=16",
            "--shards",
            "2",
        ]))
        .unwrap();
        let cfg = sa.serve_config().unwrap();
        assert!(cfg.pipeline);
        assert_eq!(cfg.spec_threshold, 0.6);
        assert_eq!(cfg.stage_queue_depth, 16);
        assert_eq!(cfg.shard.shards, 2);
        // The builder's validation runs on the CLI path too.
        let bad = ServeArgs::parse(&v(&["--spec-threshold", "1.5"])).unwrap();
        assert!(bad.serve_config().is_err());
    }

    #[test]
    fn serve_args_autoscale_knobs_flow_into_config() {
        let sa = ServeArgs::parse(&v(&[
            "--autoscale",
            "--replicas-min",
            "1",
            "--replicas-max=4",
            "--replicas",
            "2",
        ]))
        .unwrap();
        let cfg = sa.serve_config().unwrap();
        assert!(cfg.autoscale);
        assert_eq!(cfg.replicas_min, 1);
        assert_eq!(cfg.replicas_max, 4);
        assert_eq!(cfg.shard.replicas_per_level, 2);
        // Inverted bounds are caught by the builder on the CLI path.
        let bad = ServeArgs::parse(&v(&[
            "--autoscale",
            "--replicas-min",
            "4",
            "--replicas-max",
            "2",
        ]))
        .unwrap();
        assert!(bad.serve_config().is_err());
    }

    #[test]
    fn serve_args_usage_errors() {
        assert!(ServeArgs::parse(&v(&["--shard-id", "zero"])).is_err());
        let sa = ServeArgs::parse(&v(&["--resume", "strict"])).unwrap();
        assert!(sa.ckpt_options().is_err(), "--resume requires --ckpt-dir");
        let sa = ServeArgs::parse(&v(&[
            "--ckpt-dir",
            "/tmp/ck",
            "--resume",
            "strict",
        ]))
        .unwrap();
        let opts = sa.ckpt_options().unwrap().unwrap();
        assert_eq!(opts.dir, "/tmp/ck");
        assert!(opts.resume.is_some());
    }

    #[test]
    fn serve_args_help_lists_every_surface_flag() {
        let h = ServeArgs::command().help();
        for flag in [
            "--benchmark",
            "--connect",
            "--front",
            "--pipeline",
            "--spec-threshold",
            "--stage-depth",
            "--autoscale",
            "--replicas-min",
            "--replicas-max",
            "--slo-p99",
        ] {
            assert!(h.contains(flag), "help is missing {flag}:\n{h}");
        }
    }
}
