//! Declarative CLI argument parser (no `clap` in the offline image).
//!
//! Supports `ocl <subcommand> [--key value] [--key=value] [--flag]`.
//! Unknown flags are errors; every flag documents itself for `--help`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One declared option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Flag name without leading dashes, e.g. `benchmark`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Default value (`None` for boolean switches).
    pub default: Option<&'static str>,
    /// True for boolean switches that take no value.
    pub is_switch: bool,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    vals: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

impl Args {
    /// String value of `name` (declared options always resolve).
    pub fn get(&self, name: &str) -> &str {
        self.vals.get(name).map(String::as_str).unwrap_or("")
    }

    /// Parse the value as `T`, erroring with flag context.
    pub fn parse<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        self.get(name).parse::<T>().map_err(|_| {
            Error::Usage(format!("--{name}: cannot parse '{}'", self.get(name)))
        })
    }

    /// Value of `name` if set to something non-empty — the idiom for
    /// optional flags whose declared default is `""` (e.g. the serve
    /// subcommand's `--listen` / `--connect` / `--front`).
    pub fn get_opt(&self, name: &str) -> Option<&str> {
        Some(self.get(name)).filter(|v| !v.is_empty())
    }

    /// Boolean switch state.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// A subcommand with declared options.
pub struct Command {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description for help output.
    pub about: &'static str,
    /// Declared options.
    pub opts: Vec<OptSpec>,
}

impl Command {
    /// New subcommand.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    /// Declare a value option with default.
    pub fn opt(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_switch: false });
        self
    }

    /// Declare a boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_switch: true });
        self
    }

    /// Parse raw argv (after the subcommand token).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.vals.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let body = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Usage(format!("unexpected argument '{a}'")))?;
            // `--key=value` and `--key value` are equivalent.
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v)),
                None => (body, None),
            };
            let spec = self
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| Error::Usage(format!("unknown flag --{name}")))?;
            if spec.is_switch {
                if inline.is_some() {
                    return Err(Error::Usage(format!("--{name} takes no value")));
                }
                args.switches.insert(name.to_string(), true);
                i += 1;
            } else if let Some(v) = inline {
                args.vals.insert(name.to_string(), v.to_string());
                i += 1;
            } else {
                let v = argv.get(i + 1).ok_or_else(|| {
                    Error::Usage(format!("--{name} requires a value"))
                })?;
                args.vals.insert(name.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(args)
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n", self.name, self.about);
        for o in &self.opts {
            let d = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "test")
            .opt("benchmark", "imdb", "benchmark name")
            .opt("n", "100", "sample count")
            .switch("verbose", "noisy output")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&v(&[])).unwrap();
        assert_eq!(a.get("benchmark"), "imdb");
        assert_eq!(a.parse::<usize>("n").unwrap(), 100);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn values_and_switches() {
        let a = cmd()
            .parse(&v(&["--n", "5", "--verbose", "--benchmark", "fever"]))
            .unwrap();
        assert_eq!(a.parse::<usize>("n").unwrap(), 5);
        assert!(a.switch("verbose"));
        assert_eq!(a.get("benchmark"), "fever");
    }

    #[test]
    fn get_opt_treats_empty_default_as_unset() {
        let c = Command::new("serve", "test").opt("listen", "", "bind address");
        let a = c.parse(&v(&[])).unwrap();
        assert_eq!(a.get_opt("listen"), None);
        let a = c.parse(&v(&["--listen", "127.0.0.1:4000"])).unwrap();
        assert_eq!(a.get_opt("listen"), Some("127.0.0.1:4000"));
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&v(&["--bogus", "1"])).is_err());
        assert!(cmd().parse(&v(&["--n"])).is_err());
        assert!(cmd().parse(&v(&["positional"])).is_err());
        let a = cmd().parse(&v(&["--n", "abc"])).unwrap();
        assert!(a.parse::<usize>("n").is_err());
    }

    #[test]
    fn equals_syntax() {
        let a = cmd()
            .parse(&v(&["--n=7", "--benchmark=isear", "--verbose"]))
            .unwrap();
        assert_eq!(a.parse::<usize>("n").unwrap(), 7);
        assert_eq!(a.get("benchmark"), "isear");
        assert!(a.switch("verbose"));
        // values may themselves contain '=' (only the first splits)
        let a = cmd().parse(&v(&["--benchmark=a=b"])).unwrap();
        assert_eq!(a.get("benchmark"), "a=b");
        // switches reject inline values; unknown keys still error
        assert!(cmd().parse(&v(&["--verbose=1"])).is_err());
        assert!(cmd().parse(&v(&["--bogus=1"])).is_err());
    }

    #[test]
    fn help_mentions_flags() {
        let h = cmd().help();
        assert!(h.contains("--benchmark"));
        assert!(h.contains("default: 100"));
    }
}
