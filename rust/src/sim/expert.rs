//! Simulated LLM expert — the `m_N` oracle of Algorithm 1.
//!
//! The paper's expert is GPT-3.5 Turbo or Llama-2-70B-Chat under
//! zero-shot task prompts. Online cascade learning consumes exactly two
//! things from it: a (noisy) *label stream* and a *per-call cost*. The
//! simulator provides both, calibrated to the paper's measured
//! accuracies per benchmark and to the Table 5 length-degradation
//! profile (longer inputs → lower accuracy).
//!
//! Mechanics: the expert "knows" the generator's ground truth and emits
//! it with a per-sample error probability that scales with the sample's
//! difficulty stratum and length percentile. Errors are *deterministic
//! per sample* (hash-seeded), so repeated queries return the same
//! annotation — like a temperature-0 LLM — and whole runs replay
//! bit-for-bit.

use crate::config::{BenchmarkId, ExpertId};
use crate::data::Sample;
use crate::prng::Rng;
use crate::sim::cost::CostModel;
use crate::text::Stratum;

/// Relative error weight per stratum (hard inputs are ~4x more likely
/// to be answered wrongly by the LLM than easy ones — consistent with
/// the paper's observation that LLM accuracy drops on complex inputs).
const ERR_WEIGHT: [f64; 3] = [1.0, 2.0, 4.0];

/// Accuracy / behaviour profile for one (expert, benchmark) pair.
#[derive(Clone, Debug)]
pub struct ExpertProfile {
    /// Which LLM this profiles.
    pub id: ExpertId,
    /// Target aggregate accuracy (paper Table 1 LLM rows).
    pub accuracy: f64,
    /// Strength of the length→error effect (Table 5; IMDB only in the
    /// paper, mild elsewhere).
    pub length_effect: f64,
    /// Per-call FLOPs (paper C.1 for Llama-2-70B; same order for GPT).
    pub flops_per_call: f64,
}

impl ExpertProfile {
    /// Paper Table 1 LLM accuracies.
    pub fn for_pair(id: ExpertId, bench: BenchmarkId) -> Self {
        let accuracy = match (id, bench) {
            (ExpertId::Gpt35, BenchmarkId::Imdb) => 0.9415,
            (ExpertId::Gpt35, BenchmarkId::HateSpeech) => 0.8334,
            (ExpertId::Gpt35, BenchmarkId::Isear) => 0.7034,
            (ExpertId::Gpt35, BenchmarkId::Fever) => 0.7998,
            (ExpertId::Llama70b, BenchmarkId::Imdb) => 0.9333,
            (ExpertId::Llama70b, BenchmarkId::HateSpeech) => 0.7781,
            (ExpertId::Llama70b, BenchmarkId::Isear) => 0.6823,
            (ExpertId::Llama70b, BenchmarkId::Fever) => 0.7715,
        };
        let length_effect = match bench {
            BenchmarkId::Imdb => 0.6, // Table 5: 95.5% → 92.4% by length
            _ => 0.2,
        };
        ExpertProfile { id, accuracy, length_effect, flops_per_call: CostModel::LLM_INFER }
    }
}

/// The expert simulator bound to one benchmark's strata mix.
#[derive(Clone, Debug)]
pub struct Expert {
    profile: ExpertProfile,
    /// Base error rate e₀ solving
    /// `Σ_s frac_s · w_s · e₀ = 1 − accuracy`.
    base_err: f64,
    /// Mean document length (for the length percentile).
    mean_len: f64,
    seed: u64,
    /// Failure injection: when false, `annotate` returns None.
    available: bool,
    /// Total calls served (cost accounting).
    calls: std::cell::Cell<u64>,
}

impl Expert {
    /// Build from a profile and the benchmark's empirical strata mix
    /// (`fractions` = (easy, medium, hard)) and mean length.
    pub fn new(
        profile: ExpertProfile,
        fractions: (f64, f64, f64),
        mean_len: f64,
        seed: u64,
    ) -> Self {
        let weighted = fractions.0 * ERR_WEIGHT[0]
            + fractions.1 * ERR_WEIGHT[1]
            + fractions.2 * ERR_WEIGHT[2];
        let base_err = ((1.0 - profile.accuracy) / weighted.max(1e-9)).min(1.0);
        Expert {
            profile,
            base_err,
            mean_len: mean_len.max(1.0),
            seed,
            available: true,
            calls: std::cell::Cell::new(0),
        }
    }

    /// The profile in use.
    pub fn profile(&self) -> &ExpertProfile {
        &self.profile
    }

    /// Number of annotation calls served so far.
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Failure injection: make the expert unavailable (e.g. API outage).
    pub fn set_available(&mut self, avail: bool) {
        self.available = avail;
    }

    /// Per-sample error probability (deterministic in the sample).
    pub fn error_prob(&self, sample: &Sample) -> f64 {
        let w = match sample.stratum {
            Stratum::Easy => ERR_WEIGHT[0],
            Stratum::Medium => ERR_WEIGHT[1],
            Stratum::Hard => ERR_WEIGHT[2],
        };
        // Length effect: linear in the length ratio around the mean,
        // bounded to keep probabilities sane.
        let ratio = (sample.len as f64 / self.mean_len).clamp(0.2, 4.0);
        let len_mult = (1.0 + self.profile.length_effect * (ratio - 1.0)).clamp(0.25, 3.0);
        (self.base_err * w * len_mult).clamp(0.0, 0.95)
    }

    /// Annotate a sample: the expert's label (noisy ground truth) or
    /// `None` when unavailable. Deterministic per sample id.
    pub fn annotate(&self, sample: &Sample, classes: usize) -> Option<usize> {
        if !self.available {
            return None;
        }
        self.calls.set(self.calls.get() + 1);
        Some(self.label_of(sample, classes))
    }

    /// What the expert *would* answer — charge-free (used only by the
    /// evaluation harness for the Figs 5–8 expert reference line;
    /// Algorithm 1 never calls this).
    pub fn peek(&self, sample: &Sample, classes: usize) -> usize {
        self.label_of(sample, classes)
    }

    fn label_of(&self, sample: &Sample, classes: usize) -> usize {
        let mut rng = Rng::new(
            self.seed ^ (sample.id as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let p_err = self.error_prob(sample);
        if rng.coin(p_err) {
            // Wrong answer: uniform over the other classes.
            let mut wrong = rng.below(classes - 1);
            if wrong >= sample.label {
                wrong += 1;
            }
            wrong
        } else {
            sample.label
        }
    }

    /// FLOPs charged per annotation call.
    pub fn flops_per_call(&self) -> f64 {
        self.profile.flops_per_call
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Benchmark;

    fn expert_for(bench: BenchmarkId, id: ExpertId, n: usize) -> (Expert, Benchmark) {
        let b = Benchmark::build_sized(bench, 42, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let e = Expert::new(
            ExpertProfile::for_pair(id, bench),
            b.strata_fractions(),
            mean_len,
            7,
        );
        (e, b)
    }

    #[test]
    fn aggregate_accuracy_matches_profile() {
        for (bench, id, want) in [
            (BenchmarkId::Imdb, ExpertId::Gpt35, 0.9415),
            (BenchmarkId::Isear, ExpertId::Gpt35, 0.7034),
            (BenchmarkId::Fever, ExpertId::Llama70b, 0.7715),
        ] {
            let (e, b) = expert_for(bench, id, 8000);
            let correct = b
                .samples
                .iter()
                .filter(|s| e.annotate(s, b.classes) == Some(s.label))
                .count();
            let acc = correct as f64 / b.samples.len() as f64;
            assert!(
                (acc - want).abs() < 0.015,
                "{bench:?}/{id:?}: acc {acc} want {want}"
            );
        }
    }

    #[test]
    fn annotations_deterministic_per_sample() {
        let (e, b) = expert_for(BenchmarkId::Imdb, ExpertId::Gpt35, 100);
        for s in &b.samples {
            assert_eq!(e.annotate(s, 2), e.annotate(s, 2));
        }
    }

    #[test]
    fn longer_imdb_docs_get_lower_accuracy() {
        // Reproduces the Table 5 trend.
        let (e, b) = expert_for(BenchmarkId::Imdb, ExpertId::Gpt35, 12_000);
        let mut sorted: Vec<_> = b.samples.iter().collect();
        sorted.sort_by_key(|s| s.len);
        let q = sorted.len() / 5;
        let acc_of = |xs: &[&Sample]| {
            xs.iter().filter(|s| e.annotate(s, 2) == Some(s.label)).count() as f64
                / xs.len() as f64
        };
        let shortest = acc_of(&sorted[..q]);
        let longest = acc_of(&sorted[4 * q..]);
        assert!(
            shortest > longest + 0.01,
            "short {shortest} vs long {longest}"
        );
    }

    #[test]
    fn hard_stratum_is_harder_for_the_expert() {
        let (e, b) = expert_for(BenchmarkId::Fever, ExpertId::Gpt35, 8000);
        let acc_stratum = |st: Stratum| {
            let xs: Vec<_> =
                b.samples.iter().filter(|s| s.stratum == st).collect();
            xs.iter().filter(|s| e.annotate(s, 2) == Some(s.label)).count() as f64
                / xs.len() as f64
        };
        assert!(acc_stratum(Stratum::Easy) > acc_stratum(Stratum::Hard) + 0.05);
    }

    #[test]
    fn unavailability_and_call_counting() {
        let (mut e, b) = expert_for(BenchmarkId::Imdb, ExpertId::Gpt35, 10);
        assert_eq!(e.calls(), 0);
        assert!(e.annotate(&b.samples[0], 2).is_some());
        assert_eq!(e.calls(), 1);
        e.set_available(false);
        assert!(e.annotate(&b.samples[1], 2).is_none());
        assert_eq!(e.calls(), 1);
    }

    #[test]
    fn wrong_answers_are_valid_other_classes() {
        let (e, b) = expert_for(BenchmarkId::Isear, ExpertId::Llama70b, 4000);
        for s in &b.samples {
            let a = e.annotate(s, 7).unwrap();
            assert!(a < 7);
        }
    }
}
