//! Computational-cost model: the paper's Appendix C.1 FLOP accounting,
//! the Appendix B.1 prefill-latency experiment, and the cost
//! equilibrium `M = xC / (3 − 2x)`.
//!
//! All constants are the paper's own measured/derived numbers, so every
//! cost curve and budget axis in the reproduction is computed in the
//! same units the paper uses.

use crate::config::ModelKind;

/// FLOP costs per sample (paper Appendix C.1).
#[derive(Clone, Copy, Debug)]
pub struct CostModel;

impl CostModel {
    /// Logistic-regression inference FLOPs per sample.
    pub const LR_INFER: f64 = 16.9e4;
    /// Logistic-regression training FLOPs per sample.
    pub const LR_TRAIN: f64 = 33.8e4;
    /// BERT-base inference FLOPs per sample.
    pub const BERT_BASE_INFER: f64 = 9.2e7;
    /// BERT-base training FLOPs per sample.
    pub const BERT_BASE_TRAIN: f64 = 18.5e7;
    /// BERT-large inference FLOPs per sample.
    pub const BERT_LARGE_INFER: f64 = 27.7e7;
    /// BERT-large training FLOPs per sample.
    pub const BERT_LARGE_TRAIN: f64 = 55.5e7;
    /// Calibration-MLP inference FLOPs (App. C.1: negligible).
    pub const MLP_INFER: f64 = 897.0;
    /// Calibration-MLP training FLOPs.
    pub const MLP_TRAIN: f64 = 1794.0;
    /// Llama-2-70B inference FLOPs for one sample (paper's number).
    pub const LLM_INFER: f64 = 39.86e15;

    /// Inference FLOPs for a cascade level model.
    pub fn infer_flops(kind: ModelKind) -> f64 {
        match kind {
            ModelKind::Lr => Self::LR_INFER,
            ModelKind::TfmBase => Self::BERT_BASE_INFER,
            ModelKind::TfmLarge => Self::BERT_LARGE_INFER,
        }
    }

    /// Training FLOPs for a cascade level model (per sample).
    pub fn train_flops(kind: ModelKind) -> f64 {
        match kind {
            ModelKind::Lr => Self::LR_TRAIN,
            ModelKind::TfmBase => Self::BERT_BASE_TRAIN,
            ModelKind::TfmLarge => Self::BERT_LARGE_TRAIN,
        }
    }

    /// Appendix C.1 equilibrium: the maximum aggregate small-model
    /// inference cost `M` such that a cascade handling fraction `x`
    /// of queries with small models still saves cost vs all-LLM:
    /// `M = x·C / (3 − 2x)`.
    pub fn equilibrium_small_model_budget(x: f64, llm_cost: f64) -> f64 {
        assert!((0.0..=1.0).contains(&x));
        x * llm_cost / (3.0 - 2.0 * x)
    }

    /// Total per-sample training cost of the paper's large cascade
    /// (C.1: ≈ 7.4e8 FLOPs) — sanity anchor used in tests.
    pub fn large_cascade_train_flops() -> f64 {
        Self::LR_TRAIN + Self::BERT_BASE_TRAIN + Self::BERT_LARGE_TRAIN
    }
}

/// Latency model replaying the paper's Appendix B.1 prefill experiment:
/// 65B LLaMA on 8×A100, 8192-token prompts, first-token inference —
/// 3.6 s per prompt, sequential (no batching, memory-bound).
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel;

impl LatencyModel {
    /// Measured seconds per 8192-token prompt (paper B.1).
    pub const PREFILL_SECS_8K: f64 = 3.6;
    /// Tokens in the measured prompt.
    pub const PREFILL_TOKENS: f64 = 8192.0;

    /// First-token latency for a prompt of `tokens`, quadratic
    /// attention term dominating (B.1's rationale: prefill is the
    /// all-to-all attention pass).
    pub fn prefill_secs(tokens: f64) -> f64 {
        let r = tokens / Self::PREFILL_TOKENS;
        // Quadratic in sequence length for the attention term with a
        // linear floor for the MLP/projection FLOPs.
        Self::PREFILL_SECS_8K * (0.35 * r + 0.65 * r * r)
    }

    /// The paper's headline throughput arithmetic: documents/hour one
    /// 8-GPU server sustains at 3.6 s/document.
    pub fn docs_per_hour_per_server() -> f64 {
        3600.0 / Self::PREFILL_SECS_8K
    }

    /// Servers needed for a target docs/hour load (paper: 1e6/h → 1000).
    pub fn servers_needed(docs_per_hour: f64) -> f64 {
        (docs_per_hour / Self::docs_per_hour_per_server()).ceil()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c1_constants_sum() {
        // Paper C.1: total large-cascade train cost ≈ 7.4e8 FLOPs.
        let t = CostModel::large_cascade_train_flops();
        assert!((t - 7.4e8).abs() / 7.4e8 < 0.01, "{t}");
        // ... and is ~5.3e7x smaller than Llama-70B inference.
        let ratio = CostModel::LLM_INFER / t;
        assert!((ratio - 5.3e7).abs() / 5.3e7 < 0.05, "{ratio}");
    }

    #[test]
    fn equilibrium_matches_paper_example() {
        // Paper C.1: x = 0.5, C = 39.86e15 → M ≈ 9.95e15.
        let m = CostModel::equilibrium_small_model_budget(0.5, CostModel::LLM_INFER);
        assert!((m - 9.965e15).abs() / 9.965e15 < 0.01, "{m}");
    }

    #[test]
    fn equilibrium_monotone_in_x() {
        let c = CostModel::LLM_INFER;
        let mut last = 0.0;
        for i in 1..=10 {
            let m = CostModel::equilibrium_small_model_budget(i as f64 / 10.0, c);
            assert!(m > last);
            last = m;
        }
        // x = 1: all queries handled by small models → M = C.
        assert!((last - c).abs() / c < 1e-9);
    }

    #[test]
    fn prefill_anchors() {
        // At the measured prompt size, reproduce the measured 3.6 s.
        let t = LatencyModel::prefill_secs(8192.0);
        assert!((t - 3.6).abs() < 1e-9);
        // Shorter prompts strictly cheaper, superlinear growth.
        assert!(LatencyModel::prefill_secs(4096.0) < 3.6 / 2.0 + 0.7);
        assert!(LatencyModel::prefill_secs(16384.0) > 2.0 * 3.6);
    }

    #[test]
    fn server_math_matches_intro() {
        // Intro: 1e6 docs/hour needs ~1000 servers at 3.6 s/doc.
        let s = LatencyModel::servers_needed(1e6);
        assert_eq!(s, 1000.0);
    }

    #[test]
    fn per_model_accessors() {
        assert_eq!(CostModel::infer_flops(ModelKind::Lr), 16.9e4);
        assert_eq!(CostModel::train_flops(ModelKind::TfmLarge), 55.5e7);
        assert!(CostModel::infer_flops(ModelKind::TfmBase)
            < CostModel::infer_flops(ModelKind::TfmLarge));
    }
}
