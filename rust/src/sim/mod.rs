//! Simulation substrates: the FLOPs/latency cost model (paper App. B.1
//! and C.1) and the LLM-expert simulator (DESIGN.md §3 substitution).

pub mod cost;
pub mod expert;

pub use cost::{CostModel, LatencyModel};
pub use expert::{Expert, ExpertProfile};
