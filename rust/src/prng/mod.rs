//! Deterministic PRNG substrate (no `rand` crate in the offline image).
//!
//! [`Rng`] is xoshiro256** seeded via SplitMix64 — the standard
//! recommendation for fast, high-quality, reproducible simulation
//! streams. Every stochastic component of the reproduction (benchmark
//! generators, the expert's label noise, DAgger coin flips, shuffles)
//! draws from an explicitly seeded `Rng`, so whole experiments are
//! replayable bit-for-bit from their config seed.

/// SplitMix64 step — used for seeding and as a cheap standalone PRNG.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG with distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box–Muller pair.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create from a 64-bit seed (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential(rate) variate — the inter-arrival gaps of a Poisson
    /// process at `rate` events/second (the serve-layer open-loop load
    /// generator). `rate` must be positive.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0, "exp needs a positive rate");
        // 1 - U is in (0, 1], so ln never sees zero.
        -(1.0 - self.f64()).ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-ish rank sampler over [0, n): P(k) ∝ 1/(k+1)^s.
    ///
    /// Used by the synthetic text generator for realistic token
    /// frequency profiles. Inverse-CDF over precomputed weights is the
    /// caller's job for hot loops; this is the convenience path.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Rejection-inversion would be faster; n here is <= vocab (8k),
        // and hot paths precompute CDFs, so simple inversion suffices.
        let mut u = self.f64();
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        u *= norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut v = self.permutation(n);
        v.truncate(k);
        v
    }

    /// Export the full generator state (xoshiro words + the cached
    /// Box–Muller half) so a checkpointed stream can resume exactly
    /// where it left off — [`Rng::from_state`] is the inverse.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.cached_normal)
    }

    /// Rebuild a generator from [`Rng::state`] output; the restored
    /// stream continues bit-for-bit from the export point.
    pub fn from_state(s: [u64; 4], cached_normal: Option<f64>) -> Self {
        Rng { s, cached_normal }
    }
}

/// Precomputed CDF for repeated categorical sampling (hot loops).
#[derive(Clone, Debug)]
pub struct Cdf {
    cum: Vec<f64>,
}

impl Cdf {
    /// Build from unnormalized non-negative weights.
    pub fn new(weights: &[f64]) -> Self {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for w in weights {
            acc += w.max(0.0);
            cum.push(acc);
        }
        Cdf { cum }
    }

    /// Sample an index using binary search — O(log n).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = *self.cum.last().expect("empty cdf");
        let u = rng.f64() * total;
        match self
            .cum
            .binary_search_by(|c| c.partial_cmp(&u).expect("nan in cdf"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cum.len() - 1),
        }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.cum.len()
    }

    /// True when there are no categories.
    pub fn is_empty(&self) -> bool {
        self.cum.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_stream_exactly() {
        let mut a = Rng::new(7);
        // Burn an odd number of normals so the Box–Muller cache is hot.
        for _ in 0..33 {
            a.next_u64();
        }
        let _ = a.normal();
        let (s, cached) = a.state();
        assert!(cached.is_some(), "odd normal count must leave a cached half");
        let mut b = Rng::from_state(s, cached);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        assert_eq!(a.f64().to_bits(), b.f64().to_bits());
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 30_000.0 - 0.6).abs() < 0.02);
        assert!((counts[1] as f64 / 30_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn cdf_matches_categorical() {
        let mut rng = Rng::new(15);
        let w = [0.5, 0.0, 2.5, 1.0];
        let cdf = Cdf::new(&w);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[cdf.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!((counts[2] as f64 / 40_000.0 - 0.625).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut rng = Rng::new(27);
        for rate in [0.5, 4.0, 250.0] {
            let n = 30_000;
            let mut sum = 0.0;
            for _ in 0..n {
                let x = rng.exp(rate);
                assert!(x >= 0.0 && x.is_finite());
                sum += x;
            }
            let mean = sum / n as f64;
            assert!(
                (mean * rate - 1.0).abs() < 0.05,
                "rate {rate}: mean {mean} (expected {})",
                1.0 / rate
            );
        }
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Rng::new(23);
        for _ in 0..1000 {
            assert!(rng.lognormal(6.0, 0.8) > 0.0);
        }
    }

    #[test]
    fn cross_seed_streams_decorrelate() {
        // Adjacent (and distant) seeds must produce streams that agree
        // on ~50% of their bits — SplitMix64 expansion decorrelates
        // even hamming-distance-1 seeds.
        for (s1, s2) in [(0u64, 1u64), (41, 42), (7, 7 << 32), (u64::MAX - 1, u64::MAX)] {
            let (mut a, mut b) = (Rng::new(s1), Rng::new(s2));
            let mut same_bits = 0u32;
            let total = 256 * 64;
            for _ in 0..256 {
                same_bits += (!(a.next_u64() ^ b.next_u64())).count_ones();
            }
            let frac = same_bits as f64 / total as f64;
            assert!(
                (frac - 0.5).abs() < 0.03,
                "seeds {s1}/{s2}: {frac} of bits agree"
            );
        }
    }

    #[test]
    fn below_stays_in_range_for_all_bounds() {
        let mut rng = Rng::new(31);
        for n in [1usize, 2, 3, 5, 7, 10, 63, 64, 65, 1000, 1 << 20] {
            for _ in 0..500 {
                assert!(rng.below(n) < n, "below({n}) out of range");
            }
        }
        // n = 1 is degenerate: only 0 is possible.
        assert_eq!(rng.below(1), 0);
    }

    #[test]
    fn coin_edge_probabilities() {
        let mut rng = Rng::new(33);
        for _ in 0..2000 {
            assert!(!rng.coin(0.0), "coin(0) must never land");
            assert!(rng.coin(1.0), "coin(1) must always land (f64() < 1.0)");
        }
        // and a mid probability is frequency-calibrated
        let hits = (0..20_000).filter(|_| rng.coin(0.25)).count();
        assert!((hits as f64 / 20_000.0 - 0.25).abs() < 0.02, "{hits}");
    }

    #[test]
    fn f64_unit_interval_across_seeds() {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed);
            for _ in 0..1000 {
                let x = rng.f64();
                assert!((0.0..1.0).contains(&x), "seed {seed}: {x} out of [0,1)");
            }
            let y = rng.f32();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn range_and_sample_indices_invariants() {
        let mut rng = Rng::new(35);
        for _ in 0..500 {
            let x = rng.range_f64(-2.5, 7.5);
            assert!((-2.5..7.5).contains(&x));
        }
        for k in [0usize, 1, 5, 32] {
            let idx = rng.sample_indices(32, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "indices must be distinct");
            assert!(sorted.iter().all(|&i| i < 32));
        }
    }
}
