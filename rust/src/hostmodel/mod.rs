//! Host engine: pure-rust mirrors of every L2 jax graph.
//!
//! Purpose (DESIGN.md §7): (a) fast experiment sweeps without PJRT
//! dispatch overhead, (b) an independent implementation to parity-test
//! the AOT artifacts against, (c) the baseline for the §Perf L3
//! comparison. Architectures, initialization blobs, and numerics
//! (tanh-GELU, pre-LN, masked mean pooling, max-subtracted softmax)
//! match `python/compile/models/*` exactly; forward parity vs PJRT is
//! asserted to ≤1e-4 in the artifact-gated integration tests.

pub mod lr;
pub mod mlp;
pub mod tensor;
pub mod tfm;

pub use lr::HostLr;
pub use mlp::HostMlp;
pub use tfm::{HostTfm, Scratch as TfmScratch, TfmArch};
