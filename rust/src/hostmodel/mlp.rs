//! Host mirror of the deferral-calibration MLP (L2 `models/mlp.py`).
//!
//! Input features: `[probs ++ maxprob ++ normalized entropy]`; one tanh
//! hidden layer (16 units), sigmoid output; MSE objective against
//! `z = 1[argmax m_i(x) != y*]` (paper Eq. 5).

use crate::prng::Rng;
use crate::util::normalized_entropy;

/// Hidden width — matches `python/compile/models/mlp.py::HIDDEN`.
pub const HIDDEN: usize = 16;

/// Calibration MLP for a `classes`-way level.
#[derive(Clone, Debug)]
pub struct HostMlp {
    classes: usize,
    in_dim: usize,
    /// `[in_dim, HIDDEN]` row-major.
    w1: Vec<f32>,
    b1: Vec<f32>,
    /// `[HIDDEN, 1]`.
    w2: Vec<f32>,
    b2: f32,
}

impl HostMlp {
    /// Glorot-uniform init, deterministic in `seed` (host-only runs).
    pub fn new(classes: usize, seed: u64) -> Self {
        let in_dim = classes + 2;
        let mut rng = Rng::new(seed ^ 0x11AC_B00C);
        let lim1 = (6.0 / (in_dim + HIDDEN) as f64).sqrt();
        let w1 = (0..in_dim * HIDDEN)
            .map(|_| rng.range_f64(-lim1, lim1) as f32)
            .collect();
        let lim2 = (6.0 / (HIDDEN + 1) as f64).sqrt();
        let w2 = (0..HIDDEN).map(|_| rng.range_f64(-lim2, lim2) as f32).collect();
        // +1 output bias: initial score ≈ 0.73 keeps the cascade's
        // gates open at startup (matches mlp.py init; see paper §1).
        HostMlp { classes, in_dim, w1, b1: vec![0.0; HIDDEN], w2, b2: 1.0 }
    }

    /// Load from a flat blob `[w1, b1, w2, b2]` (aot.py init order).
    pub fn from_flat(classes: usize, flat: &[f32]) -> Self {
        let in_dim = classes + 2;
        let n1 = in_dim * HIDDEN;
        assert_eq!(flat.len(), n1 + HIDDEN + HIDDEN + 1);
        HostMlp {
            classes,
            in_dim,
            w1: flat[..n1].to_vec(),
            b1: flat[n1..n1 + HIDDEN].to_vec(),
            w2: flat[n1 + HIDDEN..n1 + 2 * HIDDEN].to_vec(),
            b2: flat[n1 + 2 * HIDDEN],
        }
    }

    /// Snapshot as one flat blob.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = self.w1.clone();
        v.extend_from_slice(&self.b1);
        v.extend_from_slice(&self.w2);
        v.push(self.b2);
        v
    }

    /// Number of classes the calibrator scores over.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Flat-blob length for a `classes`-way calibrator.
    pub fn flat_len(classes: usize) -> usize {
        (classes + 2) * HIDDEN + HIDDEN + HIDDEN + 1
    }

    /// Restore parameters in place from a [`HostMlp::to_flat`] blob
    /// (warm respawn / snapshot install; no reallocation).
    pub fn load_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), Self::flat_len(self.classes));
        let n1 = self.in_dim * HIDDEN;
        self.w1.copy_from_slice(&flat[..n1]);
        self.b1.copy_from_slice(&flat[n1..n1 + HIDDEN]);
        self.w2.copy_from_slice(&flat[n1 + HIDDEN..n1 + 2 * HIDDEN]);
        self.b2 = flat[n1 + 2 * HIDDEN];
    }

    fn features(&self, probs: &[f32], out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(probs);
        out.push(probs.iter().cloned().fold(f32::NEG_INFINITY, f32::max));
        out.push(normalized_entropy(probs));
    }

    /// Deferral score in (0,1) for one probability vector.
    ///
    /// Per-call compat API (allocates the feature buffer); the
    /// calibrator hot path uses [`HostMlp::predict_scratch`] with a
    /// reused buffer — bit-identical, it runs the same code.
    pub fn predict(&self, probs: &[f32]) -> f32 {
        // lint: allow(hot-alloc) — compat wrapper; hot callers reuse a Scratch buffer
        let mut feat = Vec::with_capacity(self.in_dim);
        self.predict_scratch(probs, &mut feat)
    }

    /// Deferral score with a caller-owned feature buffer: zero heap
    /// allocation once `feat`'s capacity reaches `classes + 2` (it is
    /// cleared and refilled, never reallocated in steady state).
    pub fn predict_scratch(&self, probs: &[f32], feat: &mut Vec<f32>) -> f32 {
        debug_assert_eq!(probs.len(), self.classes);
        self.features(probs, feat);
        let mut logit = self.b2;
        for h in 0..HIDDEN {
            let mut a = self.b1[h];
            for (i, &f) in feat.iter().enumerate() {
                a += f * self.w1[i * HIDDEN + h];
            }
            logit += a.tanh() * self.w2[h];
        }
        1.0 / (1.0 + (-logit).exp())
    }

    /// Batched deferral scores into `out` (`len == probs.len()`), one
    /// shared feature buffer across rows — bit-identical to per-row
    /// [`HostMlp::predict`] and allocation-free in steady state.
    pub fn predict_batch_into(
        &self,
        probs: &[&[f32]],
        feat: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), probs.len());
        for (&p, o) in probs.iter().zip(out.iter_mut()) {
            *o = self.predict_scratch(p, feat);
        }
    }

    /// One OGD minibatch step on MSE(score, z); returns the loss.
    pub fn train_batch(&mut self, probs: &[&[f32]], zs: &[f32], lr: f32) -> f32 {
        assert_eq!(probs.len(), zs.len());
        assert!(!probs.is_empty());
        let bsz = probs.len() as f32;
        let mut dw1 = vec![0.0f32; self.w1.len()];
        let mut db1 = vec![0.0f32; HIDDEN];
        let mut dw2 = vec![0.0f32; HIDDEN];
        let mut db2 = 0.0f32;
        let mut loss = 0.0f32;
        let mut feat = Vec::with_capacity(self.in_dim);
        for (&p, &z) in probs.iter().zip(zs) {
            self.features(p, &mut feat);
            // forward with caches
            let mut hpre = vec![0.0f32; HIDDEN];
            let mut hact = vec![0.0f32; HIDDEN];
            let mut logit = self.b2;
            for h in 0..HIDDEN {
                let mut a = self.b1[h];
                for (i, &f) in feat.iter().enumerate() {
                    a += f * self.w1[i * HIDDEN + h];
                }
                hpre[h] = a;
                hact[h] = a.tanh();
                logit += hact[h] * self.w2[h];
            }
            let s = 1.0 / (1.0 + (-logit).exp());
            loss += (s - z) * (s - z);
            // backward: dL/ds = 2(s-z)/B ; ds/dlogit = s(1-s)
            let dlogit = 2.0 * (s - z) / bsz * s * (1.0 - s);
            db2 += dlogit;
            for h in 0..HIDDEN {
                dw2[h] += dlogit * hact[h];
                let dh = dlogit * self.w2[h] * (1.0 - hact[h] * hact[h]);
                db1[h] += dh;
                for (i, &f) in feat.iter().enumerate() {
                    dw1[i * HIDDEN + h] += dh * f;
                }
            }
        }
        for (w, d) in self.w1.iter_mut().zip(&dw1) {
            *w -= lr * d;
        }
        for (w, d) in self.b1.iter_mut().zip(&db1) {
            *w -= lr * d;
        }
        for (w, d) in self.w2.iter_mut().zip(&dw2) {
            *w -= lr * d;
        }
        self.b2 -= lr * db2;
        loss / bsz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_in_unit_interval() {
        let m = HostMlp::new(7, 0);
        let p = vec![1.0 / 7.0; 7];
        let s = m.predict(&p);
        assert!(s > 0.0 && s < 1.0);
    }

    #[test]
    fn learns_confidence_signal() {
        // Train "defer when max-prob is low" — the calibrator's job.
        let mut m = HostMlp::new(2, 1);
        let mut rng = Rng::new(2);
        for _ in 0..400 {
            let ps: Vec<Vec<f32>> = (0..8)
                .map(|_| {
                    let c = 0.5 + 0.5 * rng.f32();
                    vec![c, 1.0 - c]
                })
                .collect();
            let zs: Vec<f32> =
                ps.iter().map(|p| if p[0] < 0.75 { 1.0 } else { 0.0 }).collect();
            let prefs: Vec<&[f32]> = ps.iter().map(|v| v.as_slice()).collect();
            m.train_batch(&prefs, &zs, 0.05);
        }
        assert!(m.predict(&[0.55, 0.45]) > m.predict(&[0.98, 0.02]));
    }

    #[test]
    fn train_reduces_mse() {
        let mut m = HostMlp::new(3, 3);
        let ps = [
            vec![0.8f32, 0.1, 0.1],
            vec![0.4, 0.3, 0.3],
            vec![0.34, 0.33, 0.33],
            vec![0.95, 0.03, 0.02],
        ];
        let zs = [0.0f32, 1.0, 1.0, 0.0];
        let prefs: Vec<&[f32]> = ps.iter().map(|v| v.as_slice()).collect();
        let l0 = m.train_batch(&prefs, &zs, 0.1);
        let mut l = l0;
        for _ in 0..100 {
            l = m.train_batch(&prefs, &zs, 0.1);
        }
        assert!(l < l0 * 0.8, "{l} vs {l0}");
    }

    #[test]
    fn batched_matches_per_sample_bitwise() {
        let m = HostMlp::new(3, 6);
        let ps = [
            vec![0.8f32, 0.1, 0.1],
            vec![0.4, 0.3, 0.3],
            vec![1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0],
        ];
        let prefs: Vec<&[f32]> = ps.iter().map(|v| v.as_slice()).collect();
        let mut feat = Vec::new();
        let mut out = vec![0.0f32; 3];
        m.predict_batch_into(&prefs, &mut feat, &mut out);
        for (p, got) in prefs.iter().zip(&out) {
            assert_eq!(got.to_bits(), m.predict(p).to_bits());
        }
    }

    #[test]
    fn flat_roundtrip() {
        let m = HostMlp::new(2, 4);
        let m2 = HostMlp::from_flat(2, &m.to_flat());
        let p = [0.7f32, 0.3];
        assert_eq!(m.predict(&p), m2.predict(&p));
    }
}
