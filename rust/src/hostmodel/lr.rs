//! Host mirror of the logistic-regression level (L2 `models/lr.py`).
//!
//! Forward = the fused-head kernel's semantics; update = the fused
//! Pallas `lr_grad_step` semantics (`W -= lr·xᵀg/B`, `b -= lr·mean(g)`).
//! The forward exploits the sparsity of hashed bag-of-words inputs.

use crate::util::softmax;

/// Logistic regression over `dim` features and `classes` labels.
#[derive(Clone, Debug)]
pub struct HostLr {
    dim: usize,
    classes: usize,
    /// Row-major `[dim, classes]`.
    w: Vec<f32>,
    b: Vec<f32>,
}

impl HostLr {
    /// Zero-initialized (matches `lr.init_params`).
    pub fn new(dim: usize, classes: usize) -> Self {
        HostLr { dim, classes, w: vec![0.0; dim * classes], b: vec![0.0; classes] }
    }

    /// Load from a flat parameter blob `[w (dim*classes), b (classes)]`.
    pub fn from_flat(dim: usize, classes: usize, flat: &[f32]) -> Self {
        assert_eq!(flat.len(), dim * classes + classes);
        HostLr {
            dim,
            classes,
            w: flat[..dim * classes].to_vec(),
            b: flat[dim * classes..].to_vec(),
        }
    }

    /// Snapshot parameters as one flat blob (PJRT interop/tests).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = self.w.clone();
        v.extend_from_slice(&self.b);
        v
    }

    /// Flat-blob length for a `(dim, classes)` model.
    pub fn flat_len(dim: usize, classes: usize) -> usize {
        dim * classes + classes
    }

    /// Restore parameters in place from a [`HostLr::to_flat`] blob
    /// (warm respawn / snapshot install; no reallocation).
    pub fn load_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), Self::flat_len(self.dim, self.classes));
        let nw = self.dim * self.classes;
        self.w.copy_from_slice(&flat[..nw]);
        self.b.copy_from_slice(&flat[nw..]);
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// probs = softmax(x·W + b); sparse-aware over x.
    ///
    /// Per-call compat API (allocates the result); the serve/cascade
    /// hot paths use [`HostLr::predict_batch_into`] with a reused
    /// output buffer.
    pub fn predict(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.dim);
        // lint: allow(hot-alloc) — compat wrapper; batched hot path is alloc-free
        let mut logits = self.b.clone();
        for (d, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &self.w[d * self.classes..(d + 1) * self.classes];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += xv * wv;
            }
        }
        softmax(&logits)
    }

    /// Batched probs, written into `out` (`[b, classes]` row-major)
    /// with zero steady-state allocation. Rows keep the per-sample
    /// sparse accumulation and an in-place softmax that mirrors
    /// [`softmax`] operation-for-operation, so the output is
    /// bit-for-bit identical to per-row [`HostLr::predict`].
    pub fn predict_batch_into(&self, xs: &[&[f32]], out: &mut [f32]) {
        let c = self.classes;
        assert_eq!(out.len(), xs.len() * c);
        for (bi, &x) in xs.iter().enumerate() {
            debug_assert_eq!(x.len(), self.dim);
            let row_out = &mut out[bi * c..(bi + 1) * c];
            row_out.copy_from_slice(&self.b);
            for (d, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &self.w[d * c..(d + 1) * c];
                for (l, &wv) in row_out.iter_mut().zip(row) {
                    *l += xv * wv;
                }
            }
            // in-place softmax: same max / exp / index-order sum /
            // divide-by-sum sequence as `util::softmax`
            let m = row_out.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            for v in row_out.iter_mut() {
                *v = (*v - m).exp();
            }
            let sum: f32 = row_out.iter().sum();
            for v in row_out.iter_mut() {
                *v /= sum;
            }
        }
    }

    /// One OGD minibatch step; returns the mean cross-entropy loss.
    pub fn train_batch(&mut self, xs: &[&[f32]], ys: &[usize], lr: f32) -> f32 {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let bsz = xs.len() as f32;
        let c = self.classes;
        let mut loss = 0.0f32;
        // Accumulate bias grad densely; weight grad applied sparsely
        // per sample (x rows are sparse).
        let mut db = vec![0.0f32; c];
        // g rows are needed per sample for the sparse W update.
        for (&x, &y) in xs.iter().zip(ys) {
            let probs = self.predict(x);
            loss -= (probs[y] + 1e-9).ln();
            // g = probs - onehot(y)
            for (j, db_j) in db.iter_mut().enumerate() {
                let g = probs[j] - if j == y { 1.0 } else { 0.0 };
                *db_j += g;
            }
            let scale = lr / bsz;
            for (d, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &mut self.w[d * c..(d + 1) * c];
                for (j, wv) in row.iter_mut().enumerate() {
                    let g = probs[j] - if j == y { 1.0 } else { 0.0 };
                    *wv -= scale * xv * g;
                }
            }
        }
        for (bj, &dbj) in self.b.iter_mut().zip(&db) {
            *bj -= lr * dbj / bsz;
        }
        loss / bsz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn uniform_at_init() {
        let m = HostLr::new(16, 4);
        let p = m.predict(&vec![0.5; 16]);
        for &v in &p {
            assert!((v - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn learns_separable_data() {
        let mut rng = Rng::new(3);
        let dim = 64;
        let mut m = HostLr::new(dim, 2);
        let gen = |rng: &mut Rng, y: usize| -> Vec<f32> {
            let mut x = vec![0.0f32; dim];
            for _ in 0..6 {
                let base = if y == 0 { 0 } else { dim / 2 };
                x[base + rng.below(dim / 2)] = 1.0;
            }
            x
        };
        for _ in 0..100 {
            let ys: Vec<usize> = (0..8).map(|_| rng.below(2)).collect();
            let xs: Vec<Vec<f32>> = ys.iter().map(|&y| gen(&mut rng, y)).collect();
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            m.train_batch(&xrefs, &ys, 0.5);
        }
        let mut correct = 0;
        for _ in 0..200 {
            let y = rng.below(2);
            let x = gen(&mut rng, y);
            if crate::util::argmax(&m.predict(&x)) == y {
                correct += 1;
            }
        }
        assert!(correct > 190, "correct={correct}");
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        let mut rng = Rng::new(5);
        let mut m = HostLr::new(32, 3);
        let xs: Vec<Vec<f32>> =
            (0..8).map(|_| (0..32).map(|_| rng.f32() - 0.5).collect()).collect();
        let ys: Vec<usize> = (0..8).map(|_| rng.below(3)).collect();
        let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let l0 = m.train_batch(&xr, &ys, 0.3);
        let mut l = l0;
        for _ in 0..20 {
            l = m.train_batch(&xr, &ys, 0.3);
        }
        assert!(l < l0, "{l} !< {l0}");
    }

    #[test]
    fn batched_matches_per_sample_bitwise() {
        let mut rng = Rng::new(9);
        let dim = 48;
        let mut m = HostLr::new(dim, 3);
        // train a little so weights are nonzero
        let xs: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                (0..dim)
                    .map(|_| if rng.below(3) == 0 { rng.f32() } else { 0.0 })
                    .collect()
            })
            .collect();
        let ys: Vec<usize> = (0..8).map(|_| rng.below(3)).collect();
        let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        m.train_batch(&xr, &ys, 0.4);
        for b in [1usize, 3, 8] {
            let mut out = vec![0.0f32; b * 3];
            m.predict_batch_into(&xr[..b], &mut out);
            for (bi, &x) in xr[..b].iter().enumerate() {
                let want = m.predict(x);
                for (c, w) in want.iter().enumerate() {
                    assert_eq!(out[bi * 3 + c].to_bits(), w.to_bits(), "b={b} row={bi}");
                }
            }
        }
    }

    #[test]
    fn flat_roundtrip() {
        let mut m = HostLr::new(8, 2);
        let xs = vec![vec![1.0f32; 8]];
        let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        m.train_batch(&xr, &[1], 0.5);
        let m2 = HostLr::from_flat(8, 2, &m.to_flat());
        assert_eq!(m.predict(&xs[0]), m2.predict(&xs[0]));
    }
}
