//! Minimal dense f32 tensor ops for the host-engine model mirrors.
//!
//! Row-major, shape-explicit free functions over `&[f32]` — enough to
//! express the L2 graphs (linear, layernorm, gelu, softmax, attention)
//! and their manual backward passes. The matmul uses the cache-friendly
//! i-k-j loop order which LLVM autovectorizes; model dimensions here
//! (d ≤ 96) keep everything L1/L2-resident.

/// c[m,n] = a[m,k] @ b[k,n] (accumulating into zeroed output).
///
/// Sparse variant: rows of `a` that are exactly 0.0 are skipped, which
/// pays off for hashed bag-of-words inputs and post-softmax attention
/// probabilities with masked (exactly-zero) columns. For dense
/// activations the per-`(i,k)` branch costs more than it saves — use
/// [`matmul_dense`] there; the two are bit-for-bit identical (see
/// `matmul_dense`'s docs for the argument).
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse inputs (hashed BoW) skip entire rows
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Register-tile width of [`matmul_dense`]'s inner loop: 16 f32 lanes
/// stay resident in vector registers across the whole k reduction.
const DENSE_TILE: usize = 16;

/// c[m,n] = a[m,k] @ b[k,n] — dense variant of [`matmul`].
///
/// Two differences from the sparse kernel, neither observable in the
/// output bits:
///
/// 1. **No `av == 0.0` skip.** The extra terms are `±0.0 * bv = ±0.0`,
///    and inserting `±0.0` additions into a `+0.0`-seeded running sum
///    never changes its bits under round-to-nearest: the accumulator
///    can never become `-0.0` (that would need two `-0.0` addends or a
///    directed rounding mode), `x + ±0.0 == x` bitwise for every other
///    value, and the nonzero terms are the same terms either way.
/// 2. **Output tiling.** Each output row is produced in
///    [`DENSE_TILE`]-wide column blocks whose accumulators live in
///    registers for the whole k loop (the sparse kernel re-loads and
///    re-stores the full output row once per k). Every individual
///    `c[i,j]` still accumulates its k terms in ascending-k order, so
///    per-element results are bit-identical — only the interleaving
///    *across* independent elements changes.
///
/// The equivalence is pinned by `dense_matches_sparse_bitwise` below
/// and by the cross-model property test in `tests/test_kernels.rs`.
pub fn matmul_dense(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 + DENSE_TILE <= n {
            let mut acc = [0.0f32; DENSE_TILE];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n + j0..kk * n + j0 + DENSE_TILE];
                for (cv, &bv) in acc.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
            crow[j0..j0 + DENSE_TILE].copy_from_slice(&acc);
            j0 += DENSE_TILE;
        }
        // remainder columns (n not a multiple of the tile width)
        for (jj, cv) in crow.iter_mut().enumerate().skip(j0) {
            let mut acc = 0.0f32;
            for (kk, &av) in arow.iter().enumerate() {
                acc += av * b[kk * n + jj];
            }
            *cv = acc;
        }
    }
}

/// c[m,n] += a[k,m]^T @ b[k,n] — the dW of a linear layer.
pub fn matmul_at_b_accum(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// c[m,k] = a[m,n] @ b[k,n]^T — the dx of a linear layer.
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * n..(j + 1) * n];
            let mut acc = 0.0;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
}

/// y[m,n] = x[m,k] @ w[k,n] + b[n] (sparse-matmul variant).
pub fn linear(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32], m: usize, k: usize, n: usize) {
    matmul(x, w, y, m, k, n);
    for i in 0..m {
        for (yv, &bv) in y[i * n..(i + 1) * n].iter_mut().zip(b) {
            *yv += bv;
        }
    }
}

/// y[m,n] = x[m,k] @ w[k,n] + b[n] via [`matmul_dense`] — bit-identical
/// to [`linear`] (same post-matmul bias pass, in the same order).
pub fn linear_dense(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    y: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    matmul_dense(x, w, y, m, k, n);
    for i in 0..m {
        for (yv, &bv) in y[i * n..(i + 1) * n].iter_mut().zip(b) {
            *yv += bv;
        }
    }
}

/// In-place row softmax over `[rows, cols]` (max-subtracted).
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        let inv = 1.0 / s;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// GELU, tanh approximation — must match `jax.nn.gelu` (approximate=True).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu / dx for the tanh approximation.
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    let x3 = x * x * x;
    let inner = C * (x + 0.044715 * x3);
    let t = inner.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// LayerNorm forward over the last axis of `[rows, d]`.
///
/// Writes normalized output to `y` and (optionally) caches per-row
/// `(mu, inv_sigma)` into `stats` (len 2*rows) for the backward pass.
pub fn layernorm(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    y: &mut [f32],
    stats: Option<&mut [f32]>,
    rows: usize,
    d: usize,
    eps: f32,
) {
    let mut stats_buf = stats;
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for i in 0..d {
            yr[i] = (xr[i] - mu) * inv * g[i] + b[i];
        }
        if let Some(s) = stats_buf.as_deref_mut() {
            s[2 * r] = mu;
            s[2 * r + 1] = inv;
        }
    }
}

/// LayerNorm backward: given dy, x, cached stats → dx (+= into dg/db).
#[allow(clippy::too_many_arguments)]
pub fn layernorm_backward(
    dy: &[f32],
    x: &[f32],
    g: &[f32],
    stats: &[f32],
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
    rows: usize,
    d: usize,
) {
    for r in 0..rows {
        let (mu, inv) = (stats[2 * r], stats[2 * r + 1]);
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let dxr = &mut dx[r * d..(r + 1) * d];
        // dxhat, and the two means the formula needs.
        let mut mean_dxhat = 0.0f32;
        let mut mean_dxhat_xhat = 0.0f32;
        for i in 0..d {
            let xhat = (xr[i] - mu) * inv;
            let dxhat = dyr[i] * g[i];
            mean_dxhat += dxhat;
            mean_dxhat_xhat += dxhat * xhat;
            dg[i] += dyr[i] * xhat;
            db[i] += dyr[i];
        }
        mean_dxhat /= d as f32;
        mean_dxhat_xhat /= d as f32;
        for i in 0..d {
            let xhat = (xr[i] - mu) * inv;
            let dxhat = dyr[i] * g[i];
            dxr[i] = inv * (dxhat - mean_dxhat - xhat * mean_dxhat_xhat);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, a);
        // with ones: rows sum
        let b1 = [1.0, 1.0, 1.0, 1.0];
        matmul(&a, &b1, &mut c, 2, 2, 2);
        assert_eq!(c, [3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn dense_matches_sparse_bitwise() {
        // Shapes straddling the DENSE_TILE boundary, inputs salted with
        // exact +0.0 / -0.0 entries so the sparse skip actually fires
        // and the ±0.0-insertion argument is exercised, not just argued.
        let mut rng = crate::prng::Rng::new(42);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 7), (4, 16, 16), (8, 64, 17), (2, 64, 256), (5, 7, 33)]
        {
            let gen = |rng: &mut crate::prng::Rng, len: usize| -> Vec<f32> {
                (0..len)
                    .map(|_| match rng.below(8) {
                        0 => 0.0,
                        1 => -0.0,
                        _ => (rng.f32() - 0.5) * 4.0,
                    })
                    .collect()
            };
            let a = gen(&mut rng, m * k);
            let b = gen(&mut rng, k * n);
            let mut cs = vec![1.0f32; m * n]; // nonzero garbage: both must overwrite
            let mut cd = vec![2.0f32; m * n];
            matmul(&a, &b, &mut cs, m, k, n);
            matmul_dense(&a, &b, &mut cd, m, k, n);
            for (i, (s, d)) in cs.iter().zip(&cd).enumerate() {
                assert_eq!(
                    s.to_bits(),
                    d.to_bits(),
                    "({m},{k},{n}) elem {i}: sparse {s} dense {d}"
                );
            }
            let bias = gen(&mut rng, n);
            let mut ys = vec![0.0f32; m * n];
            let mut yd = vec![0.0f32; m * n];
            linear(&a, &b, &bias, &mut ys, m, k, n);
            linear_dense(&a, &b, &bias, &mut yd, m, k, n);
            for (s, d) in ys.iter().zip(&yd) {
                assert_eq!(s.to_bits(), d.to_bits());
            }
        }
    }

    #[test]
    fn matmul_transposes_agree() {
        // verify A^T B and A B^T against naive matmul
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3,2] or [2,3]
        let b = [1.0, -1.0, 0.5, 2.0, -0.5, 1.5]; // [3,2] or [2,3]
        // A^T B with A:[3,2] -> [2,2]
        let mut c = [0.0; 4];
        matmul_at_b_accum(&a, &b, &mut c, 3, 2, 2);
        // naive
        let mut want = [0.0; 4];
        for k in 0..3 {
            for i in 0..2 {
                for j in 0..2 {
                    want[i * 2 + j] += a[k * 2 + i] * b[k * 2 + j];
                }
            }
        }
        assert_eq!(c, want);
        // A B^T with A:[2,3], B:[2,3] -> [2,2]
        let mut c2 = [0.0; 4];
        matmul_a_bt(&a, &b, &mut c2, 2, 3, 2);
        let mut want2 = [0.0; 4];
        for i in 0..2 {
            for j in 0..2 {
                for n in 0..3 {
                    want2[i * 2 + j] += a[i * 3 + n] * b[j * 3 + n];
                }
            }
        }
        assert_eq!(c2, want2);
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut x = [1.0, 2.0, 3.0, 1000.0, 1001.0, 999.0];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(x[r * 3..(r + 1) * 3].iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn gelu_matches_reference_points() {
        // Values from jax.nn.gelu (approximate=True).
        assert!((gelu(0.0) - 0.0).abs() < 1e-6);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!((gelu(3.0) - 2.996363).abs() < 1e-4);
    }

    #[test]
    fn gelu_grad_is_numeric_derivative() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 2.5] {
            let h = 1e-3;
            let num = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - num).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn layernorm_forward_stats() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let g = [1.0, 1.0, 1.0, 1.0];
        let b = [0.0; 4];
        let mut y = [0.0; 4];
        let mut stats = [0.0; 2];
        layernorm(&x, &g, &b, &mut y, Some(&mut stats), 1, 4, 1e-5);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_backward_numeric() {
        // finite-difference check of dx through a scalar loss sum(y*w)
        let x = [0.3f32, -1.2, 0.8, 2.1, -0.4, 0.05];
        let g = [1.1f32, 0.9, 1.3];
        let bb = [0.1f32, -0.2, 0.0];
        let wloss = [0.7f32, -1.3, 0.4, 0.2, 0.9, -0.6];
        let rows = 2;
        let d = 3;
        let loss = |xv: &[f32]| -> f32 {
            let mut y = vec![0.0; 6];
            layernorm(xv, &g, &bb, &mut y, None, rows, d, 1e-5);
            y.iter().zip(&wloss).map(|(a, b)| a * b).sum()
        };
        let mut y = vec![0.0; 6];
        let mut stats = vec![0.0; 4];
        layernorm(&x, &g, &bb, &mut y, Some(&mut stats), rows, d, 1e-5);
        let mut dx = vec![0.0; 6];
        let mut dg = vec![0.0; 3];
        let mut db = vec![0.0; 3];
        layernorm_backward(&wloss, &x, &g, &stats, &mut dx, &mut dg, &mut db, rows, d);
        for i in 0..6 {
            let mut xp = x;
            xp[i] += 1e-3;
            let mut xm = x;
            xm[i] -= 1e-3;
            let num = (loss(&xp) - loss(&xm)) / 2e-3;
            assert!((dx[i] - num).abs() < 1e-2, "i={i} got {} want {num}", dx[i]);
        }
    }
}
