//! Host mirror of the transformer encoder (L2 `models/transformer.py`).
//!
//! Same architecture class as the paper's BERT levels: token+position
//! embeddings, pre-LN self-attention blocks, tanh-GELU FFN, masked mean
//! pooling, softmax head. Forward numerics match the jax graph (parity
//! asserted against the AOT artifacts); the backward pass is a manual
//! reverse-mode derivation with global-gradient-norm clipping at 1.0 —
//! the same update rule `make_step` compiles.

use super::tensor as t;
use crate::prng::Rng;

/// Architecture preset — mirrors `transformer.CONFIGS`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TfmArch {
    /// BERT-base surrogate: d=64, 4 heads, 2 layers, ffn 256.
    Base,
    /// BERT-large surrogate: d=96, 6 heads, 4 layers, ffn 384.
    Large,
}

impl TfmArch {
    /// (vocab, seq, d, heads, layers, ffn)
    pub fn dims(self) -> (usize, usize, usize, usize, usize, usize) {
        match self {
            TfmArch::Base => (8192, 64, 64, 4, 2, 256),
            TfmArch::Large => (8192, 64, 96, 6, 4, 384),
        }
    }
}

/// Per-layer parameter tensors (order mirrors `param_spec`).
#[derive(Clone, Debug)]
struct Layer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Vec<f32>,
    bq: Vec<f32>,
    wk: Vec<f32>,
    bk: Vec<f32>,
    wv: Vec<f32>,
    bv: Vec<f32>,
    wo: Vec<f32>,
    bo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

impl Layer {
    fn zeros_like(&self) -> Layer {
        Layer {
            ln1_g: vec![0.0; self.ln1_g.len()],
            ln1_b: vec![0.0; self.ln1_b.len()],
            wq: vec![0.0; self.wq.len()],
            bq: vec![0.0; self.bq.len()],
            wk: vec![0.0; self.wk.len()],
            bk: vec![0.0; self.bk.len()],
            wv: vec![0.0; self.wv.len()],
            bv: vec![0.0; self.bv.len()],
            wo: vec![0.0; self.wo.len()],
            bo: vec![0.0; self.bo.len()],
            ln2_g: vec![0.0; self.ln2_g.len()],
            ln2_b: vec![0.0; self.ln2_b.len()],
            w1: vec![0.0; self.w1.len()],
            b1: vec![0.0; self.b1.len()],
            w2: vec![0.0; self.w2.len()],
            b2: vec![0.0; self.b2.len()],
        }
    }
}

/// The full parameter set.
#[derive(Clone, Debug)]
struct Params {
    embed: Vec<f32>,
    pos: Vec<f32>,
    layers: Vec<Layer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
}

/// Forward activation caches for one sequence (backward pass inputs).
struct Cache {
    /// Residual-stream input to each layer (pre-LN1), `[L, d]`.
    x_in: Vec<Vec<f32>>,
    /// LN1 output per layer.
    hx1: Vec<Vec<f32>>,
    /// LN1 stats per layer (mu, inv) per row.
    ln1_stats: Vec<Vec<f32>>,
    /// Q/K/V `[L, d]` per layer.
    q: Vec<Vec<f32>>,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Attention probabilities per layer, `[heads][L*L]`.
    p: Vec<Vec<Vec<f32>>>,
    /// Attention output (pre-Wo) per layer, `[L, d]`.
    o: Vec<Vec<f32>>,
    /// Residual after attention (pre-LN2) per layer.
    x_mid: Vec<Vec<f32>>,
    /// LN2 output per layer.
    hx2: Vec<Vec<f32>>,
    ln2_stats: Vec<Vec<f32>>,
    /// FFN pre-activation `[L, ffn]` per layer.
    ffn_pre: Vec<Vec<f32>>,
    /// FFN activation (gelu) per layer.
    ffn_act: Vec<Vec<f32>>,
    /// Final residual stream (pre-LNf).
    x_final: Vec<f32>,
    lnf_out: Vec<f32>,
    lnf_stats: Vec<f32>,
    pooled: Vec<f32>,
    probs: Vec<f32>,
    mask_sum: f32,
}

/// Reusable forward workspace for [`HostTfm::predict_batch_into`].
///
/// Owns every activation buffer the batched forward needs, grown once
/// to the high-water batch size and then reused: steady-state batched
/// inference does **zero** heap allocation (pinned by
/// `tests/test_alloc.rs` with a counting global allocator). One
/// `Scratch` serves any `(arch, classes, batch)` — buffers are resized
/// on demand and sliced to the live extent each call.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    /// Residual stream, `[B·L, d]`.
    x: Vec<f32>,
    /// LayerNorm output (LN1 and LN2 reuse it), `[B·L, d]`.
    hx: Vec<f32>,
    /// LayerNorm `(mu, inv)` stats, `[2·B·L]`.
    stats: Vec<f32>,
    /// Fused Q projection, `[B·L, d]`.
    q: Vec<f32>,
    /// Fused K projection, `[B·L, d]`.
    k: Vec<f32>,
    /// Fused V projection, `[B·L, d]`.
    v: Vec<f32>,
    /// Attention output pre-Wo, `[B·L, d]`.
    o: Vec<f32>,
    /// Wo / FFN-out projection (sequential uses), `[B·L, d]`.
    proj: Vec<f32>,
    /// FFN pre-activation, `[B·L, ffn]`.
    pre: Vec<f32>,
    /// FFN gelu activation, `[B·L, ffn]`.
    act: Vec<f32>,
    /// Per-head Q panel, `[L, dh]`.
    qh: Vec<f32>,
    /// Per-head K panel, `[L, dh]`.
    kh: Vec<f32>,
    /// Per-head V panel, `[L, dh]`.
    vh: Vec<f32>,
    /// Per-head context panel, `[L, dh]`.
    oh: Vec<f32>,
    /// Attention scores/probs, `[L, L]`.
    s: Vec<f32>,
    /// Masked-mean pooled rows, `[B, d]`.
    pooled: Vec<f32>,
}

impl Scratch {
    /// Empty workspace; buffers grow lazily on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow (never shrink) every buffer to hold a `b`-sequence batch.
    fn ensure(&mut self, b: usize, l: usize, d: usize, dh: usize, f: usize) {
        let bl = b * l;
        let grow = |v: &mut Vec<f32>, n: usize| {
            if v.len() < n {
                v.resize(n, 0.0);
            }
        };
        grow(&mut self.x, bl * d);
        grow(&mut self.hx, bl * d);
        grow(&mut self.stats, 2 * bl);
        grow(&mut self.q, bl * d);
        grow(&mut self.k, bl * d);
        grow(&mut self.v, bl * d);
        grow(&mut self.o, bl * d);
        grow(&mut self.proj, bl * d);
        grow(&mut self.pre, bl * f);
        grow(&mut self.act, bl * f);
        grow(&mut self.qh, l * dh);
        grow(&mut self.kh, l * dh);
        grow(&mut self.vh, l * dh);
        grow(&mut self.oh, l * dh);
        grow(&mut self.s, l * l);
        grow(&mut self.pooled, b * d);
    }
}

/// Host transformer encoder + classifier.
#[derive(Clone, Debug)]
pub struct HostTfm {
    arch: TfmArch,
    classes: usize,
    params: Params,
}

impl HostTfm {
    /// Fresh model with its own deterministic init (host-only runs;
    /// BERT-style: N(0, 0.02) embeddings, Glorot dense, unit LN).
    pub fn new(arch: TfmArch, classes: usize, seed: u64) -> Self {
        let (v, l, d, _h, layers, f) = arch.dims();
        let mut rng = Rng::new(seed ^ 0x7F0_7F0);
        let mut normal = |n: usize, s: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * s) as f32).collect()
        };
        let embed = normal(v * d, 0.02);
        let pos = normal(l * d, 0.02);
        let mut rng2 = Rng::new(seed ^ 0x61055);
        let mut glorot = |rows: usize, cols: usize| -> Vec<f32> {
            let lim = (6.0 / (rows + cols) as f64).sqrt();
            (0..rows * cols).map(|_| rng2.range_f64(-lim, lim) as f32).collect()
        };
        let mk_layer = |g: &mut dyn FnMut(usize, usize) -> Vec<f32>| Layer {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            wq: g(d, d),
            bq: vec![0.0; d],
            wk: g(d, d),
            bk: vec![0.0; d],
            wv: g(d, d),
            bv: vec![0.0; d],
            wo: g(d, d),
            bo: vec![0.0; d],
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w1: g(d, f),
            b1: vec![0.0; f],
            w2: g(f, d),
            b2: vec![0.0; d],
        };
        let layers_v = (0..layers).map(|_| mk_layer(&mut glorot)).collect();
        HostTfm {
            arch,
            classes,
            params: Params {
                embed,
                pos,
                layers: layers_v,
                lnf_g: vec![1.0; d],
                lnf_b: vec![0.0; d],
                head_w: glorot(d, classes),
                head_b: vec![0.0; classes],
            },
        }
    }

    /// Load from a flat blob in `param_spec` order (the aot.py init
    /// blob / PJRT interop format).
    pub fn from_flat(arch: TfmArch, classes: usize, flat: &[f32]) -> Self {
        let (v, l, d, _h, layers, f) = arch.dims();
        let mut off = 0usize;
        let mut take = |n: usize| -> Vec<f32> {
            let s = flat[off..off + n].to_vec();
            off += n;
            s
        };
        let embed = take(v * d);
        let pos = take(l * d);
        let layers_v = (0..layers)
            .map(|_| Layer {
                ln1_g: take(d),
                ln1_b: take(d),
                wq: take(d * d),
                bq: take(d),
                wk: take(d * d),
                bk: take(d),
                wv: take(d * d),
                bv: take(d),
                wo: take(d * d),
                bo: take(d),
                ln2_g: take(d),
                ln2_b: take(d),
                w1: take(d * f),
                b1: take(f),
                w2: take(f * d),
                b2: take(d),
            })
            .collect();
        let lnf_g = take(d);
        let lnf_b = take(d);
        let head_w = take(d * classes);
        let head_b = take(classes);
        assert_eq!(off, flat.len(), "flat blob size mismatch");
        HostTfm {
            arch,
            classes,
            params: Params { embed, pos, layers: layers_v, lnf_g, lnf_b, head_w, head_b },
        }
    }

    /// Snapshot parameters as one flat blob (`param_spec` order).
    pub fn to_flat(&self) -> Vec<f32> {
        let p = &self.params;
        let mut v = Vec::new();
        v.extend_from_slice(&p.embed);
        v.extend_from_slice(&p.pos);
        for lay in &p.layers {
            for s in [
                &lay.ln1_g, &lay.ln1_b, &lay.wq, &lay.bq, &lay.wk, &lay.bk, &lay.wv,
                &lay.bv, &lay.wo, &lay.bo, &lay.ln2_g, &lay.ln2_b, &lay.w1, &lay.b1,
                &lay.w2, &lay.b2,
            ] {
                v.extend_from_slice(s);
            }
        }
        v.extend_from_slice(&p.lnf_g);
        v.extend_from_slice(&p.lnf_b);
        v.extend_from_slice(&p.head_w);
        v.extend_from_slice(&p.head_b);
        v
    }

    /// Flat-blob length for an `(arch, classes)` model (`param_spec`
    /// order — embed, pos, per-layer tensors, final LN, head).
    pub fn flat_len(arch: TfmArch, classes: usize) -> usize {
        let (v, l, d, _h, layers, f) = arch.dims();
        let per_layer = 2 * d + 4 * (d * d + d) + 2 * d + d * f + f + f * d + d;
        v * d + l * d + layers * per_layer + 2 * d + d * classes + classes
    }

    /// Restore parameters in place from a [`HostTfm::to_flat`] blob
    /// (warm respawn / snapshot install).
    pub fn load_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), Self::flat_len(self.arch, self.classes));
        let mut off = 0usize;
        let mut fill = |dst: &mut [f32]| {
            dst.copy_from_slice(&flat[off..off + dst.len()]);
            off += dst.len();
        };
        let p = &mut self.params;
        fill(&mut p.embed);
        fill(&mut p.pos);
        for lay in &mut p.layers {
            fill(&mut lay.ln1_g);
            fill(&mut lay.ln1_b);
            fill(&mut lay.wq);
            fill(&mut lay.bq);
            fill(&mut lay.wk);
            fill(&mut lay.bk);
            fill(&mut lay.wv);
            fill(&mut lay.bv);
            fill(&mut lay.wo);
            fill(&mut lay.bo);
            fill(&mut lay.ln2_g);
            fill(&mut lay.ln2_b);
            fill(&mut lay.w1);
            fill(&mut lay.b1);
            fill(&mut lay.w2);
            fill(&mut lay.b2);
        }
        fill(&mut p.lnf_g);
        fill(&mut p.lnf_b);
        fill(&mut p.head_w);
        fill(&mut p.head_b);
        drop(fill);
        assert_eq!(off, flat.len());
    }

    /// Architecture.
    pub fn arch(&self) -> TfmArch {
        self.arch
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Class probabilities for one sequence.
    ///
    /// Reference per-sample path: runs the cache-building [`forward`]
    /// (per-call allocation, sparse matmuls). The serve/cascade hot
    /// paths go through [`HostTfm::predict_batch_into`] instead; this
    /// stays as the parity anchor the property tests and the
    /// `bench_kernels` speedup gate compare against.
    ///
    /// [`forward`]: HostTfm::forward
    pub fn predict(&self, ids: &[i32], mask: &[f32]) -> Vec<f32> {
        self.forward(ids, mask).probs
    }

    /// Batched class probabilities: all `B` sequences fused into one
    /// `[B·L, d]` activation stream so each layer's LayerNorm, Q/K/V/O
    /// and FFN projections are a single dense matmul instead of `B`
    /// small ones (attention stays per-sequence, per-head). Writes
    /// `[B, classes]` row-major probabilities into `out`.
    ///
    /// Bit-for-bit identical to calling [`HostTfm::predict`] per
    /// sequence: rows of every fused matmul are independent and keep
    /// the per-row ascending-k accumulation order, and the dense
    /// kernels match the sparse ones bitwise (see
    /// [`tensor::matmul_dense`](t::matmul_dense)). Steady-state calls
    /// at a stable batch size do zero heap allocation.
    pub fn predict_batch_into(
        &self,
        ids: &[&[i32]],
        masks: &[&[f32]],
        scratch: &mut Scratch,
        out: &mut [f32],
    ) {
        let (_vocab, l, d, heads, _nlayers, f) = self.arch.dims();
        let b = ids.len();
        assert_eq!(masks.len(), b);
        assert_eq!(out.len(), b * self.classes);
        if b == 0 {
            return;
        }
        let dh = d / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let p = &self.params;
        scratch.ensure(b, l, d, dh, f);
        let bl = b * l;
        let x = &mut scratch.x[..bl * d];
        let hx = &mut scratch.hx[..bl * d];
        let stats = &mut scratch.stats[..2 * bl];
        let q = &mut scratch.q[..bl * d];
        let k = &mut scratch.k[..bl * d];
        let v = &mut scratch.v[..bl * d];
        let o = &mut scratch.o[..bl * d];
        let proj = &mut scratch.proj[..bl * d];
        let pre = &mut scratch.pre[..bl * f];
        let act = &mut scratch.act[..bl * f];
        let qh = &mut scratch.qh[..l * dh];
        let kh = &mut scratch.kh[..l * dh];
        let vh = &mut scratch.vh[..l * dh];
        let oh = &mut scratch.oh[..l * dh];
        let s = &mut scratch.s[..l * l];
        let pooled = &mut scratch.pooled[..b * d];

        // token + position embeddings, per sequence
        for (si, seq) in ids.iter().enumerate() {
            debug_assert_eq!(seq.len(), l);
            let base = si * l * d;
            for i in 0..l {
                let row = (seq[i] as usize) * d;
                for j in 0..d {
                    x[base + i * d + j] = p.embed[row + j] + p.pos[i * d + j];
                }
            }
        }

        for lay in &p.layers {
            // --- attention block (pre-LN), projections fused over B·L ---
            t::layernorm(x, &lay.ln1_g, &lay.ln1_b, hx, Some(stats), bl, d, 1e-5);
            t::linear_dense(hx, &lay.wq, &lay.bq, q, bl, d, d);
            t::linear_dense(hx, &lay.wk, &lay.bk, k, bl, d, d);
            t::linear_dense(hx, &lay.wv, &lay.bv, v, bl, d, d);
            for si in 0..b {
                let base = si * l * d;
                let mask = masks[si];
                debug_assert_eq!(mask.len(), l);
                for h in 0..heads {
                    let c0 = h * dh;
                    for i in 0..l {
                        qh[i * dh..(i + 1) * dh]
                            .copy_from_slice(&q[base + i * d + c0..base + i * d + c0 + dh]);
                        kh[i * dh..(i + 1) * dh]
                            .copy_from_slice(&k[base + i * d + c0..base + i * d + c0 + dh]);
                        vh[i * dh..(i + 1) * dh]
                            .copy_from_slice(&v[base + i * d + c0..base + i * d + c0 + dh]);
                    }
                    // scores = q @ k^T * scale + mask bias
                    t::matmul_a_bt(qh, kh, s, l, dh, l);
                    for i in 0..l {
                        for j in 0..l {
                            s[i * l + j] = s[i * l + j] * scale + (1.0 - mask[j]) * -1e9;
                        }
                    }
                    t::softmax_rows(s, l, l);
                    // context keeps the sparse kernel: masked columns of
                    // the prob matrix are exactly 0.0 and skip whole rows
                    t::matmul(s, vh, oh, l, l, dh);
                    for i in 0..l {
                        o[base + i * d + c0..base + i * d + c0 + dh]
                            .copy_from_slice(&oh[i * dh..(i + 1) * dh]);
                    }
                }
            }
            // x = x + o @ wo + bo, fused over B·L
            t::linear_dense(o, &lay.wo, &lay.bo, proj, bl, d, d);
            for (xv, pv) in x.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }
            // --- FFN block (pre-LN), fused over B·L ---
            t::layernorm(x, &lay.ln2_g, &lay.ln2_b, hx, Some(stats), bl, d, 1e-5);
            t::linear_dense(hx, &lay.w1, &lay.b1, pre, bl, d, f);
            for (av, &pv) in act.iter_mut().zip(pre.iter()) {
                *av = t::gelu(pv);
            }
            t::linear_dense(act, &lay.w2, &lay.b2, proj, bl, f, d);
            for (xv, ov) in x.iter_mut().zip(proj.iter()) {
                *xv += ov;
            }
        }
        t::layernorm(x, &p.lnf_g, &p.lnf_b, hx, Some(stats), bl, d, 1e-5);
        // masked mean pooling, per sequence (same j-outer/i-inner
        // accumulation order as the per-sample path)
        for (si, mask) in masks.iter().enumerate() {
            let base = si * l * d;
            let mask_sum = mask.iter().sum::<f32>().max(1.0);
            for j in 0..d {
                let mut acc = 0.0;
                for i in 0..l {
                    acc += hx[base + i * d + j] * mask[i];
                }
                pooled[si * d + j] = acc / mask_sum;
            }
        }
        // head over the pooled [B, d] block in one matmul
        t::linear_dense(pooled, &p.head_w, &p.head_b, out, b, d, self.classes);
        t::softmax_rows(out, b, self.classes);
    }

    fn forward(&self, ids: &[i32], mask: &[f32]) -> Cache {
        let (_v, l, d, heads, nlayers, f) = self.arch.dims();
        debug_assert_eq!(ids.len(), l);
        debug_assert_eq!(mask.len(), l);
        let dh = d / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let p = &self.params;

        let mut x = vec![0.0f32; l * d];
        for i in 0..l {
            let row = (ids[i] as usize) * d;
            for j in 0..d {
                x[i * d + j] = p.embed[row + j] + p.pos[i * d + j];
            }
        }
        let mut cache = Cache {
            x_in: Vec::with_capacity(nlayers),
            hx1: Vec::with_capacity(nlayers),
            ln1_stats: Vec::with_capacity(nlayers),
            q: Vec::with_capacity(nlayers),
            k: Vec::with_capacity(nlayers),
            v: Vec::with_capacity(nlayers),
            p: Vec::with_capacity(nlayers),
            o: Vec::with_capacity(nlayers),
            x_mid: Vec::with_capacity(nlayers),
            hx2: Vec::with_capacity(nlayers),
            ln2_stats: Vec::with_capacity(nlayers),
            ffn_pre: Vec::with_capacity(nlayers),
            ffn_act: Vec::with_capacity(nlayers),
            x_final: Vec::new(),
            lnf_out: vec![0.0; l * d],
            lnf_stats: vec![0.0; 2 * l],
            pooled: vec![0.0; d],
            probs: Vec::new(),
            mask_sum: mask.iter().sum::<f32>().max(1.0),
        };

        for lay in &p.layers {
            cache.x_in.push(x.clone());
            // --- attention block (pre-LN) ---
            let mut hx = vec![0.0f32; l * d];
            let mut stats = vec![0.0f32; 2 * l];
            t::layernorm(&x, &lay.ln1_g, &lay.ln1_b, &mut hx, Some(&mut stats), l, d, 1e-5);
            let mut q = vec![0.0f32; l * d];
            let mut k = vec![0.0f32; l * d];
            let mut v = vec![0.0f32; l * d];
            t::linear(&hx, &lay.wq, &lay.bq, &mut q, l, d, d);
            t::linear(&hx, &lay.wk, &lay.bk, &mut k, l, d, d);
            t::linear(&hx, &lay.wv, &lay.bv, &mut v, l, d, d);
            let mut o = vec![0.0f32; l * d];
            let mut probs_heads = Vec::with_capacity(heads);
            // Per-head panels are gathered into contiguous [L, dh]
            // buffers so the score/context products run through the
            // vectorized matmul primitives instead of strided loops
            // (§Perf iteration 1: 2.3x on the forward pass).
            let mut qh = vec![0.0f32; l * dh];
            let mut kh = vec![0.0f32; l * dh];
            let mut vh = vec![0.0f32; l * dh];
            let mut oh = vec![0.0f32; l * dh];
            for h in 0..heads {
                let c0 = h * dh;
                for i in 0..l {
                    qh[i * dh..(i + 1) * dh]
                        .copy_from_slice(&q[i * d + c0..i * d + c0 + dh]);
                    kh[i * dh..(i + 1) * dh]
                        .copy_from_slice(&k[i * d + c0..i * d + c0 + dh]);
                    vh[i * dh..(i + 1) * dh]
                        .copy_from_slice(&v[i * d + c0..i * d + c0 + dh]);
                }
                // scores = q @ k^T * scale + mask bias
                let mut s = vec![0.0f32; l * l];
                t::matmul_a_bt(&qh, &kh, &mut s, l, dh, l);
                for i in 0..l {
                    for j in 0..l {
                        s[i * l + j] = s[i * l + j] * scale + (1.0 - mask[j]) * -1e9;
                    }
                }
                t::softmax_rows(&mut s, l, l);
                t::matmul(&s, &vh, &mut oh, l, l, dh);
                for i in 0..l {
                    o[i * d + c0..i * d + c0 + dh]
                        .copy_from_slice(&oh[i * dh..(i + 1) * dh]);
                }
                probs_heads.push(s);
            }
            // x = x + o @ wo + bo
            let mut proj = vec![0.0f32; l * d];
            t::linear(&o, &lay.wo, &lay.bo, &mut proj, l, d, d);
            for (xv, pv) in x.iter_mut().zip(&proj) {
                *xv += pv;
            }
            cache.hx1.push(hx);
            cache.ln1_stats.push(stats);
            cache.q.push(q);
            cache.k.push(k);
            cache.v.push(v);
            cache.p.push(probs_heads);
            cache.o.push(o);
            cache.x_mid.push(x.clone());
            // --- FFN block (pre-LN) ---
            let mut hx2 = vec![0.0f32; l * d];
            let mut stats2 = vec![0.0f32; 2 * l];
            t::layernorm(&x, &lay.ln2_g, &lay.ln2_b, &mut hx2, Some(&mut stats2), l, d, 1e-5);
            let mut pre = vec![0.0f32; l * f];
            t::linear(&hx2, &lay.w1, &lay.b1, &mut pre, l, d, f);
            let act: Vec<f32> = pre.iter().map(|&z| t::gelu(z)).collect();
            let mut out = vec![0.0f32; l * d];
            t::linear(&act, &lay.w2, &lay.b2, &mut out, l, f, d);
            for (xv, ov) in x.iter_mut().zip(&out) {
                *xv += ov;
            }
            cache.hx2.push(hx2);
            cache.ln2_stats.push(stats2);
            cache.ffn_pre.push(pre);
            cache.ffn_act.push(act);
        }
        cache.x_final = x.clone();
        t::layernorm(
            &x,
            &p.lnf_g,
            &p.lnf_b,
            &mut cache.lnf_out,
            Some(&mut cache.lnf_stats),
            l,
            d,
            1e-5,
        );
        // masked mean pooling
        for j in 0..d {
            let mut acc = 0.0;
            for i in 0..l {
                acc += cache.lnf_out[i * d + j] * mask[i];
            }
            cache.pooled[j] = acc / cache.mask_sum;
        }
        // head
        let mut logits = vec![0.0f32; self.classes];
        t::linear(&cache.pooled, &p.head_w, &p.head_b, &mut logits, 1, d, self.classes);
        t::softmax_rows(&mut logits, 1, self.classes);
        cache.probs = logits;
        cache
    }

    /// One OGD minibatch step (cross-entropy, global-norm clip at 1.0);
    /// returns the mean loss over the batch.
    pub fn train_batch(
        &mut self,
        ids: &[&[i32]],
        masks: &[&[f32]],
        ys: &[usize],
        lr: f32,
    ) -> f32 {
        assert_eq!(ids.len(), ys.len());
        assert!(!ids.is_empty());
        let (_v, l, d, heads, _n, f) = self.arch.dims();
        let dh = d / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let bsz = ids.len() as f32;
        let p = &self.params;

        // gradient accumulators
        let mut g_embed = vec![0.0f32; p.embed.len()];
        let mut g_pos = vec![0.0f32; p.pos.len()];
        let mut g_layers: Vec<Layer> = p.layers.iter().map(|x| x.zeros_like()).collect();
        let mut g_lnf_g = vec![0.0f32; d];
        let mut g_lnf_b = vec![0.0f32; d];
        let mut g_head_w = vec![0.0f32; p.head_w.len()];
        let mut g_head_b = vec![0.0f32; self.classes];
        let mut loss = 0.0f32;

        for bi in 0..ids.len() {
            let cache = self.forward(ids[bi], masks[bi]);
            let y = ys[bi];
            loss -= (cache.probs[y] + 1e-9).ln();
            // dlogits = (probs - onehot)/B
            let mut dpooled = vec![0.0f32; d];
            for c in 0..self.classes {
                let dl = (cache.probs[c] - if c == y { 1.0 } else { 0.0 }) / bsz;
                g_head_b[c] += dl;
                for j in 0..d {
                    g_head_w[j * self.classes + c] += cache.pooled[j] * dl;
                    dpooled[j] += self.params.head_w[j * self.classes + c] * dl;
                }
            }
            // pooling backward
            let mut d_lnf_out = vec![0.0f32; l * d];
            for i in 0..l {
                let m = masks[bi][i] / cache.mask_sum;
                if m == 0.0 {
                    continue;
                }
                for j in 0..d {
                    d_lnf_out[i * d + j] = dpooled[j] * m;
                }
            }
            // final LN backward
            let mut dx = vec![0.0f32; l * d];
            t::layernorm_backward(
                &d_lnf_out,
                &cache.x_final,
                &self.params.lnf_g,
                &cache.lnf_stats,
                &mut dx,
                &mut g_lnf_g,
                &mut g_lnf_b,
                l,
                d,
            );
            // layers in reverse
            for (li, lay) in self.params.layers.iter().enumerate().rev() {
                let gl = &mut g_layers[li];
                // ---- FFN block backward ----
                // x_out = x_mid + gelu(hx2@w1+b1)@w2 + b2
                let act = &cache.ffn_act[li];
                let pre = &cache.ffn_pre[li];
                let hx2 = &cache.hx2[li];
                // d(out) = dx (residual add)
                // dw2 += act^T dx ; db2 += colsum dx ; dact = dx w2^T
                t::matmul_at_b_accum(act, &dx, &mut gl.w2, l, f, d);
                for i in 0..l {
                    for j in 0..d {
                        gl.b2[j] += dx[i * d + j];
                    }
                }
                let mut dact = vec![0.0f32; l * f];
                t::matmul_a_bt(&dx, &lay.w2, &mut dact, l, d, f);
                // gelu backward
                let mut dpre = vec![0.0f32; l * f];
                for i in 0..l * f {
                    dpre[i] = dact[i] * t::gelu_grad(pre[i]);
                }
                // dw1 += hx2^T dpre ; db1 += colsum ; dhx2 = dpre w1^T
                t::matmul_at_b_accum(hx2, &dpre, &mut gl.w1, l, d, f);
                for i in 0..l {
                    for j in 0..f {
                        gl.b1[j] += dpre[i * f + j];
                    }
                }
                let mut dhx2 = vec![0.0f32; l * d];
                t::matmul_a_bt(&dpre, &lay.w1, &mut dhx2, l, f, d);
                // LN2 backward adds into dx (residual skip keeps dx too)
                let mut dx_mid = vec![0.0f32; l * d];
                t::layernorm_backward(
                    &dhx2,
                    &cache.x_mid[li],
                    &lay.ln2_g,
                    &cache.ln2_stats[li],
                    &mut dx_mid,
                    &mut gl.ln2_g,
                    &mut gl.ln2_b,
                    l,
                    d,
                );
                for i in 0..l * d {
                    dx[i] += dx_mid[i];
                }
                // ---- attention block backward ----
                // x_mid = x_in + o @ wo + bo
                let o = &cache.o[li];
                t::matmul_at_b_accum(o, &dx, &mut gl.wo, l, d, d);
                for i in 0..l {
                    for j in 0..d {
                        gl.bo[j] += dx[i * d + j];
                    }
                }
                let mut do_ = vec![0.0f32; l * d];
                t::matmul_a_bt(&dx, &lay.wo, &mut do_, l, d, d);
                // attention core backward per head
                let (q, k, v) = (&cache.q[li], &cache.k[li], &cache.v[li]);
                let mut dq = vec![0.0f32; l * d];
                let mut dk = vec![0.0f32; l * d];
                let mut dv = vec![0.0f32; l * d];
                for h in 0..heads {
                    let c0 = h * dh;
                    let pm = &cache.p[li][h]; // [L, L]
                    // dp = do v^T (head slice)
                    let mut dp = vec![0.0f32; l * l];
                    for i in 0..l {
                        for j in 0..l {
                            let mut acc = 0.0;
                            for e in 0..dh {
                                acc += do_[i * d + c0 + e] * v[j * d + c0 + e];
                            }
                            dp[i * l + j] = acc;
                        }
                    }
                    // dv += p^T do
                    for j in 0..l {
                        for e in 0..dh {
                            let mut acc = 0.0;
                            for i in 0..l {
                                acc += pm[i * l + j] * do_[i * d + c0 + e];
                            }
                            dv[j * d + c0 + e] += acc;
                        }
                    }
                    // softmax backward: ds = p * (dp - rowsum(dp*p))
                    let mut ds = vec![0.0f32; l * l];
                    for i in 0..l {
                        let mut rowsum = 0.0;
                        for j in 0..l {
                            rowsum += dp[i * l + j] * pm[i * l + j];
                        }
                        for j in 0..l {
                            ds[i * l + j] = pm[i * l + j] * (dp[i * l + j] - rowsum);
                        }
                    }
                    // dq += ds k * scale ; dk += ds^T q * scale
                    for i in 0..l {
                        for e in 0..dh {
                            let mut acc = 0.0;
                            for j in 0..l {
                                acc += ds[i * l + j] * k[j * d + c0 + e];
                            }
                            dq[i * d + c0 + e] += acc * scale;
                        }
                    }
                    for j in 0..l {
                        for e in 0..dh {
                            let mut acc = 0.0;
                            for i in 0..l {
                                acc += ds[i * l + j] * q[i * d + c0 + e];
                            }
                            dk[j * d + c0 + e] += acc * scale;
                        }
                    }
                }
                // qkv linear backwards into dhx1
                let hx1 = &cache.hx1[li];
                let mut dhx1 = vec![0.0f32; l * d];
                for (dm, w, gw, gb) in [
                    (&dq, &lay.wq, &mut gl.wq, &mut gl.bq),
                    (&dk, &lay.wk, &mut gl.wk, &mut gl.bk),
                    (&dv, &lay.wv, &mut gl.wv, &mut gl.bv),
                ] {
                    t::matmul_at_b_accum(hx1, dm, gw, l, d, d);
                    for i in 0..l {
                        for j in 0..d {
                            gb[j] += dm[i * d + j];
                        }
                    }
                    let mut tmp = vec![0.0f32; l * d];
                    t::matmul_a_bt(dm, w, &mut tmp, l, d, d);
                    for i in 0..l * d {
                        dhx1[i] += tmp[i];
                    }
                }
                // LN1 backward adds into dx
                let mut dx_in = vec![0.0f32; l * d];
                t::layernorm_backward(
                    &dhx1,
                    &cache.x_in[li],
                    &lay.ln1_g,
                    &cache.ln1_stats[li],
                    &mut dx_in,
                    &mut gl.ln1_g,
                    &mut gl.ln1_b,
                    l,
                    d,
                );
                for i in 0..l * d {
                    dx[i] += dx_in[i];
                }
            }
            // embeddings backward
            for i in 0..l {
                let row = (ids[bi][i] as usize) * d;
                for j in 0..d {
                    g_embed[row + j] += dx[i * d + j];
                    g_pos[i * d + j] += dx[i * d + j];
                }
            }
        }

        // global-norm clip + SGD (matches make_step)
        let mut sq = 0.0f64;
        {
            let mut add = |g: &[f32]| {
                for &x in g {
                    sq += (x as f64) * (x as f64);
                }
            };
            add(&g_embed);
            add(&g_pos);
            for gl in &g_layers {
                for s in [
                    &gl.ln1_g, &gl.ln1_b, &gl.wq, &gl.bq, &gl.wk, &gl.bk, &gl.wv, &gl.bv,
                    &gl.wo, &gl.bo, &gl.ln2_g, &gl.ln2_b, &gl.w1, &gl.b1, &gl.w2, &gl.b2,
                ] {
                    add(s);
                }
            }
            add(&g_lnf_g);
            add(&g_lnf_b);
            add(&g_head_w);
            add(&g_head_b);
        }
        let gnorm = (sq + 1e-12).sqrt();
        let clip = (1.0f64.min(1.0 / gnorm)) as f32;
        let step = lr * clip;
        let apply = |p: &mut [f32], g: &[f32]| {
            for (pv, &gv) in p.iter_mut().zip(g) {
                *pv -= step * gv;
            }
        };
        let pm = &mut self.params;
        apply(&mut pm.embed, &g_embed);
        apply(&mut pm.pos, &g_pos);
        for (lay, gl) in pm.layers.iter_mut().zip(&g_layers) {
            apply(&mut lay.ln1_g, &gl.ln1_g);
            apply(&mut lay.ln1_b, &gl.ln1_b);
            apply(&mut lay.wq, &gl.wq);
            apply(&mut lay.bq, &gl.bq);
            apply(&mut lay.wk, &gl.wk);
            apply(&mut lay.bk, &gl.bk);
            apply(&mut lay.wv, &gl.wv);
            apply(&mut lay.bv, &gl.bv);
            apply(&mut lay.wo, &gl.wo);
            apply(&mut lay.bo, &gl.bo);
            apply(&mut lay.ln2_g, &gl.ln2_g);
            apply(&mut lay.ln2_b, &gl.ln2_b);
            apply(&mut lay.w1, &gl.w1);
            apply(&mut lay.b1, &gl.b1);
            apply(&mut lay.w2, &gl.w2);
            apply(&mut lay.b2, &gl.b2);
        }
        apply(&mut pm.lnf_g, &g_lnf_g);
        apply(&mut pm.lnf_b, &g_lnf_b);
        apply(&mut pm.head_w, &g_head_w);
        apply(&mut pm.head_b, &g_head_b);
        loss / bsz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(rng: &mut Rng, l: usize) -> (Vec<i32>, Vec<f32>) {
        let n = 5 + rng.below(l - 5);
        let ids: Vec<i32> =
            (0..l).map(|i| if i < n { 2 + rng.below(8000) as i32 } else { 0 }).collect();
        let mask: Vec<f32> = (0..l).map(|i| if i < n { 1.0 } else { 0.0 }).collect();
        (ids, mask)
    }

    #[test]
    fn forward_is_simplex() {
        let m = HostTfm::new(TfmArch::Base, 7, 0);
        let mut rng = Rng::new(1);
        let (ids, mask) = doc(&mut rng, 64);
        let p = m.predict(&ids, &mask);
        assert_eq!(p.len(), 7);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn padding_tokens_do_not_change_output() {
        let m = HostTfm::new(TfmArch::Base, 2, 0);
        let mut rng = Rng::new(2);
        let (mut ids, mask) = doc(&mut rng, 64);
        let p1 = m.predict(&ids, &mask);
        for i in 0..64 {
            if mask[i] == 0.0 {
                ids[i] = 2 + rng.below(8000) as i32;
            }
        }
        let p2 = m.predict(&ids, &mask);
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_forward_matches_per_sample_bitwise() {
        let m = HostTfm::new(TfmArch::Base, 3, 11);
        let mut rng = Rng::new(12);
        let docs: Vec<(Vec<i32>, Vec<f32>)> = (0..5).map(|_| doc(&mut rng, 64)).collect();
        let ids: Vec<&[i32]> = docs.iter().map(|d| d.0.as_slice()).collect();
        let masks: Vec<&[f32]> = docs.iter().map(|d| d.1.as_slice()).collect();
        let mut scratch = Scratch::new();
        // odd batch size (remainder vs any internal tiling), then reuse
        // the same scratch at a different size
        for b in [5usize, 2, 1] {
            let mut out = vec![0.0f32; b * 3];
            m.predict_batch_into(&ids[..b], &masks[..b], &mut scratch, &mut out);
            for (si, (i, ma)) in ids[..b].iter().zip(&masks[..b]).enumerate() {
                let want = m.predict(i, ma);
                for (c, w) in want.iter().enumerate() {
                    assert_eq!(
                        out[si * 3 + c].to_bits(),
                        w.to_bits(),
                        "b={b} seq={si} class={c}: batched {} per-sample {w}",
                        out[si * 3 + c]
                    );
                }
            }
        }
    }

    #[test]
    fn flat_roundtrip_preserves_forward() {
        let m = HostTfm::new(TfmArch::Base, 2, 3);
        let flat = m.to_flat();
        let m2 = HostTfm::from_flat(TfmArch::Base, 2, &flat);
        let mut rng = Rng::new(4);
        let (ids, mask) = doc(&mut rng, 64);
        assert_eq!(m.predict(&ids, &mask), m2.predict(&ids, &mask));
    }

    #[test]
    fn flat_blob_size_matches_spec() {
        // base: embed 8192*64 + pos 64*64 + 2 layers * (2d+4(dd+d)+2d+df+f+fd+d)
        //       + 2d + d*2 + 2
        let m = HostTfm::new(TfmArch::Base, 2, 0);
        let d = 64;
        let f = 256;
        let per_layer = 2 * d + 4 * (d * d + d) + 2 * d + d * f + f + f * d + d;
        let want = 8192 * d + 64 * d + 2 * per_layer + 2 * d + d * 2 + 2;
        assert_eq!(m.to_flat().len(), want);
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        let mut m = HostTfm::new(TfmArch::Base, 2, 5);
        let mut rng = Rng::new(6);
        let docs: Vec<(Vec<i32>, Vec<f32>)> = (0..8).map(|_| doc(&mut rng, 64)).collect();
        let ids: Vec<&[i32]> = docs.iter().map(|d| d.0.as_slice()).collect();
        let masks: Vec<&[f32]> = docs.iter().map(|d| d.1.as_slice()).collect();
        let ys: Vec<usize> = (0..8).map(|_| rng.below(2)).collect();
        let l0 = m.train_batch(&ids, &masks, &ys, 5e-3);
        let mut l = l0;
        for _ in 0..8 {
            l = m.train_batch(&ids, &masks, &ys, 5e-3);
        }
        assert!(l < l0, "{l} !< {l0}");
    }

    #[test]
    fn learns_order_sensitive_rule() {
        // The medium stratum's core claim (text::Stratum::Medium): the
        // label is XOR(keyword class, flip-marker present) — a pattern
        // linear bag-of-words provably cannot represent, but the
        // transformer's attention+FFN nonlinearity can.
        let mut m = HostTfm::new(TfmArch::Base, 2, 7);
        let mut rng = Rng::new(8);
        let kw = [100i32, 101]; // keyword token per apparent class
        let marker = 200i32;
        let mk = |rng: &mut Rng, y: usize| -> (Vec<i32>, Vec<f32>) {
            let l = 64;
            let mut ids: Vec<i32> =
                (0..l).map(|_| 2 + rng.below(50) as i32 + 300).collect();
            let mask = vec![1.0f32; l];
            let flip = rng.below(2); // marker present?
            let apparent = (y + flip) % 2; // label = apparent XOR flip
            for _ in 0..4 {
                ids[rng.below(l)] = kw[apparent];
            }
            if flip == 1 {
                for _ in 0..3 {
                    ids[rng.below(l)] = marker;
                }
            }
            (ids, mask)
        };
        for _ in 0..400 {
            let batch: Vec<(Vec<i32>, Vec<f32>, usize)> = (0..8)
                .map(|_| {
                    let y = rng.below(2);
                    let (i, ma) = mk(&mut rng, y);
                    (i, ma, y)
                })
                .collect();
            let ids: Vec<&[i32]> = batch.iter().map(|x| x.0.as_slice()).collect();
            let masks: Vec<&[f32]> = batch.iter().map(|x| x.1.as_slice()).collect();
            let ys: Vec<usize> = batch.iter().map(|x| x.2).collect();
            m.train_batch(&ids, &masks, &ys, 2e-2);
        }
        let mut correct = 0;
        for _ in 0..100 {
            let y = rng.below(2);
            let (ids, mask) = mk(&mut rng, y);
            if crate::util::argmax(&m.predict(&ids, &mask)) == y {
                correct += 1;
            }
        }
        assert!(correct >= 70, "correct={correct}/100");
    }

    #[test]
    fn gradcheck_embedding_path() {
        // Finite-difference check of the full backward through one
        // embedding entry (covers the whole chain end-to-end).
        let mut m = HostTfm::new(TfmArch::Base, 2, 9);
        let mut rng = Rng::new(10);
        let (ids, mask) = doc(&mut rng, 64);
        let y = 1usize;
        // numeric dloss/dembed for the first token's first dim
        let tok = ids[0] as usize;
        let loss_of = |m: &HostTfm| -> f32 {
            let p = m.predict(&ids, &mask);
            -(p[y] + 1e-9).ln()
        };
        // Numeric grads at two coordinates; the analytic step applies
        // `clip * grad` with a shared (unknown) clip factor, so the
        // *ratios* across coordinates must agree.
        let num_grad = |coord: usize| -> f32 {
            let h = 1e-2f32;
            let mut mp = m.clone();
            mp.params.embed[coord] += h;
            let mut mm = m.clone();
            mm.params.embed[coord] -= h;
            (loss_of(&mp) - loss_of(&mm)) / (2.0 * h)
        };
        let c1 = tok * 64;
        let c2 = tok * 64 + 7;
        let (n1, n2) = (num_grad(c1), num_grad(c2));
        let (b1, b2) = (m.params.embed[c1], m.params.embed[c2]);
        let lr = 1e-4f32;
        let ids_b = [ids.as_slice()];
        let masks_b = [mask.as_slice()];
        m.train_batch(&ids_b, &masks_b, &[y], lr);
        let g1 = (b1 - m.params.embed[c1]) / lr; // clip * grad1
        let g2 = (b2 - m.params.embed[c2]) / lr; // clip * grad2
        assert!(n1.abs() > 1e-4 && n2.abs() > 1e-4, "degenerate test point");
        let analytic_ratio = g1 / g2;
        let numeric_ratio = n1 / n2;
        assert!(
            (analytic_ratio - numeric_ratio).abs()
                / numeric_ratio.abs().max(1e-3)
                < 0.08,
            "ratios diverge: analytic {analytic_ratio} numeric {numeric_ratio}"
        );
        // and the shared clip factor must be identical in (0, 1]
        let clip1 = g1 / n1;
        let clip2 = g2 / n2;
        assert!(clip1 > 0.0 && clip1 <= 1.05, "clip {clip1}");
        assert!((clip1 - clip2).abs() / clip1 < 0.08, "{clip1} vs {clip2}");
    }
}
