//! Text featurization substrate: tokenizer, hashing vectorizer (LR
//! input) and vocabulary indexer (transformer input).
//!
//! Both featurizers are *stateless hash functions* of the token string,
//! so the rust runtime, the host-engine mirrors, and the AOT artifacts
//! all see identical inputs with zero fitting/vocab files. Hot-path
//! methods write into caller-provided buffers — no allocation per query
//! (DESIGN.md §9 L3 target).

use crate::config::dims::{HASH_DIM, SEQ_LEN, VOCAB};

/// FNV-1a 64-bit hash of a byte string.
#[inline]
pub fn fnv1a(bytes: &[u8], seed: u64) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64 ^ seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01B3);
    }
    h
}

/// Iterate whitespace-separated, lowercased, alphanumeric-trimmed tokens.
pub fn tokenize(text: &str) -> impl Iterator<Item = &str> {
    text.split_whitespace().filter_map(|t| {
        let t = t.trim_matches(|c: char| !c.is_alphanumeric());
        if t.is_empty() {
            None
        } else {
            Some(t)
        }
    })
}

/// Hashing bag-of-words vectorizer producing the LR input.
#[derive(Clone, Debug)]
pub struct HashingVectorizer {
    dim: usize,
    seed: u64,
}

impl Default for HashingVectorizer {
    fn default() -> Self {
        HashingVectorizer { dim: HASH_DIM, seed: 0x5EED_F00D }
    }
}

impl HashingVectorizer {
    /// Custom dimension/seed (tests).
    pub fn new(dim: usize, seed: u64) -> Self {
        HashingVectorizer { dim, seed }
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Vectorize into `out` (len == dim): L2-normalized token counts
    /// with signed hashing (sign bit decorrelates collisions). No
    /// allocation.
    pub fn vectorize_into(&self, text: &str, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        out.fill(0.0);
        let mut n = 0usize;
        for tok in tokenize(text) {
            let h = fnv1a(tok.as_bytes(), self.seed);
            let idx = (h % self.dim as u64) as usize;
            let sign = if (h >> 63) == 0 { 1.0 } else { -1.0 };
            out[idx] += sign;
            n += 1;
        }
        if n == 0 {
            return;
        }
        let norm: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for x in out.iter_mut() {
                *x /= norm;
            }
        }
    }

    /// Allocating convenience wrapper.
    pub fn vectorize(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0.0; self.dim];
        self.vectorize_into(text, &mut v);
        v
    }
}

/// Vocabulary indexer producing the transformer input: token ids via
/// hashing into `[2, vocab)` (0 = PAD, 1 = OOV-reserved), truncated or
/// padded to `seq_len`, plus the f32 padding mask.
#[derive(Clone, Debug)]
pub struct VocabIndexer {
    vocab: usize,
    seq_len: usize,
    seed: u64,
}

impl Default for VocabIndexer {
    fn default() -> Self {
        VocabIndexer { vocab: VOCAB, seq_len: SEQ_LEN, seed: 0xB0CA_B1E5 }
    }
}

impl VocabIndexer {
    /// Custom sizes (tests).
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> Self {
        VocabIndexer { vocab, seq_len, seed }
    }

    /// Sequence length produced.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Index into caller buffers (`ids`/`mask` len == seq_len). No
    /// allocation. Returns the number of real (unpadded) tokens.
    pub fn index_into(&self, text: &str, ids: &mut [i32], mask: &mut [f32]) -> usize {
        debug_assert_eq!(ids.len(), self.seq_len);
        debug_assert_eq!(mask.len(), self.seq_len);
        let mut n = 0usize;
        for tok in tokenize(text) {
            if n == self.seq_len {
                break;
            }
            let h = fnv1a(tok.as_bytes(), self.seed);
            ids[n] = (2 + (h % (self.vocab as u64 - 2))) as i32;
            mask[n] = 1.0;
            n += 1;
        }
        for i in n..self.seq_len {
            ids[i] = 0;
            mask[i] = 0.0;
        }
        n
    }

    /// Allocating convenience wrapper: (ids, mask, real_len).
    pub fn index(&self, text: &str) -> (Vec<i32>, Vec<f32>, usize) {
        let mut ids = vec![0i32; self.seq_len];
        let mut mask = vec![0f32; self.seq_len];
        let n = self.index_into(text, &mut ids, &mut mask);
        (ids, mask, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_basics() {
        let toks: Vec<&str> = tokenize("Hello, world!  foo-bar 42 ").collect();
        assert_eq!(toks, vec!["Hello", "world", "foo-bar", "42"]);
        assert_eq!(tokenize("  ... !!! ").count(), 0);
    }

    #[test]
    fn hashing_is_deterministic_and_normalized() {
        let v = HashingVectorizer::default();
        let a = v.vectorize("kw1x001 kw1x001 c0w0001");
        let b = v.vectorize("kw1x001 kw1x001 c0w0001");
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn different_texts_differ() {
        let v = HashingVectorizer::default();
        assert_ne!(v.vectorize("kw0x001"), v.vectorize("kw1x001"));
    }

    #[test]
    fn empty_text_is_zero_vector() {
        let v = HashingVectorizer::default();
        assert!(v.vectorize("").iter().all(|&x| x == 0.0));
    }

    #[test]
    fn vectorize_into_no_alloc_path_matches() {
        let v = HashingVectorizer::default();
        let mut buf = vec![1.0f32; v.dim()];
        v.vectorize_into("a b c a", &mut buf);
        assert_eq!(buf, v.vectorize("a b c a"));
    }

    #[test]
    fn indexer_pads_and_truncates() {
        let ix = VocabIndexer::new(100, 4, 0);
        let (ids, mask, n) = ix.index("a b");
        assert_eq!(n, 2);
        assert_eq!(&mask, &[1.0, 1.0, 0.0, 0.0]);
        assert_eq!(ids[2], 0);
        assert!(ids[0] >= 2 && ids[0] < 100);

        let (_, mask, n) = ix.index("a b c d e f");
        assert_eq!(n, 4);
        assert_eq!(&mask, &[1.0; 4]);
    }

    #[test]
    fn indexer_ids_stable_per_token() {
        let ix = VocabIndexer::default();
        let (ids1, _, _) = ix.index("tok1 tok2 tok1");
        assert_eq!(ids1[0], ids1[2]);
        assert_ne!(ids1[0], ids1[1]);
    }
}
