//! Cascade level models behind a uniform interface, over either engine.
//!
//! [`LevelModel`] is the coordinator's view of `m_1 .. m_{N-1}`:
//! probability-vector prediction plus an online minibatch update.
//! [`Calibrator`] is the deferral function `f_i`. Each has a host
//! implementation (pure rust) and — behind the `pjrt` cargo feature —
//! a PJRT implementation (AOT HLO through
//! `crate::runtime::engine::PjrtEngine`); the expert `m_N` lives in
//! [`crate::sim::expert`].

use std::rc::Rc;

#[cfg(feature = "pjrt")]
use xla::Literal;

#[cfg(feature = "pjrt")]
use crate::config::dims::BATCH_STEP;
use crate::config::dims::{HASH_DIM, SEQ_LEN, VOCAB};
use crate::config::ModelKind;
use crate::error::{Error, Result};
use crate::features::{HashingVectorizer, VocabIndexer};
use crate::hostmodel::{HostLr, HostMlp, HostTfm, TfmArch, TfmScratch};
#[cfg(feature = "pjrt")]
use crate::runtime::engine::{literal_f32, literal_i32, load_group_literals};
use crate::runtime::PjrtEngine;

/// A query featurized once and shared by every cascade level.
#[derive(Clone, Debug, PartialEq)]
pub struct Featurized {
    /// Hashed bag-of-words (LR input), len = `HASH_DIM`.
    pub x: Vec<f32>,
    /// Token ids (transformer input), len = `SEQ_LEN`.
    pub ids: Vec<i32>,
    /// Padding mask, len = `SEQ_LEN`.
    pub mask: Vec<f32>,
}

impl Featurized {
    /// JSON encoding (checkpoint replay caches). The hashed BoW vector
    /// is stored sparsely as (index, value) pairs — a document touches
    /// a few dozen of the `HASH_DIM` buckets, so the dense form would
    /// be ~100× larger on disk. Bit-for-bit like [`Snapshot`]: every
    /// f32 survives the f64 JSON trip exactly.
    pub fn to_json(&self) -> crate::codec::Json {
        use crate::codec::Json;
        let mut xi = Vec::new();
        let mut xv = Vec::new();
        for (i, &v) in self.x.iter().enumerate() {
            if v != 0.0 {
                xi.push(Json::Num(i as f64));
                xv.push(Json::Num(v as f64));
            }
        }
        Json::obj(vec![
            ("xi", Json::Arr(xi)),
            ("xv", Json::Arr(xv)),
            (
                "ids",
                Json::Arr(self.ids.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("mask", Json::f32_arr(&self.mask)),
        ])
    }

    /// Decode from [`Featurized::to_json`] output.
    pub fn from_json(v: &crate::codec::Json) -> Result<Self> {
        let bad = |what: &str| Error::Ckpt(format!("featurized: bad '{what}'"));
        let xi = v.require("xi")?.as_usize_vec().ok_or_else(|| bad("xi"))?;
        let xv = v.require("xv")?.as_f32_vec().ok_or_else(|| bad("xv"))?;
        if xi.len() != xv.len() {
            return Err(bad("xi/xv length mismatch"));
        }
        let mut x = vec![0.0f32; HASH_DIM];
        for (&i, &val) in xi.iter().zip(xv.iter()) {
            if i >= HASH_DIM {
                return Err(bad("xi index out of range"));
            }
            x[i] = val;
        }
        let ids_arr = v.require("ids")?.as_arr().ok_or_else(|| bad("ids"))?;
        let mut ids = Vec::with_capacity(ids_arr.len());
        for t in ids_arr {
            let id = t.as_f64().ok_or_else(|| bad("ids"))?;
            // A restored cache feeds these straight into embedding-row
            // lookups — an out-of-vocab id must fail here, not panic
            // mid-training after a "successful" restore.
            if id < 0.0 || id >= VOCAB as f64 || id.fract() != 0.0 {
                return Err(bad("ids token out of vocab range"));
            }
            ids.push(id as i32);
        }
        let mask = v.require("mask")?.as_f32_vec().ok_or_else(|| bad("mask"))?;
        if ids.len() != SEQ_LEN || mask.len() != SEQ_LEN {
            return Err(bad("ids/mask length"));
        }
        Ok(Featurized { x, ids, mask })
    }
}

/// Featurization pipeline (tokenize → hash/index).
#[derive(Clone, Debug, Default)]
pub struct Pipeline {
    vectorizer: HashingVectorizer,
    indexer: VocabIndexer,
}

impl Pipeline {
    /// Featurize one document.
    pub fn featurize(&self, text: &str) -> Featurized {
        let x = self.vectorizer.vectorize(text);
        let (ids, mask, _) = self.indexer.index(text);
        Featurized { x, ids, mask }
    }

    /// Featurize into a reused buffer (hot path, no allocation).
    pub fn featurize_into(&self, text: &str, out: &mut Featurized) {
        self.vectorizer.vectorize_into(text, &mut out.x);
        self.indexer.index_into(text, &mut out.ids, &mut out.mask);
    }

    /// An empty, correctly-sized buffer for [`Pipeline::featurize_into`].
    pub fn buffer(&self) -> Featurized {
        Featurized {
            x: vec![0.0; HASH_DIM],
            ids: vec![0; SEQ_LEN],
            mask: vec![0.0; SEQ_LEN],
        }
    }
}

/// A serializable parameter snapshot of one level model or calibrator.
///
/// This is the unit of state that moves between threads (authority →
/// replica installs in `serve::pool`), across respawns (warm restart),
/// and across processes (JSON round-trip). `data` is the model's flat
/// parameter blob in its canonical `to_flat` order; restore is
/// bit-for-bit (`f32` survives the f64 JSON encoding exactly — see
/// [`crate::codec::Json::f32_arr`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    /// What produced the blob: a [`ModelKind::entry_prefix`] for level
    /// models, `"mlp"` for calibrators.
    pub kind: String,
    /// Number of classes the producer was built for.
    pub classes: usize,
    /// Flat parameter blob (canonical `to_flat` order).
    pub data: Vec<f32>,
}

impl Snapshot {
    /// JSON encoding (state files, cross-process restore).
    pub fn to_json(&self) -> crate::codec::Json {
        use crate::codec::Json;
        Json::obj(vec![
            ("kind", Json::Str(self.kind.clone())),
            ("classes", Json::Num(self.classes as f64)),
            ("data", Json::f32_arr(&self.data)),
        ])
    }

    /// Decode from [`Snapshot::to_json`] output.
    pub fn from_json(v: &crate::codec::Json) -> Result<Self> {
        let kind = v
            .require("kind")?
            .as_str()
            .ok_or_else(|| Error::Config("snapshot kind must be a string".into()))?
            .to_string();
        let classes = v
            .require("classes")?
            .as_usize()
            .ok_or_else(|| Error::Config("snapshot classes must be a usize".into()))?;
        let data = v
            .require("data")?
            .as_f32_vec()
            .ok_or_else(|| Error::Config("snapshot data must be numbers".into()))?;
        Ok(Snapshot { kind, classes, data })
    }

    /// Guard a restore target against a foreign snapshot.
    fn check(&self, kind: &str, classes: usize, flat_len: usize) -> Result<()> {
        if self.kind != kind || self.classes != classes || self.data.len() != flat_len {
            return Err(Error::Config(format!(
                "snapshot mismatch: got kind '{}' classes {} len {}, \
                 restore target wants kind '{}' classes {} len {}",
                self.kind,
                self.classes,
                self.data.len(),
                kind,
                classes,
                flat_len
            )));
        }
        Ok(())
    }
}

/// One trainable cascade level (`m_i`, i < N).
pub trait LevelModel {
    /// Which paper model this level instantiates.
    fn kind(&self) -> ModelKind;
    /// Number of classes.
    fn classes(&self) -> usize;
    /// Predictive probability vector for one query.
    fn predict(&mut self, f: &Featurized) -> Vec<f32>;
    /// One OGD minibatch step on (query, label) pairs; returns loss.
    fn train(&mut self, batch: &[(&Featurized, usize)], lr: f32) -> f32;
    /// Batched prediction (default: loop; PJRT overrides with b8).
    fn predict_batch(&mut self, fs: &[&Featurized]) -> Vec<Vec<f32>> {
        fs.iter().map(|f| self.predict(f)).collect()
    }
    /// Export the current parameters (`None` when the backend cannot
    /// serialize its state).
    fn snapshot(&self) -> Option<Snapshot>;
    /// Restore parameters from a snapshot taken on a model of the same
    /// kind/classes (bit-for-bit; errors on a foreign snapshot).
    fn restore(&mut self, snap: &Snapshot) -> Result<()>;
}

/// A deferral function `f_i` (post-hoc confidence calibrator).
pub trait Calibrator {
    /// Deferral score in (0,1) for a probability vector.
    fn score(&mut self, probs: &[f32]) -> f32;
    /// One OGD minibatch step on (probs, z) pairs (Eq. 5); returns loss.
    fn train(&mut self, batch: &[(&[f32], f32)], lr: f32) -> f32;
    /// Export the current parameters (`None` when unsupported).
    fn snapshot(&self) -> Option<Snapshot>;
    /// Restore parameters from a same-shape calibrator snapshot.
    fn restore(&mut self, snap: &Snapshot) -> Result<()>;
}

// ---------------------------------------------------------------------------
// Host engine implementations
// ---------------------------------------------------------------------------

/// Host LR level.
pub struct HostLrLevel {
    inner: HostLr,
    /// Reused `[b, classes]` output buffer for the batched path.
    out: Vec<f32>,
}

impl HostLrLevel {
    /// Zero-initialized LR level.
    pub fn new(classes: usize) -> Self {
        HostLrLevel { inner: HostLr::new(HASH_DIM, classes), out: Vec::new() }
    }
}

impl LevelModel for HostLrLevel {
    fn kind(&self) -> ModelKind {
        ModelKind::Lr
    }
    fn classes(&self) -> usize {
        self.inner.classes()
    }
    fn predict(&mut self, f: &Featurized) -> Vec<f32> {
        self.inner.predict(&f.x)
    }
    fn predict_batch(&mut self, fs: &[&Featurized]) -> Vec<Vec<f32>> {
        let c = self.inner.classes();
        let xs: Vec<&[f32]> = fs.iter().map(|f| f.x.as_slice()).collect();
        self.out.resize(fs.len() * c, 0.0);
        self.inner.predict_batch_into(&xs, &mut self.out[..fs.len() * c]);
        self.out[..fs.len() * c].chunks(c).map(|r| r.to_vec()).collect()
    }
    fn train(&mut self, batch: &[(&Featurized, usize)], lr: f32) -> f32 {
        let xs: Vec<&[f32]> = batch.iter().map(|(f, _)| f.x.as_slice()).collect();
        let ys: Vec<usize> = batch.iter().map(|&(_, y)| y).collect();
        self.inner.train_batch(&xs, &ys, lr)
    }
    fn snapshot(&self) -> Option<Snapshot> {
        Some(Snapshot {
            kind: ModelKind::Lr.entry_prefix().into(),
            classes: self.inner.classes(),
            data: self.inner.to_flat(),
        })
    }
    fn restore(&mut self, snap: &Snapshot) -> Result<()> {
        let classes = self.inner.classes();
        snap.check(
            ModelKind::Lr.entry_prefix(),
            classes,
            HostLr::flat_len(HASH_DIM, classes),
        )?;
        self.inner.load_flat(&snap.data);
        Ok(())
    }
}

/// Host transformer level (base or large).
pub struct HostTfmLevel {
    inner: HostTfm,
    kind: ModelKind,
    /// Reused forward workspace (batched and single-query inference).
    scratch: TfmScratch,
    /// Reused `[b, classes]` output buffer for the batched path.
    out: Vec<f32>,
}

impl HostTfmLevel {
    /// Fresh transformer level with deterministic init.
    pub fn new(kind: ModelKind, classes: usize, seed: u64) -> Self {
        let arch = match kind {
            ModelKind::TfmBase => TfmArch::Base,
            ModelKind::TfmLarge => TfmArch::Large,
            ModelKind::Lr => panic!("use HostLrLevel for LR"),
        };
        HostTfmLevel {
            inner: HostTfm::new(arch, classes, seed),
            kind,
            scratch: TfmScratch::new(),
            out: Vec::new(),
        }
    }

    /// Load from an artifacts init blob (parity with PJRT).
    pub fn from_flat(kind: ModelKind, classes: usize, flat: &[f32]) -> Self {
        let arch = match kind {
            ModelKind::TfmBase => TfmArch::Base,
            ModelKind::TfmLarge => TfmArch::Large,
            ModelKind::Lr => panic!("use HostLrLevel for LR"),
        };
        HostTfmLevel {
            inner: HostTfm::from_flat(arch, classes, flat),
            kind,
            scratch: TfmScratch::new(),
            out: Vec::new(),
        }
    }
}

impl LevelModel for HostTfmLevel {
    fn kind(&self) -> ModelKind {
        self.kind
    }
    fn classes(&self) -> usize {
        self.inner.classes()
    }
    fn predict(&mut self, f: &Featurized) -> Vec<f32> {
        // Single-query inference rides the batched kernels at b=1
        // (bit-identical to the reference per-sample forward, without
        // its per-call activation allocations).
        let c = self.inner.classes();
        let mut out = vec![0.0f32; c];
        self.inner.predict_batch_into(
            &[f.ids.as_slice()],
            &[f.mask.as_slice()],
            &mut self.scratch,
            &mut out,
        );
        out
    }
    fn predict_batch(&mut self, fs: &[&Featurized]) -> Vec<Vec<f32>> {
        let c = self.inner.classes();
        let ids: Vec<&[i32]> = fs.iter().map(|f| f.ids.as_slice()).collect();
        let masks: Vec<&[f32]> = fs.iter().map(|f| f.mask.as_slice()).collect();
        self.out.resize(fs.len() * c, 0.0);
        self.inner.predict_batch_into(
            &ids,
            &masks,
            &mut self.scratch,
            &mut self.out[..fs.len() * c],
        );
        self.out[..fs.len() * c].chunks(c).map(|r| r.to_vec()).collect()
    }
    fn train(&mut self, batch: &[(&Featurized, usize)], lr: f32) -> f32 {
        let ids: Vec<&[i32]> = batch.iter().map(|(f, _)| f.ids.as_slice()).collect();
        let masks: Vec<&[f32]> = batch.iter().map(|(f, _)| f.mask.as_slice()).collect();
        let ys: Vec<usize> = batch.iter().map(|&(_, y)| y).collect();
        self.inner.train_batch(&ids, &masks, &ys, lr)
    }
    fn snapshot(&self) -> Option<Snapshot> {
        Some(Snapshot {
            kind: self.kind.entry_prefix().into(),
            classes: self.inner.classes(),
            data: self.inner.to_flat(),
        })
    }
    fn restore(&mut self, snap: &Snapshot) -> Result<()> {
        let classes = self.inner.classes();
        snap.check(
            self.kind.entry_prefix(),
            classes,
            HostTfm::flat_len(self.inner.arch(), classes),
        )?;
        self.inner.load_flat(&snap.data);
        Ok(())
    }
}

/// Host calibrator.
pub struct HostCalibrator {
    inner: HostMlp,
    /// Reused feature buffer — the calibrator runs on every gate
    /// consult, so per-call feature allocation is hot-path churn.
    feat: Vec<f32>,
}

impl HostCalibrator {
    /// Fresh calibrator.
    pub fn new(classes: usize, seed: u64) -> Self {
        HostCalibrator { inner: HostMlp::new(classes, seed), feat: Vec::new() }
    }
}

impl HostCalibrator {
    /// Classes the calibrator scores over.
    fn classes(&self) -> usize {
        self.inner.classes()
    }
}

impl Calibrator for HostCalibrator {
    fn score(&mut self, probs: &[f32]) -> f32 {
        self.inner.predict_scratch(probs, &mut self.feat)
    }
    fn train(&mut self, batch: &[(&[f32], f32)], lr: f32) -> f32 {
        let ps: Vec<&[f32]> = batch.iter().map(|&(p, _)| p).collect();
        let zs: Vec<f32> = batch.iter().map(|&(_, z)| z).collect();
        self.inner.train_batch(&ps, &zs, lr)
    }
    fn snapshot(&self) -> Option<Snapshot> {
        Some(Snapshot {
            kind: "mlp".into(),
            classes: self.classes(),
            data: self.inner.to_flat(),
        })
    }
    fn restore(&mut self, snap: &Snapshot) -> Result<()> {
        let classes = self.classes();
        snap.check("mlp", classes, HostMlp::flat_len(classes))?;
        self.inner.load_flat(&snap.data);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// PJRT engine implementations (feature-gated)
// ---------------------------------------------------------------------------

/// A cascade level running AOT HLO artifacts through PJRT.
///
/// Holds its parameters as XLA literals and threads the step outputs
/// back into subsequent calls — rust never interprets the tensors.
#[cfg(feature = "pjrt")]
pub struct PjrtLevel {
    engine: Rc<PjrtEngine>,
    kind: ModelKind,
    classes: usize,
    params: Vec<Literal>,
    fwd1: String,
    fwd8: String,
    step: String,
}

#[cfg(feature = "pjrt")]
impl PjrtLevel {
    /// Build from the engine + model kind, loading init parameters
    /// from the artifacts blob.
    pub fn new(engine: Rc<PjrtEngine>, kind: ModelKind, classes: usize) -> Result<Self> {
        let prefix = kind.entry_prefix();
        let group = format!("{prefix}_c{classes}");
        let params = load_group_literals(engine.manifest(), &group)?;
        Ok(PjrtLevel {
            engine,
            kind,
            classes,
            params,
            fwd1: format!("{prefix}_fwd_c{classes}_b1"),
            fwd8: format!("{prefix}_fwd_c{classes}_b8"),
            step: format!("{prefix}_step_c{classes}_b{BATCH_STEP}"),
        })
    }

    fn data_args(&self, entry: &str, fs: &[&Featurized]) -> Result<Vec<Literal>> {
        let meta = self.engine.manifest().entry(entry)?;
        match self.kind {
            ModelKind::Lr => {
                let mut x = Vec::with_capacity(fs.len() * HASH_DIM);
                for f in fs {
                    x.extend_from_slice(&f.x);
                }
                Ok(vec![literal_f32(&meta.args[0], &x)?])
            }
            ModelKind::TfmBase | ModelKind::TfmLarge => {
                let mut ids = Vec::with_capacity(fs.len() * SEQ_LEN);
                let mut mask = Vec::with_capacity(fs.len() * SEQ_LEN);
                for f in fs {
                    ids.extend_from_slice(&f.ids);
                    mask.extend_from_slice(&f.mask);
                }
                Ok(vec![
                    literal_i32(&meta.args[0], &ids)?,
                    literal_f32(&meta.args[1], &mask)?,
                ])
            }
        }
    }

    fn run_fwd(&mut self, entry: &str, fs: &[&Featurized]) -> Result<Vec<Vec<f32>>> {
        let data = self.data_args(entry, fs)?;
        let mut args: Vec<&Literal> = data.iter().collect();
        args.extend(self.params.iter());
        let out = self.engine.run(entry, &args)?;
        let probs = out
            .first()
            .ok_or_else(|| Error::Runtime(format!("{entry}: empty result")))?
            .to_vec::<f32>()?;
        Ok(probs.chunks(self.classes).map(|c| c.to_vec()).collect())
    }
}

#[cfg(feature = "pjrt")]
impl LevelModel for PjrtLevel {
    fn kind(&self) -> ModelKind {
        self.kind
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn predict(&mut self, f: &Featurized) -> Vec<f32> {
        let entry = self.fwd1.clone();
        self.run_fwd(&entry, &[f])
            .expect("pjrt forward failed")
            .pop()
            .expect("b1 forward returned no rows")
    }
    fn predict_batch(&mut self, fs: &[&Featurized]) -> Vec<Vec<f32>> {
        // Full b8 chunks through the batched executable; remainder b1.
        let mut out = Vec::with_capacity(fs.len());
        let mut i = 0;
        let fwd8 = self.fwd8.clone();
        while i + 8 <= fs.len() {
            out.extend(
                self.run_fwd(&fwd8, &fs[i..i + 8]).expect("pjrt b8 forward failed"),
            );
            i += 8;
        }
        for f in &fs[i..] {
            out.push(self.predict(f));
        }
        out
    }
    fn train(&mut self, batch: &[(&Featurized, usize)], lr: f32) -> f32 {
        assert_eq!(
            batch.len(),
            BATCH_STEP,
            "pjrt step executables are compiled for batch {BATCH_STEP}"
        );
        let fs: Vec<&Featurized> = batch.iter().map(|&(f, _)| f).collect();
        let step = self.step.clone();
        let meta = self.engine.manifest().entry(&step).expect("step entry");
        let n_data = meta.params_at;
        let mut data = self.data_args(&step, &fs).expect("step data args");
        // one-hot labels are the last data argument
        let mut yoh = vec![0.0f32; BATCH_STEP * self.classes];
        for (i, &(_, y)) in batch.iter().enumerate() {
            yoh[i * self.classes + y] = 1.0;
        }
        data.push(literal_f32(&meta.args[n_data - 1], &yoh).expect("yoh literal"));
        let lr_lit = Literal::scalar(lr);
        let mut args: Vec<&Literal> = data.iter().collect();
        args.extend(self.params.iter());
        args.push(&lr_lit);
        let mut out = self.engine.run(&step, &args).expect("pjrt step failed");
        let loss = out
            .pop()
            .expect("step returned nothing")
            .to_vec::<f32>()
            .expect("loss literal")[0];
        self.params = out; // params' in call order
        loss
    }
    fn snapshot(&self) -> Option<Snapshot> {
        pjrt_snapshot(self.kind.entry_prefix(), self.classes, &self.params)
    }
    fn restore(&mut self, snap: &Snapshot) -> Result<()> {
        pjrt_restore(self.kind.entry_prefix(), self.classes, &mut self.params, snap)
    }
}

/// Export PJRT parameter literals as one flat host blob (call order).
#[cfg(feature = "pjrt")]
fn pjrt_snapshot(kind: &str, classes: usize, params: &[Literal]) -> Option<Snapshot> {
    let mut data = Vec::new();
    for p in params {
        data.extend(p.to_vec::<f32>().ok()?);
    }
    Some(Snapshot { kind: kind.into(), classes, data })
}

/// Rebuild PJRT parameter literals from a flat blob, using the current
/// literals' shapes as the split spec (bit-for-bit restore).
#[cfg(feature = "pjrt")]
fn pjrt_restore(
    kind: &str,
    classes: usize,
    params: &mut [Literal],
    snap: &Snapshot,
) -> Result<()> {
    let total: usize = params.iter().map(|p| p.element_count()).sum();
    snap.check(kind, classes, total)?;
    let mut off = 0usize;
    for p in params.iter_mut() {
        let n = p.element_count();
        let shape: Vec<i64> = p.shape().to_vec();
        *p = Literal::vec1(&snap.data[off..off + n])
            .reshape(&shape)
            .map_err(|e| Error::Runtime(format!("snapshot reshape: {e}")))?;
        off += n;
    }
    Ok(())
}

/// PJRT calibrator (deferral MLP through artifacts).
#[cfg(feature = "pjrt")]
pub struct PjrtCalibrator {
    engine: Rc<PjrtEngine>,
    classes: usize,
    params: Vec<Literal>,
    fwd1: String,
    step: String,
}

#[cfg(feature = "pjrt")]
impl PjrtCalibrator {
    /// Build from the engine, loading init parameters.
    pub fn new(engine: Rc<PjrtEngine>, classes: usize) -> Result<Self> {
        let group = format!("mlp_c{classes}");
        let params = load_group_literals(engine.manifest(), &group)?;
        Ok(PjrtCalibrator {
            engine,
            classes,
            params,
            fwd1: format!("mlp_fwd_c{classes}_b1"),
            step: format!("mlp_step_c{classes}_b{BATCH_STEP}"),
        })
    }
}

#[cfg(feature = "pjrt")]
impl Calibrator for PjrtCalibrator {
    fn score(&mut self, probs: &[f32]) -> f32 {
        let meta = self.engine.manifest().entry(&self.fwd1).expect("mlp fwd entry");
        let p = literal_f32(&meta.args[0], probs).expect("probs literal");
        let mut args: Vec<&Literal> = vec![&p];
        args.extend(self.params.iter());
        let out = self.engine.run(&self.fwd1, &args).expect("mlp fwd failed");
        out[0].to_vec::<f32>().expect("score literal")[0]
    }
    fn train(&mut self, batch: &[(&[f32], f32)], lr: f32) -> f32 {
        assert_eq!(batch.len(), BATCH_STEP);
        let meta = self.engine.manifest().entry(&self.step).expect("mlp step entry");
        let mut ps = Vec::with_capacity(BATCH_STEP * self.classes);
        let mut zs = Vec::with_capacity(BATCH_STEP);
        for &(p, z) in batch {
            ps.extend_from_slice(p);
            zs.push(z);
        }
        let p_lit = literal_f32(&meta.args[0], &ps).expect("probs literal");
        let z_lit = literal_f32(&meta.args[1], &zs).expect("z literal");
        let lr_lit = Literal::scalar(lr);
        let mut args: Vec<&Literal> = vec![&p_lit, &z_lit];
        args.extend(self.params.iter());
        args.push(&lr_lit);
        let mut out = self.engine.run(&self.step, &args).expect("mlp step failed");
        let loss = out.pop().expect("loss").to_vec::<f32>().expect("loss literal")[0];
        self.params = out;
        loss
    }
    fn snapshot(&self) -> Option<Snapshot> {
        pjrt_snapshot("mlp", self.classes, &self.params)
    }
    fn restore(&mut self, snap: &Snapshot) -> Result<()> {
        pjrt_restore("mlp", self.classes, &mut self.params, snap)
    }
}

/// Construct the level model for a config row over the chosen engine.
///
/// `engine = None` selects the host backend. In builds without the
/// `pjrt` feature, `PjrtEngine` is uninhabited, so the `Some(_)` arm
/// can never execute.
pub fn build_level(
    engine: Option<&Rc<PjrtEngine>>,
    kind: ModelKind,
    classes: usize,
    seed: u64,
) -> Result<Box<dyn LevelModel>> {
    match engine {
        #[cfg(feature = "pjrt")]
        Some(e) => Ok(Box::new(PjrtLevel::new(e.clone(), kind, classes)?)),
        #[cfg(not(feature = "pjrt"))]
        Some(_) => unreachable!("PjrtEngine is uninhabited without the `pjrt` feature"),
        None => Ok(match kind {
            ModelKind::Lr => Box::new(HostLrLevel::new(classes)) as Box<dyn LevelModel>,
            _ => Box::new(HostTfmLevel::new(kind, classes, seed)),
        }),
    }
}

/// Construct a calibrator over the chosen engine.
pub fn build_calibrator(
    engine: Option<&Rc<PjrtEngine>>,
    classes: usize,
    seed: u64,
) -> Result<Box<dyn Calibrator>> {
    match engine {
        #[cfg(feature = "pjrt")]
        Some(e) => Ok(Box::new(PjrtCalibrator::new(e.clone(), classes)?)),
        #[cfg(not(feature = "pjrt"))]
        Some(_) => unreachable!("PjrtEngine is uninhabited without the `pjrt` feature"),
        None => Ok(Box::new(HostCalibrator::new(classes, seed))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_shapes() {
        let p = Pipeline::default();
        let f = p.featurize("kw0x001 neg00 c1w0003");
        assert_eq!(f.x.len(), HASH_DIM);
        assert_eq!(f.ids.len(), SEQ_LEN);
        assert_eq!(f.mask.iter().sum::<f32>(), 3.0);
        let mut buf = p.buffer();
        p.featurize_into("kw0x001 neg00 c1w0003", &mut buf);
        assert_eq!(buf.x, f.x);
        assert_eq!(buf.ids, f.ids);
    }

    #[test]
    fn host_levels_implement_trait() {
        let p = Pipeline::default();
        let f = p.featurize("kw1x001 kw1x002 kw1x003");
        let mut lr = HostLrLevel::new(2);
        let probs = lr.predict(&f);
        assert_eq!(probs.len(), 2);
        let batch = [(&f, 1usize)];
        // batch of 1 trains fine on host
        let l1 = lr.train(&batch, 0.5);
        assert!(l1 > 0.0);
        let mut tfm = HostTfmLevel::new(ModelKind::TfmBase, 7, 0);
        assert_eq!(tfm.predict(&f).len(), 7);
        assert_eq!(tfm.kind(), ModelKind::TfmBase);
    }

    #[test]
    fn host_calibrator_trains() {
        let mut c = HostCalibrator::new(2, 0);
        let lo: &[f32] = &[0.55, 0.45];
        let hi: &[f32] = &[0.97, 0.03];
        let batch = [(lo, 1.0f32), (hi, 0.0f32)];
        for _ in 0..200 {
            c.train(&batch, 0.1);
        }
        assert!(c.score(lo) > c.score(hi));
    }

    #[test]
    fn snapshot_json_roundtrip_is_bit_for_bit() {
        let p = Pipeline::default();
        let f = p.featurize("kw0x001 kw1x002 neg00");
        let mut lr = HostLrLevel::new(2);
        lr.train(&[(&f, 1usize)], 0.5);
        let snap = lr.snapshot().expect("host snapshot");
        let text = snap.to_json().to_string_compact();
        let back = Snapshot::from_json(&crate::codec::parse(&text).unwrap()).unwrap();
        assert_eq!(back, snap, "f32 blob must survive the JSON trip exactly");
        let mut fresh = HostLrLevel::new(2);
        fresh.restore(&back).unwrap();
        assert_eq!(fresh.predict(&f), lr.predict(&f));
        // foreign snapshots are rejected, not silently installed
        let mut seven = HostTfmLevel::new(ModelKind::TfmBase, 7, 0);
        assert!(seven.restore(&back).is_err());
        let mut c = HostCalibrator::new(2, 0);
        assert!(c.restore(&back).is_err(), "model blob must not restore a calibrator");
    }

    #[test]
    fn featurized_json_roundtrip_is_bit_for_bit() {
        let p = Pipeline::default();
        let f = p.featurize("kw0x001 kw1x002 neg00 c1w0003");
        let text = f.to_json().to_string_compact();
        let back = Featurized::from_json(&crate::codec::parse(&text).unwrap()).unwrap();
        assert_eq!(back, f, "sparse encoding must reproduce x/ids/mask exactly");
        // malformed inputs fail cleanly, not silently
        let bad = crate::codec::parse(r#"{"xi":[1],"xv":[],"ids":[],"mask":[]}"#).unwrap();
        assert!(Featurized::from_json(&bad).is_err());
        // out-of-vocab token ids are rejected at decode time (they
        // would otherwise panic inside embedding lookups much later)
        let mut oov = f.clone();
        oov.ids[0] = -1;
        let text = oov.to_json().to_string_compact();
        assert!(Featurized::from_json(&crate::codec::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn predict_batch_default_matches_loop() {
        let p = Pipeline::default();
        let f1 = p.featurize("kw0x001 kw0x004");
        let f2 = p.featurize("kw1x002");
        let mut lr = HostLrLevel::new(2);
        let batched = lr.predict_batch(&[&f1, &f2]);
        assert_eq!(batched[0], lr.predict(&f1));
        assert_eq!(batched[1], lr.predict(&f2));
    }

    #[test]
    fn host_overrides_match_per_sample_exactly() {
        // The batched overrides (HostLrLevel/HostTfmLevel) and the
        // b=1-through-batched predict must agree bit-for-bit with the
        // reference per-sample forward of the underlying host models.
        let p = Pipeline::default();
        let fs: Vec<Featurized> = ["kw0x001 kw0x004 neg00", "kw1x002", "kw1x002 kw0x001"]
            .iter()
            .map(|t| p.featurize(t))
            .collect();
        let refs: Vec<&Featurized> = fs.iter().collect();
        let mut tfm = HostTfmLevel::new(ModelKind::TfmBase, 2, 3);
        let batched = tfm.predict_batch(&refs);
        for (f, got) in refs.iter().zip(&batched) {
            let reference = tfm.inner.predict(&f.ids, &f.mask);
            assert_eq!(got, &reference, "batched vs reference forward");
            assert_eq!(&tfm.predict(f), &reference, "b=1 trait predict vs reference");
        }
        let mut lr = HostLrLevel::new(2);
        let batched = lr.predict_batch(&refs);
        for (f, got) in refs.iter().zip(&batched) {
            assert_eq!(got, &lr.inner.predict(&f.x));
        }
    }
}
