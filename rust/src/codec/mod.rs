//! Serialization substrate (no `serde` in the offline image).

pub mod json;

pub use json::{parse, Json};
