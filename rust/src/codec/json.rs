//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Used for the artifact manifest (written by `python/compile/aot.py`),
//! experiment configs, and machine-readable reports. Full JSON per
//! RFC 8259 minus surrogate-pair escapes (the manifest is ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (integers ride in `f64`; see [`Json::u64_hex`] for
    /// values that must survive beyond 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys → deterministic encoding).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object member lookup; `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Member lookup that errors with the key name (manifest loading).
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key '{key}'")))
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Encode an `f32` slice as a number array. Every finite `f32` is
    /// exactly representable as `f64` and the writer emits shortest
    /// round-trip decimals, so `as_f32_vec(parse(write(x))) == x`
    /// bit-for-bit — the property model snapshots rely on.
    pub fn f32_arr(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Decode a number array into `f32`s (`None` on any non-number).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Encode a `u64` losslessly as a hex string. JSON numbers travel
    /// through `f64` in this codec, which silently rounds integers
    /// above 2^53 — full-range words (PRNG state in checkpoints) use
    /// this instead; [`Json::as_u64_hex`] is the inverse.
    pub fn u64_hex(x: u64) -> Json {
        Json::Str(format!("{x:016x}"))
    }

    /// Decode a [`Json::u64_hex`] string (`None` on any other value).
    pub fn as_u64_hex(&self) -> Option<u64> {
        u64::from_str_radix(self.as_str()?, 16).ok()
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `[usize]` array.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty encoding with 1-space indent (matches aot.py's output).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-borrow multi-byte UTF-8 sequences intact.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("bad utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert!(v.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"hi\t\"q\"","o":{"k":-1}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "nul", "{\"a\" 1}", "1 2", "\"", "{\"a\":}"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn u64_hex_is_lossless_at_full_range() {
        for x in [0u64, 1, (1 << 53) + 1, u64::MAX, 0x9E3779B97F4A7C15] {
            let j = Json::u64_hex(x);
            let back = parse(&j.to_string_compact()).unwrap();
            assert_eq!(back.as_u64_hex(), Some(x));
        }
        assert_eq!(Json::Num(3.0).as_u64_hex(), None);
        assert_eq!(Json::Str("zz".into()).as_u64_hex(), None);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n":3,"f":1.5,"b":false,"s":"x"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.require("missing").is_err());
    }
}
