//! Single import funnel for every concurrency primitive the crate
//! uses — `Arc`, `Mutex`, atomics, `mpsc` channels, and threads all
//! come through here instead of `std::sync`/`std::thread` directly.
//!
//! Two reasons to centralize:
//!
//! 1. **Model-checking seam.** The protocol cores extracted into
//!    [`crate::mc`] (admission gate, snapshot slot, checkpoint
//!    barrier) are exhaustively explored over interleavings by
//!    `tests/test_loom.rs`. Swapping the whole crate onto an
//!    instrumented runtime (the `loom` crate, when a vendored copy is
//!    available) is a one-file change: re-export `loom::sync`/
//!    `loom::thread` here under `cfg(loom)` and nothing else moves.
//!    Today the default and `--cfg loom` builds both re-export `std`;
//!    `--cfg loom` instead raises the in-tree checker from its
//!    bounded quick profile to exhaustive exploration (see
//!    `tests/test_loom.rs`).
//! 2. **Lint surface.** `ocl-lint` (rule `sync-funnel`) fails the
//!    build on any direct `std::sync`/`std::thread` import outside
//!    this file, so new concurrency can't silently bypass the seam.
//!
//! The re-exports are deliberately the *narrow* subset the crate
//! actually uses — adding a primitive here is a conscious act that
//! should come with a model or at least a lint story.

pub use std::sync::mpsc;
pub use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
pub use std::thread;

/// The atomic types and orderings the serve layer uses.
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Lock a mutex, recovering from poisoning instead of propagating the
/// panic of whichever thread died while holding it.
///
/// Sound only where the protected data is *replaced whole* under the
/// lock (snapshot slots, response registries, report maps) so a
/// mid-update panic cannot leave it torn. Callers for whom poisoning
/// would mean torn state must keep the explicit `lock().expect(..)`
/// with a `// lint: allow(unwrap)` justification instead.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
