//! Online cascade learning — the paper's Algorithm 1.
//!
//! For each stream query the cascade walks levels `m_1 .. m_{N-1}`:
//! predict, score the prediction with the level's deferral calibrator
//! `f_i`, exit if confident, defer otherwise; the expert LLM `m_N` is
//! the last resort. DAgger-style, each level may also jump straight to
//! the expert with a decaying probability β_i. Every expert annotation
//! is appended to the per-level replay caches ("Cache Size" in Tables
//! 3–4) and the levels + calibrators are updated by online gradient
//! descent. No human label is ever read by the algorithm: ground truth
//! is used *only* by [`metrics::StreamMetrics`] for evaluation.

pub mod metrics;

use std::rc::Rc;

use crate::config::{CascadeConfig, LevelConfig};
use crate::data::Sample;
use crate::error::Result;
use crate::models::{
    build_calibrator, build_level, Calibrator, Featurized, LevelModel, Pipeline,
};
use crate::policy::{zero_one_loss, CostParams, RegretTracker};
use crate::prng::Rng;
use crate::runtime::PjrtEngine;
use crate::sim::cost::CostModel;
use crate::sim::Expert;
use crate::util::{argmax, normalized_entropy, Ring};
use metrics::StreamMetrics;

/// How the deferral decision is made. The calibrated MLP is the
/// paper's method; max-prob / entropy are the related-work rules and
/// double as the ablation of confidence calibration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DeferralRule {
    /// Paper §3: post-hoc calibration MLP; defer when score > τ_i.
    Calibrated,
    /// Defer when max predictive probability < τ (Varshney & Baral).
    MaxProb(f64),
    /// Defer when normalized entropy > τ (Stogiannidis et al.).
    Entropy(f64),
}

/// What happened to one query.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    /// The cascade's emitted label.
    pub pred: usize,
    /// Level that produced the output (`levels.len()` = the expert).
    pub handled_by: usize,
    /// Whether the expert was invoked (deferral or DAgger jump).
    pub expert_called: bool,
    /// The expert's annotation, when it was invoked.
    pub annotation: Option<usize>,
    /// FLOPs charged for this query (inference + any training).
    pub flops: f64,
}

/// Calibration replay cache depth (see Level::calib_cache) — shared
/// with the serve router so the two learners size their calibration
/// replay identically (learner parity).
pub const CALIB_CACHE: usize = 128;

/// Replay depth multiplier over the paper's "Cache Size" column.
///
/// The paper fine-tunes *pretrained* BERT levels, which tolerate
/// training on the deferral-biased annotation stream with an 8–32
/// sample cache. Our from-scratch surrogates drift catastrophically
/// under the same regime (the annotated subset collapses to the
/// hard/uncertain tail once gates narrow). A deeper replay ring with
/// uniform batch sampling restores the i.i.d.-ish training mix while
/// keeping the table's batch sizes; the deviation is documented in
/// DESIGN.md §7 and ablated in `benches/bench_large_cascade.rs`.
pub const REPLAY_FACTOR: usize = 16;

/// The paper's Tables 3–4 quote calibration-MLP learning rates of
/// 7e-4..1e-3 for MLPs over BERT-scale inputs; our probability
/// vectors are 2–7 dimensional, so the same rates would need ~100x
/// more annotated samples than the budgets provide. The table value
/// is kept in the config (for traceability) and scaled by this
/// constant wherever a calibrator is trained — shared with the serve
/// router so the offline and served learners cannot drift.
pub const MLP_LR_SCALE: f32 = 50.0;

/// Replay batches drawn from the calibration cache per trigger —
/// shared with the serve router (learner parity).
pub const CALIB_REPLAY: usize = 4;

/// Replay-batch index selection shared by [`Cascade`] and
/// [`crate::serve::Server`]: half the batch is the newest annotations
/// (fast adaptation), half is replayed history (drift resistance),
/// plus a second full uniform pass — two passes per trigger (the
/// distillation baseline trains 5 epochs over its label set, §B.3, so
/// the online learner needs comparable per-annotation sample
/// efficiency). Keeping this in one place is what guarantees the two
/// learners build identical training batches per trigger.
pub fn replay_picks(rng: &mut Rng, len: usize, bs: usize) -> Vec<usize> {
    let mut picked: Vec<usize> = (len - bs / 2..len).collect();
    picked.extend(rng.sample_indices(len, bs - bs / 2));
    picked.extend(rng.sample_indices(len, bs));
    picked
}

/// One cascade level: model + deferral function + learning state.
struct Level {
    cfg: LevelConfig,
    model: Box<dyn LevelModel>,
    calib: Box<dyn Calibrator>,
    /// Annotation replay cache D_i.
    cache: Ring<(Rc<Featurized>, usize)>,
    /// Calibration replay cache: (probs at this level, z_i).
    calib_cache: Ring<(Vec<f32>, f32)>,
    /// Annotations since the last model update.
    pending: usize,
    /// Calibration examples since the last calibrator update.
    calib_pending: usize,
    /// Current DAgger jump probability β_i.
    beta: f64,
    /// 8-sample model-training chunks executed (parity diagnostics).
    train_chunks: u64,
    /// 8-sample calibrator-training chunks executed.
    calib_chunks: u64,
}

/// The online cascade (Algorithm 1 driver).
pub struct Cascade {
    cfg: CascadeConfig,
    classes: usize,
    levels: Vec<Level>,
    expert: Expert,
    pipeline: Pipeline,
    rng: Rng,
    /// Global multiplier on per-level calibration thresholds — the
    /// practical μ knob: smaller scale ⇒ defer more ⇒ more LLM calls.
    threshold_scale: f64,
    /// Hard budget on expert calls (the paper's 𝒩); `None` = unlimited.
    budget: Option<u64>,
    /// Expert calls spent (survives metric resets — budgets span the
    /// whole stream even when accuracy is measured on the test half).
    spent: u64,
    /// Queries processed (survives metric resets; pacing denominator).
    processed: usize,
    /// Expected stream length for the budget pacing controller.
    pace_len: Option<usize>,
    deferral_rule: DeferralRule,
    /// Evaluation state (ground truth is consumed here only).
    pub metrics: StreamMetrics,
    /// Empirical-regret tracker (enable explicitly; it evaluates every
    /// level on every sample, which costs extra inference).
    pub regret: Option<RegretTracker>,
    /// Online learning switch (frozen cascades for ablations).
    pub learning: bool,
}

impl Cascade {
    /// Build a cascade for `classes`-way streams.
    ///
    /// `pjrt` must be `Some` when `cfg.engine` selects the PJRT
    /// backend (only possible with the `pjrt` cargo feature).
    pub fn new(
        cfg: CascadeConfig,
        classes: usize,
        expert: Expert,
        pjrt: Option<&Rc<PjrtEngine>>,
        snapshot_every: usize,
    ) -> Result<Self> {
        let engine_ref = if cfg.engine.is_pjrt() {
            assert!(pjrt.is_some(), "pjrt engine required by config");
            pjrt
        } else {
            None
        };
        let mut levels = Vec::with_capacity(cfg.levels.len());
        for (i, lc) in cfg.levels.iter().enumerate() {
            let seed = cfg.seed ^ ((i as u64 + 1) * 0x9E37);
            levels.push(Level {
                cfg: lc.clone(),
                model: build_level(engine_ref, lc.model, classes, seed)?,
                calib: build_calibrator(engine_ref, classes, seed)?,
                cache: Ring::new(lc.cache_size.max(lc.batch_size) * REPLAY_FACTOR),
                // Calibration replay is kept deeper than the model
                // cache: the deferral decision is the control loop of
                // the whole system and needs a smoother MSE estimate
                // than an 8-sample window provides.
                calib_cache: Ring::new(CALIB_CACHE),
                pending: 0,
                calib_pending: 0,
                beta: cfg.beta0,
                train_chunks: 0,
                calib_chunks: 0,
            });
        }
        let n_levels = cfg.levels.len() + 1;
        Ok(Cascade {
            rng: Rng::new(cfg.seed ^ 0xCA5C),
            metrics: StreamMetrics::new(n_levels, classes, snapshot_every),
            regret: None,
            learning: true,
            threshold_scale: 1.0,
            budget: None,
            spent: 0,
            processed: 0,
            pace_len: None,
            deferral_rule: DeferralRule::Calibrated,
            pipeline: Pipeline::default(),
            classes,
            levels,
            expert,
            cfg,
        })
    }

    /// Set the global threshold scale (the cost-pressure / μ knob).
    pub fn set_threshold_scale(&mut self, s: f64) {
        self.threshold_scale = s;
    }

    /// Set a hard expert-call budget (the paper's 𝒩).
    pub fn set_budget(&mut self, n: Option<u64>) {
        self.budget = n;
    }

    /// Enable budget pacing against an expected stream length.
    ///
    /// The paper hits each reported budget by tuning μ per run
    /// (§B.3: "we tuned μ specifically in the context of different
    /// cost budgets"). The online equivalent is a feedback controller:
    /// the effective deferral threshold is scaled by
    /// `exp(k·(spent_frac − elapsed_frac))`, deferring more while the
    /// budget is underspent and exiting earlier when overspent —
    /// converging on the same cost-performance operating point without
    /// a per-run offline grid search.
    pub fn set_budget_paced(&mut self, n: u64, expected_stream_len: usize) {
        self.budget = Some(n);
        self.pace_len = Some(expected_stream_len.max(1));
    }

    /// Switch the deferral rule (ablations).
    pub fn set_deferral_rule(&mut self, r: DeferralRule) {
        self.deferral_rule = r;
    }

    /// Enable empirical-regret tracking.
    pub fn enable_regret_tracking(&mut self, trace_every: usize) {
        self.regret = Some(RegretTracker::new(
            CostParams::from_config(&self.cfg),
            self.levels.len() + 1,
            trace_every,
        ));
    }

    /// Direct access to the expert simulator (failure injection).
    pub fn expert_mut(&mut self) -> &mut Expert {
        &mut self.expert
    }

    /// Expert call count charged so far.
    pub fn llm_calls(&self) -> u64 {
        self.metrics.llm_calls()
    }

    /// The config in use.
    pub fn config(&self) -> &CascadeConfig {
        &self.cfg
    }

    /// Current β of each level (diagnostics).
    pub fn betas(&self) -> Vec<f64> {
        self.levels.iter().map(|l| l.beta).collect()
    }

    /// Per-level (model, calibrator) 8-sample training-chunk counts —
    /// the learner-parity diagnostic the serve tests compare against
    /// [`crate::serve::ServeReport`]'s worker counters.
    pub fn train_counts(&self) -> Vec<(u64, u64)> {
        self.levels.iter().map(|l| (l.train_chunks, l.calib_chunks)).collect()
    }

    /// Evaluate every level on a sample without touching any state
    /// (diagnostics/tests): returns (probs, deferral score) per level.
    pub fn diagnose(&mut self, sample: &Sample) -> Vec<(Vec<f32>, f32)> {
        let f = self.pipeline.featurize(&sample.text);
        let mut out = Vec::with_capacity(self.levels.len());
        for l in &mut self.levels {
            // Batched entry point (b=1): bit-identical to `predict`,
            // exercises the serve hot path's kernels.
            let probs = l
                .model
                .predict_batch(&[&f])
                .pop()
                .expect("predict_batch returned no rows");
            let score = l.calib.score(&probs);
            out.push((probs, score));
        }
        out
    }

    /// Budget-pacing multiplier on the effective threshold (1.0 when
    /// pacing is off): <1 while underspent (defer more), >1 when
    /// overspent (exit earlier).
    fn pace_factor(&self) -> f64 {
        let (Some(budget), Some(t_total)) = (self.budget, self.pace_len) else {
            return 1.0;
        };
        if budget == 0 {
            return 4.0;
        }
        let spent = self.spent as f64 / budget as f64;
        let elapsed = self.processed as f64 / t_total as f64;
        // Spend profile: up to half the budget may be front-loaded into
        // the first 20% of the stream (annotations train the levels
        // fastest early — the paper's Fig. 5 spend shape), the rest is
        // released pro-rata so expert capacity remains available across
        // the whole stream instead of exhausting at the start.
        let allowed = 0.5 * (elapsed / 0.2).min(1.0)
            + 0.5 * ((elapsed - 0.2).max(0.0) / 0.8).min(1.0);
        (4.0 * (spent - allowed)).exp().clamp(0.05, 4.0)
    }

    fn defer_decision(&mut self, level: usize, probs: &[f32]) -> bool {
        let pace = self.pace_factor();
        match self.deferral_rule {
            DeferralRule::Calibrated => {
                let tau =
                    self.levels[level].cfg.calibration * self.threshold_scale * pace;
                (self.levels[level].calib.score(probs) as f64) > tau
            }
            DeferralRule::MaxProb(t) => {
                let mp =
                    probs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
                mp < t / self.threshold_scale.max(1e-6)
            }
            DeferralRule::Entropy(t) => {
                (normalized_entropy(probs) as f64) > t * self.threshold_scale
            }
        }
    }

    /// Reset the evaluation metrics while keeping all learned state —
    /// the Table-1 protocol measures accuracy on the test half only
    /// (§4: "All methods are evaluated on the identical test sets")
    /// while learning and budgets span the whole stream.
    pub fn reset_metrics(&mut self) {
        let snap = self.metrics.series.last().map(|s| s.t).unwrap_or(1).max(1);
        let classes = self.classes;
        let n_levels = self.levels.len() + 1;
        let _ = snap;
        let every = usize::MAX / 2;
        self.metrics = StreamMetrics::new(n_levels, classes, every);
    }

    /// Process one stream query — the body of Algorithm 1's outer loop.
    pub fn process(&mut self, sample: &Sample) -> StepOutcome {
        self.processed += 1;
        let f = Rc::new(self.pipeline.featurize(&sample.text));
        let mut flops = 0.0;
        // Predictions gathered on the way down (calibration targets,
        // budget fallback, regret tracking).
        let mut seen: Vec<Option<Vec<f32>>> = vec![None; self.levels.len()];
        let mut exit: Option<(usize, usize)> = None; // (level, pred)
        let mut jumped = false;

        let budget_left = self
            .budget
            .map(|b| self.spent < b)
            .unwrap_or(true);

        for i in 0..self.levels.len() {
            // DAgger jump to the expert at probability β_i.
            let beta = self.levels[i].beta;
            if self.learning && budget_left && beta > 0.0 && self.rng.coin(beta) {
                jumped = true;
                break;
            }
            let probs = self.levels[i].model.predict(&f);
            flops +=
                CostModel::infer_flops(self.levels[i].cfg.model) + CostModel::MLP_INFER;
            let defer = self.defer_decision(i, &probs);
            let pred = argmax(&probs);
            seen[i] = Some(probs);
            if !defer {
                exit = Some((i, pred));
                break;
            }
        }

        // Expert invocation: deferral past the last level, or a jump.
        let (handled_by, pred, expert_called, annotation) = match exit {
            Some((i, p)) if !jumped => (i, p, false, None),
            _ => {
                if budget_left {
                    match self.expert.annotate(sample, self.classes) {
                        Some(y_hat) => {
                            flops += self.expert.flops_per_call();
                            self.spent += 1;
                            (self.levels.len(), y_hat, true, Some(y_hat))
                        }
                        None => {
                            // Failure injection: expert down — deepest
                            // level answers instead.
                            let (p, extra) = self.fallback_pred(&f, &mut seen);
                            flops += extra;
                            (self.levels.len() - 1, p, false, None)
                        }
                    }
                } else {
                    // Budget exhausted: deepest level answers.
                    let (p, extra) = self.fallback_pred(&f, &mut seen);
                    flops += extra;
                    (self.levels.len() - 1, p, false, None)
                }
            }
        };

        // --- learning updates (only from expert annotations) ---------
        if self.learning {
            if let Some(y_star) = annotation {
                flops += self.absorb_annotation(&f, y_star, &seen);
            }
            for l in &mut self.levels {
                l.beta *= l.cfg.beta_decay;
            }
        }

        // --- evaluation ----------------------------------------------
        let expert_would = self.expert.peek(sample, self.classes) == sample.label;
        self.metrics.record(
            pred,
            sample.label,
            handled_by,
            expert_called,
            expert_would,
            flops,
        );
        if self.regret.is_some() {
            let loss = zero_one_loss(pred, sample.label);
            self.record_regret(&f, sample, &seen, handled_by, loss);
        }

        StepOutcome { pred, handled_by, expert_called, annotation, flops }
    }

    /// Run a whole stream; returns final accuracy.
    pub fn run_stream(&mut self, stream: &[&Sample]) -> f64 {
        for s in stream {
            self.process(s);
        }
        self.metrics.finalize();
        self.metrics.accuracy()
    }

    /// Fallback when the expert cannot be used (budget exhausted or
    /// outage): a confidence-weighted ensemble over the levels. Each
    /// calibrator estimates `P(m_i wrong | m_i(x))`, so weighting each
    /// level's probability vector by `1 − P(wrong)` is the natural
    /// posterior mixture — and adds the ensemble's variance reduction
    /// exactly in the regime (no more annotations) where single-level
    /// exits are least reliable.
    fn fallback_pred(
        &mut self,
        f: &Rc<Featurized>,
        seen: &mut [Option<Vec<f32>>],
    ) -> (usize, f64) {
        let mut extra = 0.0;
        let mut mix = vec![0.0f32; self.classes];
        for i in 0..self.levels.len() {
            if seen[i].is_none() {
                let probs = self.levels[i]
                    .model
                    .predict_batch(&[f.as_ref()])
                    .pop()
                    .expect("predict_batch returned no rows");
                extra += CostModel::infer_flops(self.levels[i].cfg.model);
                seen[i] = Some(probs);
            }
            let probs = seen[i].as_ref().expect("fallback probs");
            let score = self.levels[i].calib.score(probs);
            extra += CostModel::MLP_INFER;
            let w = (1.0 - score).max(0.05);
            for (m, &p) in mix.iter_mut().zip(probs) {
                *m += w * p;
            }
        }
        (argmax(&mix), extra)
    }

    /// Push an expert annotation through every level's caches and run
    /// due OGD updates; returns the training FLOPs charged.
    ///
    /// Calibration (Eq. 5) happens exactly on expert-annotated queries:
    /// levels the walk skipped (DAgger jump) are evaluated here so every
    /// `f_i` receives its `(m_i(x), z_i)` example — the cost is charged.
    fn absorb_annotation(
        &mut self,
        f: &Rc<Featurized>,
        y_star: usize,
        seen: &[Option<Vec<f32>>],
    ) -> f64 {
        let mut flops = 0.0;
        for i in 0..self.levels.len() {
            self.levels[i].cache.push((f.clone(), y_star));
            self.levels[i].pending += 1;
            let probs = match &seen[i] {
                Some(p) => p.clone(),
                None => {
                    // Calibration fill-in rides the batched inference
                    // entry point (bit-identical to per-sample predict;
                    // host models reuse their scratch buffers there).
                    let p = self.levels[i]
                        .model
                        .predict_batch(&[f.as_ref()])
                        .pop()
                        .expect("predict_batch returned no rows");
                    flops += CostModel::infer_flops(self.levels[i].cfg.model);
                    p
                }
            };
            {
                let probs = &probs;
                let z = if argmax(probs) != y_star { 1.0 } else { 0.0 };
                self.levels[i].calib_cache.push((probs.clone(), z));
                self.levels[i].calib_pending += 1;
            }
            let bs = self.levels[i].cfg.batch_size;
            if self.levels[i].pending >= bs && self.levels[i].cache.len() >= bs {
                flops += self.train_level(i);
                self.levels[i].pending = 0;
            }
            if self.levels[i].calib_pending >= 8 && self.levels[i].calib_cache.len() >= 8
            {
                flops += self.train_calibrator(i);
                self.levels[i].calib_pending = 0;
            }
        }
        flops
    }

    fn train_level(&mut self, i: usize) -> f64 {
        let is_pjrt = self.cfg.engine.is_pjrt();
        let items = self.levels[i].cache.to_vec();
        let bs = self.levels[i].cfg.batch_size;
        if items.len() < bs {
            return 0.0;
        }
        // Uniform replay over the ring (see REPLAY_FACTOR); batch
        // construction is shared with the serve router via
        // `replay_picks` so the two learners cannot drift.
        let picked = replay_picks(&mut self.rng, items.len(), bs);
        let mut flops = 0.0;
        let lvl = &mut self.levels[i];
        for chunk in picked.chunks(8) {
            if chunk.len() < 8 && is_pjrt {
                break; // pjrt step executables are fixed at batch 8
            }
            let batch: Vec<(&Featurized, usize)> =
                chunk.iter().map(|&j| (items[j].0.as_ref(), items[j].1)).collect();
            lvl.model.train(&batch, lvl.cfg.model_lr);
            lvl.train_chunks += 1;
            flops += CostModel::train_flops(lvl.cfg.model) * chunk.len() as f64;
        }
        flops
    }

    fn train_calibrator(&mut self, i: usize) -> f64 {
        let items = self.levels[i].calib_cache.to_vec();
        if items.len() < 8 {
            return 0.0;
        }
        let lr = self.levels[i].cfg.mlp_lr * MLP_LR_SCALE;
        let mut flops = 0.0;
        for _ in 0..CALIB_REPLAY {
            let idx = self.rng.sample_indices(items.len(), 8);
            let batch: Vec<(&[f32], f32)> =
                idx.iter().map(|&j| (items[j].0.as_slice(), items[j].1)).collect();
            self.levels[i].calib.train(&batch, lr);
            self.levels[i].calib_chunks += 1;
            flops += CostModel::MLP_TRAIN * 8.0;
        }
        flops
    }

    fn record_regret(
        &mut self,
        f: &Rc<Featurized>,
        sample: &Sample,
        seen: &[Option<Vec<f32>>],
        exit_level: usize,
        loss: f64,
    ) {
        let mut fixed = Vec::with_capacity(self.levels.len() + 1);
        for i in 0..self.levels.len() {
            let pred = match &seen[i] {
                Some(p) => argmax(p),
                None => argmax(
                    &self.levels[i]
                        .model
                        .predict_batch(&[f.as_ref()])
                        .pop()
                        .expect("predict_batch returned no rows"),
                ),
            };
            fixed.push(zero_one_loss(pred, sample.label));
        }
        fixed.push(zero_one_loss(
            self.expert.peek(sample, self.classes),
            sample.label,
        ));
        if let Some(rt) = &mut self.regret {
            rt.record(exit_level, loss, &fixed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BenchmarkId, CascadeConfig, ExpertId};
    use crate::data::Benchmark;
    use crate::sim::ExpertProfile;

    pub(crate) fn build(
        bench: BenchmarkId,
        n: usize,
        seed: u64,
    ) -> (Cascade, Benchmark) {
        let b = Benchmark::build_sized(bench, seed, n);
        let mean_len =
            b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
        let expert = Expert::new(
            ExpertProfile::for_pair(ExpertId::Gpt35, bench),
            b.strata_fractions(),
            mean_len,
            seed ^ 0xE,
        );
        let cfg = CascadeConfig::small(bench, ExpertId::Gpt35);
        let c = Cascade::new(cfg, b.classes, expert, None, 200).unwrap();
        (c, b)
    }

    #[test]
    fn early_stream_goes_to_expert() {
        let (mut c, b) = build(BenchmarkId::Imdb, 50, 1);
        // β₁ = 1.0: the very first queries must all reach the expert.
        let out = c.process(&b.samples[0]);
        assert!(out.expert_called);
        assert_eq!(out.handled_by, 2);
        assert!(out.annotation.is_some());
    }

    #[test]
    fn smaller_models_take_over() {
        let (mut c, b) = build(BenchmarkId::Imdb, 1500, 2);
        let stream = b.stream();
        c.run_stream(&stream);
        let frac = c.metrics.handled_fractions();
        // After 1500 samples the cheap levels must handle a majority
        // and the LLM share must have dropped well below 1.
        let small = frac[0] + frac[1];
        assert!(small > 0.4, "small-model share {small} fracs {frac:?}");
        assert!(
            (c.llm_calls() as f64) < 0.7 * stream.len() as f64,
            "llm calls {}",
            c.llm_calls()
        );
        // β decayed essentially to zero.
        assert!(c.betas().iter().all(|&b| b < 0.01));
    }

    #[test]
    fn accuracy_tracks_expert_on_easy_benchmark() {
        // Operate near the paper's featured IMDB budget (~30% of the
        // stream annotated — Fig. 5 runs at 𝒩/T ≈ 0.29).
        let (mut c, b) = build(BenchmarkId::Imdb, 2500, 3);
        c.set_threshold_scale(0.7);
        let acc = c.run_stream(&b.stream());
        let exp = c.metrics.expert_accuracy();
        assert!(
            acc > exp - 0.15,
            "cascade {acc} too far below expert {exp}"
        );
        assert!(
            (c.llm_calls() as f64) < 0.75 * 2500.0,
            "too many llm calls: {}",
            c.llm_calls()
        );
    }

    #[test]
    fn budget_is_hard() {
        let (mut c, b) = build(BenchmarkId::Imdb, 800, 4);
        c.set_budget(Some(100));
        c.run_stream(&b.stream());
        assert!(c.llm_calls() <= 100, "{} calls", c.llm_calls());
        assert_eq!(c.metrics.total(), 800);
    }

    #[test]
    fn threshold_scale_modulates_llm_usage() {
        let mut calls = Vec::new();
        for (i, scale) in [(10u64, 0.4), (11, 2.5)] {
            let (mut c, b) = build(BenchmarkId::Imdb, 1200, i);
            c.set_threshold_scale(scale);
            c.run_stream(&b.stream());
            calls.push(c.llm_calls());
        }
        assert!(
            calls[0] > calls[1],
            "lower threshold must defer more: {calls:?}"
        );
    }

    #[test]
    fn expert_outage_falls_back_without_panic() {
        let (mut c, b) = build(BenchmarkId::Imdb, 300, 5);
        c.expert_mut().set_available(false);
        c.run_stream(&b.stream());
        assert_eq!(c.llm_calls(), 0);
        assert_eq!(c.metrics.total(), 300);
    }

    #[test]
    fn frozen_cascade_never_learns_or_jumps() {
        let (mut c, b) = build(BenchmarkId::Imdb, 200, 6);
        c.learning = false;
        c.run_stream(&b.stream());
        // β never decayed (no learning), but jumps disabled.
        assert!(c.betas().iter().all(|&x| (x - 1.0).abs() < 1e-12));
    }

    #[test]
    fn regret_trends_nonincreasing() {
        let (mut c, b) = build(BenchmarkId::Imdb, 2000, 7);
        c.enable_regret_tracking(100);
        c.run_stream(&b.stream());
        let rt = c.regret.as_ref().unwrap();
        let trace = &rt.trace;
        assert!(trace.len() >= 10);
        // Average regret in the last quarter must be below the first
        // quarter (the no-regret property, empirically).
        let q = trace.len() / 4;
        let first: f64 =
            trace[..q].iter().map(|&(_, r)| r).sum::<f64>() / q as f64;
        let last: f64 =
            trace[trace.len() - q..].iter().map(|&(_, r)| r).sum::<f64>() / q as f64;
        assert!(
            last <= first + 1e-9,
            "avg regret rose: first {first} last {last}"
        );
    }

    #[test]
    fn deferral_rule_ablations_run() {
        for rule in [DeferralRule::MaxProb(0.8), DeferralRule::Entropy(0.5)] {
            let (mut c, b) = build(BenchmarkId::Imdb, 300, 8);
            c.set_deferral_rule(rule);
            let acc = c.run_stream(&b.stream());
            assert!(acc > 0.4, "{rule:?} collapsed: {acc}");
        }
    }

    #[test]
    fn isear_multiclass_runs() {
        let (mut c, b) = build(BenchmarkId::Isear, 600, 9);
        let acc = c.run_stream(&b.stream());
        assert!(acc > 1.0 / 7.0, "above chance: {acc}");
    }
}
