//! Streaming evaluation metrics for cascade runs: running accuracy,
//! per-class precision/recall/F1, per-level routing fractions, cost
//! accumulators, and periodic time-series snapshots (the data behind
//! the paper's Figures 5–8 case-analysis plots).

/// One periodic snapshot of the run state (a point on Figs 5–8).
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Samples processed so far.
    pub t: usize,
    /// Running accuracy of the cascade's outputs vs ground truth.
    pub accuracy: f64,
    /// Running accuracy of the expert alone on the same prefix.
    pub expert_accuracy: f64,
    /// Cumulative fraction of queries handled at each level
    /// (levels 0..N-2 then the expert).
    pub handled_frac: Vec<f64>,
    /// Cumulative expert (LLM) calls.
    pub llm_calls: u64,
    /// Cumulative FLOPs spent (inference + training, all levels).
    pub flops: f64,
}

/// Streaming metrics accumulator.
#[derive(Clone, Debug)]
pub struct StreamMetrics {
    n_levels: usize,
    #[allow(dead_code)]
    classes: usize,
    total: usize,
    correct: usize,
    expert_correct: usize,
    /// Confusion counts for per-class PRF: `[class][0]`=tp, `[1]`=fp, `[2]`=fn.
    confusion: Vec<[u64; 3]>,
    handled: Vec<u64>,
    llm_calls: u64,
    flops: f64,
    snapshot_every: usize,
    /// Time series of snapshots.
    pub series: Vec<Snapshot>,
}

impl StreamMetrics {
    /// `n_levels` includes the expert as the last level.
    pub fn new(n_levels: usize, classes: usize, snapshot_every: usize) -> Self {
        StreamMetrics {
            n_levels,
            classes,
            total: 0,
            correct: 0,
            expert_correct: 0,
            confusion: vec![[0; 3]; classes],
            handled: vec![0; n_levels],
            llm_calls: 0,
            flops: 0.0,
            snapshot_every: snapshot_every.max(1),
            series: Vec::new(),
        }
    }

    /// Record one processed sample.
    ///
    /// `expert_would_be_correct` feeds the Figs 5–8 expert-reference
    /// line (the simulator can answer it without charging a call).
    pub fn record(
        &mut self,
        pred: usize,
        truth: usize,
        handled_by: usize,
        expert_called: bool,
        expert_would_be_correct: bool,
        flops: f64,
    ) {
        self.total += 1;
        if pred == truth {
            self.correct += 1;
        }
        if expert_would_be_correct {
            self.expert_correct += 1;
        }
        if pred == truth {
            self.confusion[pred][0] += 1;
        } else {
            self.confusion[pred][1] += 1;
            self.confusion[truth][2] += 1;
        }
        self.handled[handled_by.min(self.n_levels - 1)] += 1;
        if expert_called {
            self.llm_calls += 1;
        }
        self.flops += flops;
        if self.total % self.snapshot_every == 0 {
            self.push_snapshot();
        }
    }

    fn push_snapshot(&mut self) {
        let t = self.total.max(1) as f64;
        self.series.push(Snapshot {
            t: self.total,
            accuracy: self.correct as f64 / t,
            expert_accuracy: self.expert_correct as f64 / t,
            handled_frac: self.handled.iter().map(|&h| h as f64 / t).collect(),
            llm_calls: self.llm_calls,
            flops: self.flops,
        });
    }

    /// Force a final snapshot (end of stream).
    pub fn finalize(&mut self) {
        if self.series.last().map(|s| s.t) != Some(self.total) && self.total > 0 {
            self.push_snapshot();
        }
    }

    /// Samples processed.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Overall accuracy.
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }

    /// Expert-alone accuracy on the same stream.
    pub fn expert_accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.expert_correct as f64 / self.total as f64
        }
    }

    /// Recall for one class (HateSpeech reports class 1 = hate).
    pub fn recall(&self, class: usize) -> f64 {
        let tp = self.confusion[class][0] as f64;
        let fne = self.confusion[class][2] as f64;
        if tp + fne == 0.0 {
            0.0
        } else {
            tp / (tp + fne)
        }
    }

    /// Precision for one class.
    pub fn precision(&self, class: usize) -> f64 {
        let tp = self.confusion[class][0] as f64;
        let fp = self.confusion[class][1] as f64;
        if tp + fp == 0.0 {
            0.0
        } else {
            tp / (tp + fp)
        }
    }

    /// F1 for one class.
    pub fn f1(&self, class: usize) -> f64 {
        let p = self.precision(class);
        let r = self.recall(class);
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// Expert (LLM) calls charged.
    pub fn llm_calls(&self) -> u64 {
        self.llm_calls
    }

    /// Cumulative FLOPs.
    pub fn flops(&self) -> f64 {
        self.flops
    }

    /// Fraction of queries handled at each level.
    pub fn handled_fractions(&self) -> Vec<f64> {
        let t = self.total.max(1) as f64;
        self.handled.iter().map(|&h| h as f64 / t).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_and_routing() {
        let mut m = StreamMetrics::new(3, 2, 2);
        m.record(1, 1, 0, false, true, 10.0);
        m.record(0, 1, 1, false, true, 10.0);
        m.record(1, 1, 2, true, false, 100.0);
        m.record(0, 0, 0, false, true, 10.0);
        m.finalize();
        assert_eq!(m.total(), 4);
        assert!((m.accuracy() - 0.75).abs() < 1e-12);
        assert!((m.expert_accuracy() - 0.75).abs() < 1e-12);
        assert_eq!(m.llm_calls(), 1);
        assert_eq!(m.handled_fractions(), vec![0.5, 0.25, 0.25]);
        assert_eq!(m.flops(), 130.0);
        // snapshots at t=2, t=4
        assert_eq!(m.series.len(), 2);
        assert_eq!(m.series[1].t, 4);
    }

    #[test]
    fn prf_math() {
        let mut m = StreamMetrics::new(2, 2, 100);
        // class 1: 2 tp, 1 fn, 1 fp
        m.record(1, 1, 0, false, true, 0.0);
        m.record(1, 1, 0, false, true, 0.0);
        m.record(0, 1, 0, false, true, 0.0); // fn for 1
        m.record(1, 0, 0, false, true, 0.0); // fp for 1
        assert!((m.recall(1) - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.precision(1) - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.f1(1) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = StreamMetrics::new(2, 2, 10);
        assert_eq!(m.accuracy(), 0.0);
        assert_eq!(m.recall(1), 0.0);
        assert_eq!(m.precision(0), 0.0);
    }
}
