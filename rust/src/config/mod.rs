//! Configuration system: typed configs + per-benchmark presets that
//! mirror the paper's hyperparameter tables (Tables 3 and 4), JSON
//! round-trip for reproducible experiment specs.

use crate::codec::Json;
use crate::error::{Error, Result};

/// The four evaluation benchmarks (paper §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BenchmarkId {
    /// IMDB sentiment, 25 000 samples, 2 balanced classes.
    Imdb,
    /// HateSpeech, 10 703 samples, 2 classes at 1:7.95 imbalance.
    HateSpeech,
    /// ISEAR emotion, 7 666 samples, 7 classes.
    Isear,
    /// FEVER fact-checking, 6 512 samples, 2 classes, reasoning-hard.
    Fever,
}

impl BenchmarkId {
    /// All benchmarks in paper order.
    pub const ALL: [BenchmarkId; 4] =
        [BenchmarkId::Imdb, BenchmarkId::HateSpeech, BenchmarkId::Isear, BenchmarkId::Fever];

    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            BenchmarkId::Imdb => "imdb",
            BenchmarkId::HateSpeech => "hatespeech",
            BenchmarkId::Isear => "isear",
            BenchmarkId::Fever => "fever",
        }
    }

    /// Parse from CLI string.
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "imdb" => Ok(BenchmarkId::Imdb),
            "hatespeech" => Ok(BenchmarkId::HateSpeech),
            "isear" => Ok(BenchmarkId::Isear),
            "fever" => Ok(BenchmarkId::Fever),
            _ => Err(Error::Config(format!("unknown benchmark '{s}'"))),
        }
    }

    /// Number of label classes.
    pub fn classes(self) -> usize {
        match self {
            BenchmarkId::Isear => 7,
            _ => 2,
        }
    }

    /// Stream length (dataset size the paper processes).
    pub fn stream_len(self) -> usize {
        match self {
            BenchmarkId::Imdb => 25_000,
            BenchmarkId::HateSpeech => 10_703,
            BenchmarkId::Isear => 7_666,
            BenchmarkId::Fever => 6_512,
        }
    }
}

/// Which LLM plays the expert `m_N` (paper §4 runs both).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExpertId {
    /// GPT-3.5 Turbo profile.
    Gpt35,
    /// Llama 2 70B Chat profile.
    Llama70b,
}

impl ExpertId {
    /// Both expert profiles.
    pub const ALL: [ExpertId; 2] = [ExpertId::Gpt35, ExpertId::Llama70b];

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            ExpertId::Gpt35 => "gpt35",
            ExpertId::Llama70b => "llama70b",
        }
    }

    /// Parse from CLI string.
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "gpt35" | "gpt-3.5" => Ok(ExpertId::Gpt35),
            "llama70b" | "llama" => Ok(ExpertId::Llama70b),
            _ => Err(Error::Config(format!("unknown expert '{s}'"))),
        }
    }
}

/// Cascade level model kinds (the paper's LR / BERT-base / BERT-large).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Logistic regression over hashed bag-of-words (level 1).
    Lr,
    /// BERT-base surrogate transformer.
    TfmBase,
    /// BERT-large surrogate transformer.
    TfmLarge,
}

impl ModelKind {
    /// Artifact entry-point prefix (`lr`, `tfm_base`, `tfm_large`).
    pub fn entry_prefix(self) -> &'static str {
        match self {
            ModelKind::Lr => "lr",
            ModelKind::TfmBase => "tfm_base",
            ModelKind::TfmLarge => "tfm_large",
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Lr => "LR",
            ModelKind::TfmBase => "BERT-base",
            ModelKind::TfmLarge => "BERT-large",
        }
    }
}

/// Inference engine backing the cascade models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust mirrors (parity-tested vs PJRT) — fast sweeps, and the
    /// only backend in builds without the `pjrt` cargo feature.
    Host,
    /// AOT HLO artifacts through the PJRT CPU client — production path.
    /// Only exists when the crate is built with `--features pjrt`.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl Engine {
    /// Parse from CLI string.
    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "host" => Ok(Engine::Host),
            #[cfg(feature = "pjrt")]
            "pjrt" => Ok(Engine::Pjrt),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => Err(Error::Config(
                "engine 'pjrt' requires building with `--features pjrt`".into(),
            )),
            _ => Err(Error::Config(format!("unknown engine '{s}'"))),
        }
    }

    /// True when this is the PJRT engine. Always `false` without the
    /// `pjrt` feature — the single branch point the coordinator,
    /// baseline, and serving layers use, so they compile unchanged in
    /// both configurations.
    pub fn is_pjrt(self) -> bool {
        #[cfg(feature = "pjrt")]
        {
            matches!(self, Engine::Pjrt)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            false
        }
    }
}

/// Per-level hyperparameters — one row of the paper's Tables 3–4.
#[derive(Clone, Debug)]
pub struct LevelConfig {
    /// Which model runs at this level.
    pub model: ModelKind,
    /// Deferral penalty `c_{i+1}` charged for deferring past this level
    /// ("Model Cost" column).
    pub model_cost: f64,
    /// Annotation ring-cache capacity ("Cache Size").
    pub cache_size: usize,
    /// OGD minibatch size ("Batch Size").
    pub batch_size: usize,
    /// Calibration-MLP learning rate ("Learning Rate" — the paper's
    /// table refers to the MLPs, §B.3).
    pub mlp_lr: f32,
    /// Model learning rate (paper: BERT 1e-5; scaled for the surrogate).
    pub model_lr: f32,
    /// Per-level DAgger β multiplicative decay ("Decaying Factor").
    pub beta_decay: f64,
    /// Deferral threshold ("Calibration Factor"): defer when the
    /// calibrated score exceeds this.
    pub calibration: f64,
}

/// Complete cascade configuration.
#[derive(Clone, Debug)]
pub struct CascadeConfig {
    /// Levels `m_1 .. m_{N-1}` (the expert is level N, implicit).
    pub levels: Vec<LevelConfig>,
    /// Expert profile.
    pub expert: ExpertId,
    /// Deferral penalty for the final hop into the expert.
    pub expert_cost: f64,
    /// Cost weighting factor μ (paper Eq. C): trades accuracy vs cost.
    pub mu: f64,
    /// Initial DAgger jump probability β₁.
    pub beta0: f64,
    /// RNG seed for all stochastic components.
    pub seed: u64,
    /// Engine backing the models.
    pub engine: Engine,
}

impl CascadeConfig {
    /// The paper's **small cascade**: LR → BERT-base → LLM, with the
    /// hyperparameters of Tables 3–4 for `bench`/`expert`.
    pub fn small(bench: BenchmarkId, expert: ExpertId) -> Self {
        let llm_cost = match expert {
            ExpertId::Gpt35 => 1182.0,
            ExpertId::Llama70b => 636.0,
        };
        // Per-benchmark LR rows (Tables 3–4; identical across experts
        // except the BERT-base -> LLM cost).
        let (lr_mlp_lr, lr_decay, lr_calib) = match bench {
            BenchmarkId::HateSpeech => (0.001, 0.97, 0.4),
            BenchmarkId::Isear => (0.0007, 0.8, 0.15),
            _ => (0.0007, 0.97, 0.4),
        };
        let (bb_decay, bb_calib) = match bench {
            BenchmarkId::HateSpeech => (0.9, 0.4),
            BenchmarkId::Isear => (0.9, 0.45),
            _ => (0.95, 0.3),
        };
        CascadeConfig {
            levels: vec![
                LevelConfig {
                    model: ModelKind::Lr,
                    model_cost: 1.0,
                    cache_size: 8,
                    batch_size: 8,
                    mlp_lr: lr_mlp_lr,
                    model_lr: 0.5,
                    beta_decay: lr_decay,
                    calibration: lr_calib,
                },
                LevelConfig {
                    model: ModelKind::TfmBase,
                    model_cost: llm_cost,
                    cache_size: 16,
                    batch_size: 8,
                    mlp_lr: 0.0007,
                    model_lr: 2e-3,
                    beta_decay: bb_decay,
                    calibration: bb_calib,
                },
            ],
            expert,
            expert_cost: llm_cost,
            mu: 5e-4,
            beta0: 1.0,
            seed: 0,
            engine: Engine::Host,
        }
    }

    /// The paper's **large cascade** (§5.3): LR → BERT-base →
    /// BERT-large → LLM.
    pub fn large(bench: BenchmarkId, expert: ExpertId) -> Self {
        let llm_cost = match expert {
            ExpertId::Gpt35 => 1182.0,
            ExpertId::Llama70b => 636.0,
        };
        let mut cfg = CascadeConfig::small(bench, expert);
        let (lr_decay, lr_calib) = match bench {
            BenchmarkId::HateSpeech => (0.99, 0.45),
            BenchmarkId::Isear => (0.99, 0.4),
            _ => (0.99, 0.45),
        };
        cfg.levels = vec![
            LevelConfig {
                model: ModelKind::Lr,
                model_cost: 1.0,
                cache_size: 8,
                batch_size: 8,
                mlp_lr: if bench == BenchmarkId::HateSpeech { 0.001 } else { 0.0007 },
                model_lr: 0.5,
                beta_decay: lr_decay,
                calibration: lr_calib,
            },
            LevelConfig {
                model: ModelKind::TfmBase,
                model_cost: 3.0,
                cache_size: 16,
                batch_size: 8,
                mlp_lr: 0.0007,
                model_lr: 2e-3,
                beta_decay: 0.97,
                calibration: if bench == BenchmarkId::HateSpeech { 0.45 } else { 0.4 },
            },
            LevelConfig {
                model: ModelKind::TfmLarge,
                model_cost: llm_cost,
                cache_size: 32,
                batch_size: 16,
                mlp_lr: 0.0007,
                model_lr: 2e-3,
                beta_decay: if bench == BenchmarkId::Fever { 0.93 } else { 0.95 },
                calibration: match bench {
                    BenchmarkId::HateSpeech => 0.45,
                    BenchmarkId::Isear => 0.3,
                    _ => 0.4,
                },
            },
        ];
        cfg.expert_cost = llm_cost;
        cfg
    }

    /// Number of cascade levels including the expert (paper's N).
    pub fn n_levels(&self) -> usize {
        self.levels.len() + 1
    }

    /// JSON encoding (reports, replayable configs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("expert", Json::Str(self.expert.name().into())),
            ("expert_cost", Json::Num(self.expert_cost)),
            ("mu", Json::Num(self.mu)),
            ("beta0", Json::Num(self.beta0)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "levels",
                Json::Arr(
                    self.levels
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("model", Json::Str(l.model.name().into())),
                                ("model_cost", Json::Num(l.model_cost)),
                                ("cache_size", Json::Num(l.cache_size as f64)),
                                ("batch_size", Json::Num(l.batch_size as f64)),
                                ("mlp_lr", Json::Num(l.mlp_lr as f64)),
                                ("model_lr", Json::Num(l.model_lr as f64)),
                                ("beta_decay", Json::Num(l.beta_decay)),
                                ("calibration", Json::Num(l.calibration)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Scale-out topology: router shards × per-level worker replicas.
///
/// `shards = 1, replicas_per_level = 1, sync_interval = 0` is the
/// single-router topology and reproduces it bit-for-bit (the learner
/// parity pinned by `tests/test_serve_load.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardConfig {
    /// Number of independent routers behind the front dispatcher.
    pub shards: usize,
    /// Worker-pool capacity per cascade level per shard. Worker 0 is
    /// the *learner authority* (applies all training); workers 1.. are
    /// read-only inference replicas fed by published snapshots.
    pub replicas_per_level: usize,
    /// Cross-shard annotation broadcast: every `sync_interval` expert
    /// annotations a shard replicates them to its peers so every
    /// shard's learners converge toward the single-learner trajectory.
    /// 0 disables the broadcast.
    pub sync_interval: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { shards: 1, replicas_per_level: 1, sync_interval: 0 }
    }
}

impl ShardConfig {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("replicas_per_level", Json::Num(self.replicas_per_level as f64)),
            ("sync_interval", Json::Num(self.sync_interval as f64)),
        ])
    }
}

/// Serve-layer knobs: dynamic batching + admission control +
/// supervision + scale-out topology. The router in `serve::Server`
/// owns no hyperparameters of its own — everything operationally
/// tunable lives here so experiment specs can pin it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeConfig {
    /// Max jobs per inference batch dispatched to a level worker.
    pub batch_max: usize,
    /// Max time the oldest *enqueued* job may wait before its level's
    /// batch is flushed regardless of fill (measured from the job's own
    /// enqueue instant, so partial drains never re-arm the deadline).
    pub deadline: std::time::Duration,
    /// Admission bound: when this many requests are in the system
    /// (admitted, unanswered), new arrivals are shed with an immediate
    /// `shed` response instead of growing the router's state without
    /// bound. Sheds are counted separately in [`crate::serve::ServeReport`].
    pub max_pending: usize,
    /// Respawn budget per level — a supervision loop exceeding it
    /// indicates a deterministic crash (bad config/artifacts), not a
    /// transient fault. Reported back in [`crate::serve::ServeReport`].
    pub max_restarts: usize,
    /// Model-training triggers between snapshot publications by each
    /// level's learner authority (pool layer). 0 disables publication —
    /// replicas then serve init weights and respawns are cold.
    pub publish_every: usize,
    /// Expert annotations between durable checkpoints when a checkpoint
    /// directory is configured (`serve::ckpt`). Each cadence checkpoint
    /// is a quiescent barrier: the router briefly stops admitting,
    /// drains in-flight work, then snapshots — which is what makes a
    /// resumed trajectory bit-identical (DESIGN.md §9). 0 disables the
    /// cadence; the graceful-shutdown checkpoint is still written.
    pub ckpt_every: usize,
    /// How long a cadence-checkpoint barrier may wait for a level
    /// authority to export its weights. A timeout with the authority
    /// still alive *aborts* the attempt (admission resumes, the next
    /// cadence re-arms) instead of wedging the barrier — liveness over
    /// checkpoint freshness. The graceful-shutdown checkpoint ignores
    /// this bound: with the stream drained there is nothing to stall.
    pub export_timeout: std::time::Duration,
    /// Scale-out topology (shards × replicas × sync cadence).
    pub shard: ShardConfig,
    /// Pipelined level execution: deferred (and speculative) jobs are
    /// dispatched through bounded per-level *stage queues* the moment a
    /// replica frees up, instead of waiting for the next batch-deadline
    /// sweep — L0 inference for batch N overlaps with L1 inference for
    /// batch N−1. Inference scheduling only; the learner trajectory is
    /// bit-identical either way (DESIGN.md §13).
    pub pipeline: bool,
    /// Speculative dispatch threshold: when a level's calibrated score
    /// exceeds this *and* the gate defers, the request is already on its
    /// way to level k+1 speculatively the moment the level-k result
    /// lands — the gate's own decision then either consumes or discards
    /// the speculative result. Valid range (0, 1]; `1.0` disables
    /// speculation (a calibrated score never strictly exceeds it).
    /// Speculation is inference-only: gates alone decide what trains.
    pub spec_threshold: f64,
    /// Capacity of each per-level stage queue when `pipeline` is on.
    /// Overflowing *deferred* jobs fall back to the regular batcher
    /// (backpressure without loss); overflowing *speculative* jobs are
    /// dropped (they were optional work).
    pub stage_queue_depth: usize,
    /// Queue-driven autoscaling: let the router grow/shrink each
    /// level's replica pool at runtime off live queue depth
    /// (`serve::scale`). Off by default — the topology stays exactly
    /// what `replicas_per_level` pins.
    pub autoscale: bool,
    /// Autoscale floor on replicas per level (≥ 1: the learner
    /// authority itself is never scaled away). Ignored unless
    /// `autoscale` is on.
    pub replicas_min: usize,
    /// Autoscale ceiling on replicas per level. Ignored unless
    /// `autoscale` is on.
    pub replicas_max: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_max: 8,
            deadline: std::time::Duration::from_millis(2),
            max_pending: 1024,
            max_restarts: 16,
            publish_every: 4,
            ckpt_every: 64,
            export_timeout: std::time::Duration::from_secs(60),
            shard: ShardConfig::default(),
            pipeline: false,
            spec_threshold: 1.0,
            stage_queue_depth: 64,
            autoscale: false,
            replicas_min: 1,
            replicas_max: 1,
        }
    }
}

impl ServeConfig {
    /// Start a validated builder — the only construction path that
    /// checks knob combinations up front (`build` returns
    /// [`Error::Config`] on nonsense) and the home of the
    /// pipeline/speculation knobs.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    /// JSON encoding (serve reports / replayable load specs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch_max", Json::Num(self.batch_max as f64)),
            ("deadline_us", Json::Num(self.deadline.as_micros() as f64)),
            ("max_pending", Json::Num(self.max_pending as f64)),
            ("max_restarts", Json::Num(self.max_restarts as f64)),
            ("publish_every", Json::Num(self.publish_every as f64)),
            ("ckpt_every", Json::Num(self.ckpt_every as f64)),
            ("export_timeout_us", Json::Num(self.export_timeout.as_micros() as f64)),
            ("shard", self.shard.to_json()),
            ("pipeline", Json::Bool(self.pipeline)),
            ("spec_threshold", Json::Num(self.spec_threshold)),
            ("stage_queue_depth", Json::Num(self.stage_queue_depth as f64)),
            ("autoscale", Json::Bool(self.autoscale)),
            ("replicas_min", Json::Num(self.replicas_min as f64)),
            ("replicas_max", Json::Num(self.replicas_max as f64)),
        ])
    }
}

/// Builder for [`ServeConfig`] with up-front validation.
///
/// Every setter mirrors a `ServeConfig` field (shard topology fields
/// get their own setters so callers never hand-build a
/// [`ShardConfig`]); `build()` rejects degenerate combinations with
/// [`Error::Config`] instead of letting them surface as a wedged
/// router at runtime, and `build_with_warnings()` additionally surfaces
/// suspicious-but-legal combinations as human-readable strings.
#[derive(Clone, Debug)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    /// Max jobs per inference batch.
    pub fn batch_max(mut self, v: usize) -> Self {
        self.cfg.batch_max = v;
        self
    }

    /// Batch-flush deadline for the oldest enqueued job.
    pub fn deadline(mut self, v: std::time::Duration) -> Self {
        self.cfg.deadline = v;
        self
    }

    /// Admission bound before shedding.
    pub fn max_pending(mut self, v: usize) -> Self {
        self.cfg.max_pending = v;
        self
    }

    /// Per-level supervision respawn budget.
    pub fn max_restarts(mut self, v: usize) -> Self {
        self.cfg.max_restarts = v;
        self
    }

    /// Training triggers between snapshot publications.
    pub fn publish_every(mut self, v: usize) -> Self {
        self.cfg.publish_every = v;
        self
    }

    /// Expert annotations between cadence checkpoints (0 disables).
    pub fn ckpt_every(mut self, v: usize) -> Self {
        self.cfg.ckpt_every = v;
        self
    }

    /// Barrier export-timeout bound.
    pub fn export_timeout(mut self, v: std::time::Duration) -> Self {
        self.cfg.export_timeout = v;
        self
    }

    /// Number of router shards.
    pub fn shards(mut self, v: usize) -> Self {
        self.cfg.shard.shards = v;
        self
    }

    /// Worker replicas per cascade level per shard.
    pub fn replicas_per_level(mut self, v: usize) -> Self {
        self.cfg.shard.replicas_per_level = v;
        self
    }

    /// Cross-shard annotation broadcast cadence (0 disables).
    pub fn sync_interval(mut self, v: usize) -> Self {
        self.cfg.shard.sync_interval = v;
        self
    }

    /// Pipelined level execution on/off.
    pub fn pipeline(mut self, v: bool) -> Self {
        self.cfg.pipeline = v;
        self
    }

    /// Speculative-dispatch threshold in (0, 1]; `1.0` disables.
    pub fn spec_threshold(mut self, v: f64) -> Self {
        self.cfg.spec_threshold = v;
        self
    }

    /// Per-level stage-queue capacity for the pipelined path.
    pub fn stage_queue_depth(mut self, v: usize) -> Self {
        self.cfg.stage_queue_depth = v;
        self
    }

    /// Queue-driven autoscaling on/off.
    pub fn autoscale(mut self, v: bool) -> Self {
        self.cfg.autoscale = v;
        self
    }

    /// Autoscale floor on replicas per level (≥ 1).
    pub fn replicas_min(mut self, v: usize) -> Self {
        self.cfg.replicas_min = v;
        self
    }

    /// Autoscale ceiling on replicas per level.
    pub fn replicas_max(mut self, v: usize) -> Self {
        self.cfg.replicas_max = v;
        self
    }

    /// Validate and produce the config (warnings discarded).
    pub fn build(self) -> Result<ServeConfig> {
        self.build_with_warnings().map(|(cfg, _)| cfg)
    }

    /// Validate and produce the config plus non-fatal warnings
    /// (suspicious-but-legal combinations, e.g. a checkpoint cadence
    /// tighter than the cross-shard sync interval).
    pub fn build_with_warnings(self) -> Result<(ServeConfig, Vec<String>)> {
        let cfg = self.cfg;
        if cfg.batch_max == 0 {
            return Err(Error::Config("serve: batch_max must be positive".into()));
        }
        if cfg.max_pending == 0 {
            return Err(Error::Config("serve: max_pending must be positive".into()));
        }
        if cfg.stage_queue_depth == 0 {
            return Err(Error::Config(
                "serve: stage_queue_depth must be positive".into(),
            ));
        }
        if !(cfg.spec_threshold > 0.0 && cfg.spec_threshold <= 1.0) {
            return Err(Error::Config(format!(
                "serve: spec_threshold must be in (0, 1], got {}",
                cfg.spec_threshold
            )));
        }
        if cfg.shard.shards == 0 {
            return Err(Error::Config("serve: shards must be positive".into()));
        }
        if cfg.shard.replicas_per_level == 0 {
            return Err(Error::Config(
                "serve: replicas_per_level must be positive".into(),
            ));
        }
        if cfg.autoscale {
            if cfg.replicas_min == 0 {
                return Err(Error::Config(
                    "serve: replicas_min must be positive".into(),
                ));
            }
            if cfg.replicas_min > cfg.replicas_max {
                return Err(Error::Config(format!(
                    "serve: replicas_min ({}) must not exceed replicas_max ({})",
                    cfg.replicas_min, cfg.replicas_max
                )));
            }
            let r = cfg.shard.replicas_per_level;
            if r < cfg.replicas_min || r > cfg.replicas_max {
                return Err(Error::Config(format!(
                    "serve: replicas_per_level ({r}) must start inside the \
                     autoscale bounds [{}, {}]",
                    cfg.replicas_min, cfg.replicas_max
                )));
            }
        }
        let mut warnings = Vec::new();
        if cfg.ckpt_every != 0
            && cfg.shard.sync_interval != 0
            && cfg.ckpt_every < cfg.shard.sync_interval
        {
            warnings.push(format!(
                "serve: ckpt_every ({}) < sync_interval ({}) — cadence \
                 checkpoints will fire faster than cross-shard annotation \
                 sync, so restored shards may lag their peers' annotations",
                cfg.ckpt_every, cfg.shard.sync_interval
            ));
        }
        if cfg.spec_threshold < 1.0 && !cfg.pipeline {
            warnings.push(format!(
                "serve: spec_threshold ({}) enables speculation but \
                 pipeline is off — speculative jobs will ride the regular \
                 batcher and gain little latency",
                cfg.spec_threshold
            ));
        }
        Ok((cfg, warnings))
    }
}

/// Global dimension constants — must agree with `python/compile/model.py`
/// (the manifest carries them; `runtime` asserts agreement at load).
pub mod dims {
    /// Hashed bag-of-words dimensionality (LR input).
    pub const HASH_DIM: usize = 4096;
    /// Transformer sequence length.
    pub const SEQ_LEN: usize = 64;
    /// Transformer vocabulary size.
    pub const VOCAB: usize = 8192;
    /// Online-update minibatch size compiled into the step artifacts.
    pub const BATCH_STEP: usize = 8;
    /// Forward batch sizes compiled into the artifacts.
    pub const BATCHES_FWD: [usize; 2] = [1, 8];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benchmark_meta() {
        assert_eq!(BenchmarkId::Isear.classes(), 7);
        assert_eq!(BenchmarkId::Imdb.classes(), 2);
        assert_eq!(BenchmarkId::Imdb.stream_len(), 25_000);
        assert_eq!(BenchmarkId::from_name("fever").unwrap(), BenchmarkId::Fever);
        assert!(BenchmarkId::from_name("nope").is_err());
    }

    #[test]
    fn small_cascade_matches_tables() {
        let c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        assert_eq!(c.levels.len(), 2);
        assert_eq!(c.levels[0].model_cost, 1.0);
        assert_eq!(c.levels[1].model_cost, 1182.0);
        assert_eq!(c.levels[0].cache_size, 8);
        assert_eq!(c.levels[1].cache_size, 16);
        let c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Llama70b);
        assert_eq!(c.levels[1].model_cost, 636.0);
        let c = CascadeConfig::small(BenchmarkId::Isear, ExpertId::Gpt35);
        assert_eq!(c.levels[0].beta_decay, 0.8);
        assert_eq!(c.levels[0].calibration, 0.15);
    }

    #[test]
    fn large_cascade_has_three_levels() {
        let c = CascadeConfig::large(BenchmarkId::Fever, ExpertId::Llama70b);
        assert_eq!(c.levels.len(), 3);
        assert_eq!(c.n_levels(), 4);
        assert_eq!(c.levels[1].model_cost, 3.0);
        assert_eq!(c.levels[2].model_cost, 636.0);
        assert_eq!(c.levels[2].cache_size, 32);
        assert_eq!(c.levels[2].batch_size, 16);
        assert_eq!(c.levels[2].beta_decay, 0.93);
    }

    #[test]
    fn json_roundtrip_parses() {
        let c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        let j = c.to_json().to_string_pretty();
        let v = crate::codec::parse(&j).unwrap();
        assert_eq!(v.get("expert").unwrap().as_str(), Some("gpt35"));
        assert_eq!(v.get("levels").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        // Full round-trip over the richest config type: encode → parse
        // → re-encode must be a fixed point, and every hyperparameter
        // of Tables 3–4 must survive the trip bit-for-bit (f64-exact
        // for the table constants used here).
        for cfg in [
            CascadeConfig::small(BenchmarkId::HateSpeech, ExpertId::Llama70b),
            CascadeConfig::large(BenchmarkId::Fever, ExpertId::Gpt35),
        ] {
            let j = cfg.to_json();
            for text in [j.to_string_compact(), j.to_string_pretty()] {
                let v = crate::codec::parse(&text).unwrap();
                assert_eq!(v, j, "parse(encode(cfg)) must equal the Json value");
                assert_eq!(v.get("expert").unwrap().as_str(), Some(cfg.expert.name()));
                assert_eq!(v.get("mu").unwrap().as_f64(), Some(cfg.mu));
                assert_eq!(
                    v.get("expert_cost").unwrap().as_f64(),
                    Some(cfg.expert_cost)
                );
                let levels = v.get("levels").unwrap().as_arr().unwrap();
                assert_eq!(levels.len(), cfg.levels.len());
                for (lv, lc) in levels.iter().zip(&cfg.levels) {
                    assert_eq!(lv.get("model").unwrap().as_str(), Some(lc.model.name()));
                    assert_eq!(lv.get("model_cost").unwrap().as_f64(), Some(lc.model_cost));
                    assert_eq!(lv.get("cache_size").unwrap().as_usize(), Some(lc.cache_size));
                    assert_eq!(lv.get("batch_size").unwrap().as_usize(), Some(lc.batch_size));
                    assert_eq!(lv.get("beta_decay").unwrap().as_f64(), Some(lc.beta_decay));
                    assert_eq!(lv.get("calibration").unwrap().as_f64(), Some(lc.calibration));
                }
            }
        }
    }

    #[test]
    fn serve_config_defaults_and_json() {
        let s = ServeConfig::default();
        assert_eq!(s.batch_max, 8);
        assert_eq!(s.max_pending, 1024);
        assert_eq!(s.deadline, std::time::Duration::from_millis(2));
        assert_eq!(s.max_restarts, 16);
        assert_eq!(s.publish_every, 4);
        assert_eq!(s.ckpt_every, 64);
        assert_eq!(s.export_timeout, std::time::Duration::from_secs(60));
        assert_eq!(s.shard, ShardConfig::default());
        assert!(!s.pipeline);
        assert_eq!(s.spec_threshold, 1.0);
        assert_eq!(s.stage_queue_depth, 64);
        assert!(!s.autoscale);
        assert_eq!(s.replicas_min, 1);
        assert_eq!(s.replicas_max, 1);
        let v = crate::codec::parse(&s.to_json().to_string_compact()).unwrap();
        assert_eq!(v.get("batch_max").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("deadline_us").unwrap().as_f64(), Some(2000.0));
        assert_eq!(v.get("max_pending").unwrap().as_usize(), Some(1024));
        assert_eq!(v.get("max_restarts").unwrap().as_usize(), Some(16));
        assert_eq!(v.get("ckpt_every").unwrap().as_usize(), Some(64));
        assert_eq!(v.get("export_timeout_us").unwrap().as_f64(), Some(60_000_000.0));
        assert_eq!(v.get("pipeline").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("spec_threshold").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("stage_queue_depth").unwrap().as_usize(), Some(64));
        assert_eq!(v.get("autoscale").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("replicas_min").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("replicas_max").unwrap().as_usize(), Some(1));
        let sh = v.get("shard").unwrap();
        assert_eq!(sh.get("shards").unwrap().as_usize(), Some(1));
        assert_eq!(sh.get("replicas_per_level").unwrap().as_usize(), Some(1));
        assert_eq!(sh.get("sync_interval").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn serve_builder_happy_path_matches_default() {
        // An untouched builder must reproduce Default exactly, and the
        // setter surface must cover every knob.
        let built = ServeConfig::builder().build().unwrap();
        assert_eq!(built, ServeConfig::default());
        let cfg = ServeConfig::builder()
            .batch_max(4)
            .deadline(std::time::Duration::from_millis(1))
            .max_pending(2048)
            .max_restarts(3)
            .publish_every(2)
            .ckpt_every(32)
            .export_timeout(std::time::Duration::from_secs(5))
            .shards(2)
            .replicas_per_level(3)
            .sync_interval(16)
            .pipeline(true)
            .spec_threshold(0.5)
            .stage_queue_depth(8)
            .autoscale(true)
            .replicas_min(2)
            .replicas_max(5)
            .build()
            .unwrap();
        assert_eq!(cfg.batch_max, 4);
        assert_eq!(cfg.max_pending, 2048);
        assert_eq!(cfg.shard.shards, 2);
        assert_eq!(cfg.shard.replicas_per_level, 3);
        assert_eq!(cfg.shard.sync_interval, 16);
        assert!(cfg.pipeline);
        assert_eq!(cfg.spec_threshold, 0.5);
        assert_eq!(cfg.stage_queue_depth, 8);
        assert!(cfg.autoscale);
        assert_eq!(cfg.replicas_min, 2);
        assert_eq!(cfg.replicas_max, 5);
    }

    #[test]
    fn serve_builder_rejects_nonsense_combos() {
        for (b, what) in [
            (ServeConfig::builder().batch_max(0), "batch_max"),
            (ServeConfig::builder().max_pending(0), "max_pending"),
            (ServeConfig::builder().stage_queue_depth(0), "stage_queue_depth"),
            (ServeConfig::builder().spec_threshold(0.0), "spec_threshold"),
            (ServeConfig::builder().spec_threshold(-0.2), "spec_threshold"),
            (ServeConfig::builder().spec_threshold(1.5), "spec_threshold"),
            (ServeConfig::builder().spec_threshold(f64::NAN), "spec_threshold"),
            (ServeConfig::builder().shards(0), "shards"),
            (ServeConfig::builder().replicas_per_level(0), "replicas_per_level"),
            (ServeConfig::builder().autoscale(true).replicas_min(0), "replicas_min"),
            (
                ServeConfig::builder().autoscale(true).replicas_min(4).replicas_max(2),
                "replicas_min",
            ),
            (
                // replicas_per_level defaults to 1, below the floor.
                ServeConfig::builder().autoscale(true).replicas_min(2).replicas_max(4),
                "replicas_per_level",
            ),
        ] {
            let err = b.build().unwrap_err().to_string();
            assert!(err.contains(what), "expected '{what}' in: {err}");
        }
        // The boundary is inclusive at 1.0 (= disabled), exclusive at 0.
        assert!(ServeConfig::builder().spec_threshold(1.0).build().is_ok());
        assert!(ServeConfig::builder().spec_threshold(1e-9).build().is_ok());
        // Autoscale bounds are only enforced when autoscale is on, and a
        // replica count inside them is accepted.
        assert!(ServeConfig::builder().replicas_min(0).build().is_ok());
        assert!(ServeConfig::builder()
            .autoscale(true)
            .replicas_min(1)
            .replicas_max(4)
            .replicas_per_level(2)
            .build()
            .is_ok());
    }

    #[test]
    fn serve_builder_warns_without_failing() {
        // ckpt cadence tighter than the sync interval: legal, flagged.
        let (cfg, warnings) = ServeConfig::builder()
            .shards(2)
            .sync_interval(100)
            .ckpt_every(10)
            .build_with_warnings()
            .unwrap();
        assert_eq!(cfg.ckpt_every, 10);
        assert!(
            warnings.iter().any(|w| w.contains("ckpt_every")),
            "{warnings:?}"
        );
        // Speculation without pipelining: legal, flagged.
        let (_, warnings) = ServeConfig::builder()
            .spec_threshold(0.3)
            .build_with_warnings()
            .unwrap();
        assert!(
            warnings.iter().any(|w| w.contains("spec_threshold")),
            "{warnings:?}"
        );
        // The quiet path stays quiet.
        let (_, warnings) = ServeConfig::builder().build_with_warnings().unwrap();
        assert!(warnings.is_empty(), "{warnings:?}");
    }

    #[test]
    fn engine_parsing_matches_build_features() {
        assert_eq!(Engine::from_name("host").unwrap(), Engine::Host);
        assert!(!Engine::Host.is_pjrt());
        assert!(Engine::from_name("warp").is_err());
        #[cfg(feature = "pjrt")]
        {
            assert!(Engine::from_name("pjrt").unwrap().is_pjrt());
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let err = Engine::from_name("pjrt").unwrap_err().to_string();
            assert!(err.contains("pjrt"), "{err}");
        }
    }
}
