//! Mini property-based testing framework (no `proptest` offline).
//!
//! [`check`] runs a property over `n` seeded random cases; on failure
//! it re-runs a bounded shrink loop that retries the failing case with
//! "smaller" seeds derived from the failure, then panics with the
//! smallest reproducer seed. Tests write generators as plain
//! `fn(&mut Rng) -> T`.

use crate::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (vary per property to decorrelate).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: DEFAULT_SEED }
    }
}

const DEFAULT_SEED: u64 = 0x9E37_79B9;

/// Run `prop` over `cases` random inputs produced by `gen`.
///
/// Panics with the reproducer seed on the first falsified case.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    for case in 0..cases {
        let case_seed = DEFAULT_SEED ^ (case as u64).wrapping_mul(0xA24B_AED4_963E_E407);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' falsified at case {case} (seed {case_seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Run a property that needs its own Rng (e.g. stateful simulations).
pub fn check_seeded<P>(name: &str, cases: usize, mut prop: P)
where
    P: FnMut(&mut Rng) -> bool,
{
    for case in 0..cases {
        let case_seed = DEFAULT_SEED ^ (case as u64).wrapping_mul(0xD134_2543_DE82_EF95);
        let mut rng = Rng::new(case_seed);
        if !prop(&mut rng) {
            panic!("property '{name}' falsified at case {case} (seed {case_seed:#x})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 10, |rng| rng.below(100), |_| {
            // count via closure side effect is fine here
            true
        });
        check_seeded("count2", 10, |_| {
            n += 1;
            true
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_panics_with_seed() {
        check("always-false", 5, |rng| rng.below(10), |_| false);
    }
}
