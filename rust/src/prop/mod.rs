//! Mini property-based testing framework (no `proptest` offline).
//!
//! [`check`] runs a property over `n` seeded random cases; the first
//! falsified case panics with a **reproducer seed**. Feeding that seed
//! to [`recheck`] (or [`recheck_seeded`]) replays exactly the same
//! generated input, so failures shrink to a one-line deterministic
//! repro instead of a flaky CI log. Tests write generators as plain
//! `fn(&mut Rng) -> T`.

use crate::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (vary per property to decorrelate).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: DEFAULT_SEED }
    }
}

const DEFAULT_SEED: u64 = 0x9E37_79B9;

/// Per-case seed for [`check`] — public so a failure's reported case
/// index can also be mapped back to its seed.
pub fn case_seed(case: usize) -> u64 {
    DEFAULT_SEED ^ (case as u64).wrapping_mul(0xA24B_AED4_963E_E407)
}

/// Per-case seed for [`check_seeded`].
pub fn case_seed_stateful(case: usize) -> u64 {
    DEFAULT_SEED ^ (case as u64).wrapping_mul(0xD134_2543_DE82_EF95)
}

/// Run `prop` over `cases` random inputs produced by `gen`.
///
/// Panics with the reproducer seed on the first falsified case.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    for case in 0..cases {
        let seed = case_seed(case);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' falsified at case {case} (seed {seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Run a property that needs its own Rng (e.g. stateful simulations).
pub fn check_seeded<P>(name: &str, cases: usize, mut prop: P)
where
    P: FnMut(&mut Rng) -> bool,
{
    for case in 0..cases {
        let seed = case_seed_stateful(case);
        let mut rng = Rng::new(seed);
        if !prop(&mut rng) {
            panic!("property '{name}' falsified at case {case} (seed {seed:#x})");
        }
    }
}

/// Replay one [`check`] case from a reproducer seed: regenerates the
/// input and re-evaluates the property. Returns `(input, held)`.
/// Deterministic — the same seed always replays the same case.
pub fn recheck<T, G, P>(seed: u64, mut gen: G, mut prop: P) -> (T, bool)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    let held = prop(&input);
    (input, held)
}

/// Replay one [`check_seeded`] case from a reproducer seed.
pub fn recheck_seeded<P>(seed: u64, mut prop: P) -> bool
where
    P: FnMut(&mut Rng) -> bool,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng)
}

/// Extract the `seed 0x…` reproducer from a [`check`]/[`check_seeded`]
/// panic message.
pub fn parse_reproducer_seed(msg: &str) -> Option<u64> {
    let at = msg.find("seed 0x")? + "seed 0x".len();
    let hex: String = msg[at..]
        .chars()
        .take_while(|c| c.is_ascii_hexdigit())
        .collect();
    u64::from_str_radix(&hex, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 10, |rng| rng.below(100), |_| {
            // count via closure side effect is fine here
            true
        });
        check_seeded("count2", 10, |_| {
            n += 1;
            true
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_panics_with_seed() {
        check("always-false", 5, |rng| rng.below(10), |_| false);
    }

    // --- self-tests of the reproducer-seed contract ---------------------

    /// Deliberately falsifiable: `below(1000)` exceeds 9 almost always.
    fn gen_u(rng: &mut Rng) -> usize {
        rng.below(1000)
    }
    fn prop_small(x: &usize) -> bool {
        *x < 10
    }

    fn panic_message(err: Box<dyn std::any::Any + Send>) -> String {
        match err.downcast::<String>() {
            Ok(s) => *s,
            Err(err) => err
                .downcast::<&'static str>()
                .map(|s| s.to_string())
                .unwrap_or_else(|_| "<non-string panic>".into()),
        }
    }

    #[test]
    fn falsified_check_reports_a_seed_that_replays_the_failure() {
        let err = std::panic::catch_unwind(|| check("repro", 64, gen_u, prop_small))
            .expect_err("property must be falsified within 64 cases");
        let msg = panic_message(err);
        assert!(msg.contains("falsified"), "{msg}");
        let seed =
            parse_reproducer_seed(&msg).expect("panic message must carry a seed");
        // Rerunning with the reported seed reproduces the failure …
        let (a, held_a) = recheck(seed, gen_u, prop_small);
        assert!(!held_a, "reproducer seed must refail (input {a})");
        // … deterministically: same seed, same input, same verdict.
        let (b, held_b) = recheck(seed, gen_u, prop_small);
        assert_eq!(a, b, "replay must regenerate the identical input");
        assert!(!held_b);
        // The reported input is embedded in the message too.
        assert!(msg.contains(&format!("{a}")), "{msg} should mention {a}");
    }

    #[test]
    fn falsified_check_seeded_seed_replays() {
        let err = std::panic::catch_unwind(|| {
            check_seeded("repro2", 16, |rng| rng.below(100) < 2)
        })
        .expect_err("must falsify");
        let seed = parse_reproducer_seed(&panic_message(err)).expect("seed");
        assert!(!recheck_seeded(seed, |rng| rng.below(100) < 2));
        // and the seed matches the published derivation for its case
        assert!(
            (0..16).any(|c| case_seed_stateful(c) == seed),
            "seed must come from the documented per-case derivation"
        );
    }

    #[test]
    fn case_seed_derivations_are_stable_and_distinct() {
        assert_eq!(case_seed(0), DEFAULT_SEED);
        assert_ne!(case_seed(1), case_seed(2));
        assert_ne!(case_seed(3), case_seed_stateful(3));
        assert_eq!(parse_reproducer_seed("seed 0xdead_beef"), Some(0xdead));
        assert_eq!(parse_reproducer_seed(&format!("(seed {:#x})", u64::MAX)), Some(u64::MAX));
        assert_eq!(parse_reproducer_seed("no seed here"), None);
    }
}
