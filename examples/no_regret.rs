//! Theory check: the empirical no-regret property (Theorem 3.2).
//!
//! Runs online cascade learning with the MDP cost accounting of §2 and
//! tracks γ/T — the average regret against the best *fixed* exit-level
//! policy in hindsight — which must trend toward ≤ 0 as T grows.
//!
//! ```bash
//! cargo run --release --example no_regret
//! ```

use ocl::cascade::Cascade;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId};
use ocl::data::Benchmark;
use ocl::sim::{Expert, ExpertProfile};

fn main() -> ocl::Result<()> {
    let bench = BenchmarkId::Imdb;
    let n = 4000;
    let b = Benchmark::build_sized(bench, 17, n);
    let mean_len = b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, bench),
        b.strata_fractions(),
        mean_len,
        17,
    );
    let cfg = CascadeConfig::small(bench, ExpertId::Gpt35);
    let mut c = Cascade::new(cfg, b.classes, expert, None, n + 1)?;
    c.set_threshold_scale(0.7);
    c.enable_regret_tracking(200);
    c.run_stream(&b.stream());

    let rt = c.regret.as_ref().expect("tracking enabled");
    println!("{:>7} {:>14}", "T", "avg regret γ/T");
    for (t, r) in &rt.trace {
        println!("{t:>7} {r:>14.5}");
    }
    println!(
        "\nbest fixed policy in hindsight: always exit at level {} \
         (J = {:.1} vs learned J = {:.1})",
        rt.best_fixed_level(),
        rt.j_best_fixed(),
        rt.j_learned()
    );
    println!(
        "final average regret: {:.5} (Theorem 3.2: → ≤ 0 as T → ∞)",
        rt.average_regret()
    );
    Ok(())
}
