//! End-to-end serving driver — the full three-layer system on a real
//! workload: AOT HLO artifacts (Pallas kernels inside) executed through
//! PJRT from rust worker threads, behind the request router + dynamic
//! batcher, with online cascade learning active. Reports latency
//! percentiles and throughput. This is the run recorded in
//! DESIGN.md §10 (End-to-end).
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example serve_stream
//! # host engine (no artifacts or pjrt feature needed): --engine host
//! # over a real socket (wire protocol + loopback client): --listen 127.0.0.1:0
//! ```

use std::sync::mpsc::channel;

use ocl::config::{BenchmarkId, CascadeConfig, Engine, ExpertId};
use ocl::data::Benchmark;
use ocl::serve::shard::ShardFront;
use ocl::serve::{load, net};
use ocl::sim::{Expert, ExpertProfile};

/// Prefer PJRT when the build and the artifacts allow it.
#[cfg(feature = "pjrt")]
fn auto_engine() -> Engine {
    if ocl::runtime::artifacts_available(ocl::runtime::DEFAULT_ARTIFACTS_DIR) {
        Engine::Pjrt
    } else {
        eprintln!("artifacts/ not found — falling back to the host engine");
        Engine::Host
    }
}

/// Feature-off twin of [`auto_engine`]: only the host engine exists.
#[cfg(not(feature = "pjrt"))]
fn auto_engine() -> Engine {
    eprintln!("built without the `pjrt` feature — using the host engine");
    Engine::Host
}

fn main() -> ocl::Result<()> {
    // One shared flag table (`cli::ServeArgs`) for this example, `ocl
    // serve`, and the wire client — flags and defaults cannot drift.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help") {
        print!("{}", ocl::cli::ServeArgs::command().help());
        return Ok(());
    }
    let sa = ocl::cli::ServeArgs::parse(&argv)?;
    // An explicit `--engine <name>` is honored strictly (erroring in
    // builds that cannot provide it); only the unspecified case
    // auto-selects.
    let engine = match sa.engine.as_deref() {
        Some(name) => Engine::from_name(name)?,
        None => auto_engine(),
    };
    let n = sa.requests;
    // Open-loop offered load (req/s); 0 = submit as fast as possible.
    let rate = sa.rate;
    // Scale-out topology: router shards and per-level worker replicas.
    let (shards, replicas) = (sa.shards, sa.replicas);
    // Durability: `--ckpt-dir <dir>` persists the learner state;
    // `--resume strict|best-effort` restores it first.
    let ckpt = sa.ckpt_options()?;

    let bench = BenchmarkId::Imdb;
    let b = Benchmark::build_sized(bench, 7, n);
    let mean_len = b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, bench),
        b.strata_fractions(),
        mean_len,
        7,
    );
    let mut cfg = CascadeConfig::small(bench, ExpertId::Gpt35);
    cfg.engine = engine;
    println!(
        "engine: {engine:?}, requests: {n}, shards: {shards}, replicas: {replicas}"
    );

    // Validated construction through the builder; the broadcast only
    // activates when shards > 1 (ShardFront wires it). `--pipeline` /
    // `--spec-threshold` / `--stage-depth` flow through here too.
    let serve_cfg = sa.serve_config()?;
    let mut front = ShardFront::with_ckpt(
        cfg,
        b.classes,
        expert,
        serve_cfg,
        ocl::runtime::DEFAULT_ARTIFACTS_DIR,
        ckpt,
    )?;
    front.set_threshold_scale(0.7);
    // A restored run resubmits only the stream tail, original ids kept.
    let cursor = (front.resume_cursor() as usize).min(n);

    // Open-loop submission: a positive --rate drives a Poisson arrival
    // process; 0 degenerates to back-to-back submission.
    let arrival = load::Arrival::Poisson { rate: if rate > 0.0 { rate } else { 1e9 } };
    // `--listen <addr>` puts the whole front behind the wire protocol
    // (`serve::net`) and drives the identical stream through a real
    // loopback socket; the default stays on in-process channels.
    let (report, client_correct, client_total) = match sa.listen.clone() {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(&addr)
                .map_err(|e| ocl::Error::io(&addr, e))?;
            let bound = listener
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or(addr);
            println!("serving over TCP on {bound}");
            let server = std::thread::spawn(move || net::serve(front, listener));
            let client =
                net::Client::connect_retry(&bound, std::time::Duration::from_secs(10))?;
            let submit = load::drive_from(
                b.samples[cursor..].to_vec(),
                arrival,
                7,
                client.request_sender(),
                cursor as u64,
            );
            submit.join().ok();
            let (responses, _wire_report) = client.finish()?;
            let report = server
                .join()
                .map_err(|_| ocl::Error::Worker("serve thread panicked".into()))??;
            let mut correct = 0usize;
            let mut total = 0usize;
            for r in responses.iter().filter(|r| !r.shed) {
                total += 1;
                if r.pred == r.truth {
                    correct += 1;
                }
            }
            (report, correct, total)
        }
        None => {
            let (req_tx, req_rx) = channel();
            let (resp_tx, resp_rx) = channel::<ocl::serve::Response>();
            let submit = load::drive_from(
                b.samples[cursor..].to_vec(),
                arrival,
                7,
                req_tx,
                cursor as u64,
            );
            let drain = std::thread::spawn(move || {
                let mut correct = 0usize;
                let mut total = 0usize;
                for r in resp_rx.iter() {
                    if r.shed {
                        continue; // shed responses carry no prediction
                    }
                    total += 1;
                    if r.pred == r.truth {
                        correct += 1;
                    }
                }
                (correct, total)
            });
            let report = front.serve(req_rx, resp_tx)?;
            submit.join().ok();
            let (correct, total) = drain.join().unwrap_or((0, 0));
            (report, correct, total)
        }
    };

    let lat = report.latency_ms();
    println!("\n== serving report ==");
    println!("shards              {}", report.shards.len());
    println!("served              {}", report.served());
    println!("wall                {:.2} s", report.wall_secs);
    println!("throughput          {:.0} req/s", report.throughput());
    println!(
        "latency p50/p95/p99 {:.2} / {:.2} / {:.2} ms",
        lat.pct(50.0),
        lat.pct(95.0),
        lat.pct(99.0)
    );
    println!(
        "p99 direct/deferred {:.2} / {:.2} ms",
        report.latency_direct_ms().pct(99.0),
        report.latency_deferred_ms().pct(99.0)
    );
    println!(
        "speculation         hits={} wasted={} queue_depth={:?}",
        report.spec_hits(),
        report.spec_wasted(),
        report.queue_depth()
    );
    println!("accuracy            {:.2}%", report.accuracy() * 100.0);
    println!(
        "client-side check   {}/{} correct",
        client_correct, client_total
    );
    println!("llm calls           {}", report.llm_calls());
    println!("max snapshot lag    {} train chunks", report.max_snapshot_lag());
    println!(
        "durability          resumed={} cursor={} ckpts={}",
        report.resumed(),
        cursor,
        report.ckpts()
    );
    for (i, r) in report.shards.iter().enumerate() {
        println!(
            "shard {i}: served {} shed {} handled {:?} restarts {:?} (cap {}) \
             warm {:?} snapshots {:?} lag {:?} replica-jobs {:?}",
            r.served,
            r.shed,
            r.handled,
            r.restarts,
            r.restart_cap,
            r.warm_respawns,
            r.snapshots,
            r.snapshot_lag,
            r.replica_jobs
        );
    }
    assert_eq!(
        report.served() + report.shed(),
        n,
        "every request must be answered (served or shed)"
    );
    Ok(())
}
