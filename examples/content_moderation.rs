//! Domain scenario: streaming content moderation (the paper's
//! HateSpeech motivation) — heavy class imbalance (1:7.95), where the
//! operational metric is *recall* on the rare harmful class, and the
//! cascade must cut LLM cost without missing hate speech.
//!
//! Demonstrates: per-class PRF metrics, budgeted operation, and the
//! calibrated-deferral vs max-prob ablation on imbalanced data.
//!
//! ```bash
//! cargo run --release --example content_moderation
//! ```

use ocl::cascade::{Cascade, DeferralRule};
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId};
use ocl::data::Benchmark;
use ocl::sim::{Expert, ExpertProfile};

fn run(rule: DeferralRule, label: &str) -> ocl::Result<()> {
    let bench = BenchmarkId::HateSpeech;
    let n = 4000;
    let b = Benchmark::build_sized(bench, 11, n);
    let mean_len = b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, bench),
        b.strata_fractions(),
        mean_len,
        11,
    );
    let cfg = CascadeConfig::small(bench, ExpertId::Gpt35);
    let mut c = Cascade::new(cfg, b.classes, expert, None, n + 1)?;
    c.set_threshold_scale(0.7);
    c.set_deferral_rule(rule);
    // ~paper budget N=507/10703 ≈ 4.7% of the stream
    c.set_budget(Some((n as f64 * 0.06) as u64));
    c.run_stream(&b.stream());
    let m = &c.metrics;
    println!(
        "{label:<22} acc={:.2}% recall(hate)={:.2}% precision={:.2}% \
         f1={:.2}% llm_calls={} ({:.1}% of stream)",
        m.accuracy() * 100.0,
        m.recall(1) * 100.0,
        m.precision(1) * 100.0,
        m.f1(1) * 100.0,
        m.llm_calls(),
        m.llm_calls() as f64 / n as f64 * 100.0,
    );
    Ok(())
}

fn main() -> ocl::Result<()> {
    println!("streaming content moderation: 1:7.95 imbalance, budget ~6%\n");
    run(DeferralRule::Calibrated, "calibrated (paper)")?;
    run(DeferralRule::MaxProb(0.8), "max-prob baseline")?;
    run(DeferralRule::Entropy(0.45), "entropy baseline")?;
    println!(
        "\nThe calibrated deferral learns that 'confident' predictions on \
         the rare class\nare often wrong under imbalance — the ablation \
         shows the fixed-threshold rules\ntrading recall away silently."
    );
    Ok(())
}
