//! Quickstart: build a 3-level cascade (LR → BERT-surrogate → LLM
//! expert), stream an IMDB-like workload through it, and watch the
//! cheap levels take over from the expert while accuracy holds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ocl::cascade::Cascade;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId};
use ocl::data::Benchmark;
use ocl::sim::{Expert, ExpertProfile};

fn main() -> ocl::Result<()> {
    let bench = BenchmarkId::Imdb;
    let expert_id = ExpertId::Gpt35;
    let n = 4000;

    // 1. A benchmark stream (synthetic IMDB-calibrated generator) and
    //    the simulated LLM expert (accuracy-calibrated to GPT-3.5).
    let benchmark = Benchmark::build_sized(bench, 42, n);
    let mean_len =
        benchmark.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(expert_id, bench),
        benchmark.strata_fractions(),
        mean_len,
        42,
    );

    // 2. The cascade, with the paper's Table 3 hyperparameters.
    let cfg = CascadeConfig::small(bench, expert_id);
    let mut cascade = Cascade::new(cfg, benchmark.classes, expert, None, 400)?;
    cascade.set_threshold_scale(0.7); // the featured operating point

    // 3. Stream the queries — Algorithm 1 runs online, no human labels.
    println!("{:>6} {:>9} {:>12} {:>22}", "t", "acc", "expert_acc", "handled (lr/bert/llm)");
    for s in benchmark.stream() {
        cascade.process(s);
        let m = &cascade.metrics;
        if m.total() % 400 == 0 {
            let f = m.handled_fractions();
            println!(
                "{:>6} {:>8.2}% {:>11.2}% {:>9.2}/{:.2}/{:.2}",
                m.total(),
                m.accuracy() * 100.0,
                m.expert_accuracy() * 100.0,
                f[0],
                f[1],
                f[2]
            );
        }
    }

    let m = &cascade.metrics;
    let savings = 1.0 - m.llm_calls() as f64 / n as f64;
    println!(
        "\nfinal: accuracy {:.2}% (expert alone {:.2}%), {} LLM calls \
         out of {} queries — {:.0}% inference-cost savings",
        m.accuracy() * 100.0,
        m.expert_accuracy() * 100.0,
        m.llm_calls(),
        n,
        savings * 100.0
    );
    Ok(())
}
