//! Domain scenario: robustness to input distribution shift (§5.4) —
//! the same IMDB stream served (a) i.i.d., (b) sorted by length
//! (semantic-complexity drift), (c) with a whole category held out
//! until the final third of the stream ("comedy reviews last").
//!
//! ```bash
//! cargo run --release --example distribution_shift
//! ```

use ocl::config::{BenchmarkId, ExpertId};
use ocl::data::{StreamOrder, IMDB_HELDOUT_CATEGORY};
use ocl::eval::Harness;

fn main() -> ocl::Result<()> {
    let h = Harness::new(0.12, 5);
    let budget = Some(900u64);
    let scenarios: [(&str, StreamOrder); 3] = [
        ("i.i.d. (natural)", StreamOrder::Natural),
        ("length-ascending", StreamOrder::LengthAscending),
        ("category-holdout", StreamOrder::CategoryHoldout(IMDB_HELDOUT_CATEGORY)),
    ];
    println!("IMDB, budget {} LLM calls, stream {}\n", 900, h.stream_len(BenchmarkId::Imdb));
    let mut base = None;
    for (name, order) in scenarios {
        let (r, _) = h.run_ocl(BenchmarkId::Imdb, ExpertId::Gpt35, budget, false, order)?;
        let delta = base
            .map(|b: f64| format!("{:+.2} pts", (r.accuracy - b) * 100.0))
            .unwrap_or_else(|| "baseline".into());
        if base.is_none() {
            base = Some(r.accuracy);
        }
        println!(
            "{name:<20} acc={:.2}%  llm_calls={}  ({delta})",
            r.accuracy * 100.0,
            r.llm_calls
        );
    }
    println!(
        "\nOnline learning adapts within the stream: shifts cost at most a \
         fraction of a point\n(paper Table 2: -0.54 / +0.08 pts), because the \
         cascade re-opens its gates when the\ncalibrators see unfamiliar inputs."
    );
    Ok(())
}
