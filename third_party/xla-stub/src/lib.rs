//! Offline stub of the `xla` (PJRT) crate API surface used by `ocl`.
//!
//! Shapes and element counts are tracked honestly so argument
//! validation in `ocl::runtime` behaves; every execution entry point
//! errors with [`STUB_MSG`]. See README.md for how to swap in the real
//! crate.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// The message every unimplemented execution path reports.
pub const STUB_MSG: &str =
    "xla stub: built against third_party/xla-stub — patch in the real `xla` \
     crate to execute HLO artifacts";

/// Stub error type (mirrors `xla::Error`'s `Display`/`Error` role).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

/// Parsed HLO module (stub: retains nothing).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. The stub only checks the file exists so
    /// missing-artifact errors surface with the right path.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error(format!("no such HLO file: {}", p.display())));
        }
        Ok(HloModuleProto { _private: () })
    }
}

/// Computation wrapper (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. The stub cannot create one: `cpu()` always
/// errors, so engine construction fails fast with [`STUB_MSG`].
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU client (always errors in the stub).
    pub fn cpu() -> Result<Self> {
        stub_err()
    }

    /// Compile a computation (unreachable: no client can exist).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

/// Compiled executable handle (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments (unreachable: never constructed).
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// Device buffer handle (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal (unreachable: never constructed).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Host literal: the stub tracks shape/element count only (enough for
/// `ocl::runtime`'s arity and element-count validation).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: Vec<i64>,
    elems: usize,
}

impl Literal {
    /// Rank-0 scalar literal.
    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal { shape: Vec::new(), elems: 1 }
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal { shape: vec![data.len() as i64], elems: data.len() }
    }

    /// Reshape; errors on element-count mismatch like the real crate.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.elems {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.elems
            )));
        }
        Ok(Literal { shape: dims.to_vec(), elems: self.elems })
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.elems
    }

    /// Literal shape (stub bookkeeping).
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Copy out as a host vec (no data in the stub: always errors).
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        stub_err()
    }

    /// Split a tuple literal (no data in the stub: always errors).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_bookkeeping() {
        let l = Literal::vec1(&[0f32; 12]);
        assert_eq!(l.element_count(), 12);
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.shape(), &[3, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
        assert_eq!(Literal::scalar(1.0f32).element_count(), 1);
    }

    #[test]
    fn execution_paths_error_with_stub_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
        let mut l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.decompose_tuple().is_err());
    }

    #[test]
    fn hlo_file_existence_is_checked() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
