//! PJRT runtime integration: load real AOT artifacts, execute them,
//! and assert parity with the host-engine mirrors.
//!
//! Double-gated: the whole file compiles only with `--features pjrt`
//! (default builds produce an empty, trivially-green test binary), and
//! each test additionally skips gracefully unless
//! `artifacts/manifest.json` exists (build with `make artifacts`) — so
//! plain `cargo test` stays green in a fresh offline checkout.
#![cfg(feature = "pjrt")]

use std::rc::Rc;

use ocl::config::dims::{BATCH_STEP, HASH_DIM};
use ocl::config::ModelKind;
use ocl::hostmodel::{HostLr, HostMlp, HostTfm, TfmArch};
use ocl::models::{Calibrator, Featurized, LevelModel, Pipeline, PjrtCalibrator, PjrtLevel};
use ocl::prng::Rng;
use ocl::runtime::{artifacts_available, PjrtEngine};

const DIR: &str = "artifacts";

fn engine() -> Option<Rc<PjrtEngine>> {
    if !artifacts_available(DIR) {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Rc::new(PjrtEngine::from_dir(DIR).expect("engine")))
}

fn sample_doc(rng: &mut Rng) -> Featurized {
    let p = Pipeline::default();
    let n = 5 + rng.below(40);
    let text: Vec<String> = (0..n)
        .map(|_| format!("kw{}x{:03} c0w{:04}", rng.below(2), rng.below(40), rng.below(100)))
        .collect();
    p.featurize(&text.join(" "))
}

#[test]
fn lr_forward_parity_host_vs_pjrt() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1);
    // Identical parameters: both sides start from the (zero) init blob.
    let flat = e.manifest().load_group_flat("lr_c2").expect("blob");
    let host = HostLr::from_flat(HASH_DIM, 2, &flat);
    let mut pjrt = PjrtLevel::new(e, ModelKind::Lr, 2).expect("level");
    for _ in 0..5 {
        let f = sample_doc(&mut rng);
        let hp = host.predict(&f.x);
        let pp = pjrt.predict(&f);
        for (a, b) in hp.iter().zip(&pp) {
            assert!((a - b).abs() < 1e-4, "host {hp:?} pjrt {pp:?}");
        }
    }
}

#[test]
fn lr_training_parity_host_vs_pjrt() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(2);
    let flat = e.manifest().load_group_flat("lr_c2").expect("blob");
    let mut host = HostLr::from_flat(HASH_DIM, 2, &flat);
    let mut pjrt = PjrtLevel::new(e, ModelKind::Lr, 2).expect("level");
    let docs: Vec<Featurized> = (0..BATCH_STEP).map(|_| sample_doc(&mut rng)).collect();
    let ys: Vec<usize> = (0..BATCH_STEP).map(|_| rng.below(2)).collect();
    // Train both for 3 steps on the same batch.
    for _ in 0..3 {
        let xs: Vec<&[f32]> = docs.iter().map(|d| d.x.as_slice()).collect();
        host.train_batch(&xs, &ys, 0.3);
        let batch: Vec<(&Featurized, usize)> =
            docs.iter().zip(ys.iter().copied()).collect();
        pjrt.train(&batch, 0.3);
    }
    // Predictions must agree after identical updates.
    let f = sample_doc(&mut rng);
    let hp = host.predict(&f.x);
    let pp = pjrt.predict(&f);
    for (a, b) in hp.iter().zip(&pp) {
        assert!((a - b).abs() < 1e-3, "host {hp:?} pjrt {pp:?}");
    }
}

#[test]
fn tfm_forward_parity_host_vs_pjrt() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(3);
    let flat = e.manifest().load_group_flat("tfm_base_c2").expect("blob");
    let host = HostTfm::from_flat(TfmArch::Base, 2, &flat);
    let mut pjrt = PjrtLevel::new(e, ModelKind::TfmBase, 2).expect("level");
    for _ in 0..3 {
        let f = sample_doc(&mut rng);
        let hp = host.predict(&f.ids, &f.mask);
        let pp = pjrt.predict(&f);
        for (a, b) in hp.iter().zip(&pp) {
            assert!(
                (a - b).abs() < 1e-4,
                "host {hp:?} pjrt {pp:?} (architecture mirror drifted)"
            );
        }
    }
}

#[test]
fn tfm_batched_forward_matches_single() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(4);
    let mut pjrt = PjrtLevel::new(e, ModelKind::TfmBase, 2).expect("level");
    let docs: Vec<Featurized> = (0..8).map(|_| sample_doc(&mut rng)).collect();
    let refs: Vec<&Featurized> = docs.iter().collect();
    let batched = pjrt.predict_batch(&refs);
    for (i, f) in docs.iter().enumerate() {
        let single = pjrt.predict(f);
        for (a, b) in single.iter().zip(&batched[i]) {
            assert!((a - b).abs() < 1e-5, "row {i}: {single:?} vs {:?}", batched[i]);
        }
    }
}

#[test]
fn tfm_training_reduces_loss_through_pjrt() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(5);
    let mut pjrt = PjrtLevel::new(e, ModelKind::TfmBase, 2).expect("level");
    let docs: Vec<Featurized> = (0..BATCH_STEP).map(|_| sample_doc(&mut rng)).collect();
    let ys: Vec<usize> = (0..BATCH_STEP).map(|_| rng.below(2)).collect();
    let batch: Vec<(&Featurized, usize)> = docs.iter().zip(ys.iter().copied()).collect();
    let l0 = pjrt.train(&batch, 5e-3);
    let mut l = l0;
    for _ in 0..6 {
        l = pjrt.train(&batch, 5e-3);
    }
    assert!(l < l0, "loss {l} !< {l0}");
}

#[test]
fn mlp_calibrator_scores_and_trains_through_pjrt() {
    let Some(e) = engine() else { return };
    let flat = e.manifest().load_group_flat("mlp_c2").expect("blob");
    let mut host = HostMlp::from_flat(2, &flat);
    let mut pjrt = PjrtCalibrator::new(e, 2).expect("calibrator");
    // Score parity at init.
    for p in [[0.5f32, 0.5], [0.9, 0.1], [0.02, 0.98]] {
        let hs = host.predict(&p);
        let ps = pjrt.score(&p);
        assert!((hs - ps).abs() < 1e-4, "host {hs} pjrt {ps}");
    }
    // Training moves scores in the right direction.
    let lo = [0.55f32, 0.45];
    let hi = [0.98f32, 0.02];
    for _ in 0..200 {
        let batch: Vec<(&[f32], f32)> = (0..BATCH_STEP)
            .map(|i| {
                if i % 2 == 0 {
                    (&lo[..], 1.0f32)
                } else {
                    (&hi[..], 0.0f32)
                }
            })
            .collect();
        pjrt.train(&batch, 0.2);
    }
    assert!(pjrt.score(&lo) > pjrt.score(&hi));
}

#[test]
fn engine_caches_compilations() {
    let Some(e) = engine() else { return };
    assert_eq!(e.compiled_count(), 0);
    let _ = e.executable("lr_fwd_c2_b1").expect("compile");
    let _ = e.executable("lr_fwd_c2_b1").expect("cache hit");
    assert_eq!(e.compiled_count(), 1);
}

#[test]
fn engine_rejects_bad_arity_and_shape() {
    let Some(e) = engine() else { return };
    // wrong arity
    assert!(e.run("lr_fwd_c2_b1", &[]).is_err());
    // wrong element count
    let bad = xla::Literal::vec1(&[0f32; 8]);
    let w = xla::Literal::vec1(&vec![0f32; HASH_DIM * 2]);
    let b = xla::Literal::vec1(&[0f32; 2]);
    assert!(e.run("lr_fwd_c2_b1", &[&bad, &w, &b]).is_err());
}
