//! Snapshot-layer contracts: bit-for-bit weight extraction/restore for
//! every host model and calibrator, including the JSON round-trip that
//! moves state across processes — the substrate the pool layer's
//! replica fan-out and warm respawn are built on (DESIGN.md §9).

use ocl::codec;
use ocl::config::ModelKind;
use ocl::models::{
    Calibrator, HostCalibrator, HostLrLevel, HostTfmLevel, LevelModel, Pipeline,
    Snapshot,
};
use ocl::prng::Rng;

fn docs(n: usize, seed: u64) -> Vec<ocl::models::Featurized> {
    let p = Pipeline::default();
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let words: Vec<String> = (0..8)
                .map(|_| format!("kw{}x{:03}", rng.below(2), rng.below(40)))
                .collect();
            p.featurize(&words.join(" "))
        })
        .collect()
}

/// Train a little, snapshot, push through JSON text, restore into a
/// freshly initialized twin, and demand bit-identical predictions on
/// held-out inputs — for both training state and a post-restore train
/// step (restored state must *continue* identically, not just predict).
fn roundtrip_level(mut model: Box<dyn LevelModel>, mut fresh: Box<dyn LevelModel>) {
    let ds = docs(24, 9);
    for chunk in ds[..16].chunks(8) {
        let batch: Vec<(&ocl::models::Featurized, usize)> =
            chunk.iter().enumerate().map(|(i, f)| (f, i % 2)).collect();
        model.train(&batch, 0.05);
    }
    let snap = model.snapshot().expect("host models must snapshot");
    let text = snap.to_json().to_string_pretty();
    let back = Snapshot::from_json(&codec::parse(&text).unwrap()).unwrap();
    assert_eq!(back, snap, "JSON round-trip must be bit-for-bit");

    for f in &ds[16..] {
        assert_ne!(
            fresh.predict(f),
            model.predict(f),
            "trained weights must differ from init for the test to bite"
        );
    }
    fresh.restore(&back).unwrap();
    for f in &ds[16..] {
        assert_eq!(fresh.predict(f), model.predict(f), "restore must be exact");
    }
    // identical continuation: one more identical train step on both
    let batch: Vec<(&ocl::models::Featurized, usize)> =
        ds[16..].iter().enumerate().map(|(i, f)| (f, i % 2)).collect();
    model.train(&batch, 0.05);
    fresh.train(&batch, 0.05);
    for f in &ds[..4] {
        assert_eq!(
            fresh.predict(f),
            model.predict(f),
            "post-restore training must stay on the same trajectory"
        );
    }
}

#[test]
fn lr_snapshot_roundtrips_bit_for_bit() {
    roundtrip_level(Box::new(HostLrLevel::new(2)), Box::new(HostLrLevel::new(2)));
}

#[test]
fn tfm_base_snapshot_roundtrips_bit_for_bit() {
    roundtrip_level(
        Box::new(HostTfmLevel::new(ModelKind::TfmBase, 2, 11)),
        Box::new(HostTfmLevel::new(ModelKind::TfmBase, 2, 999)),
    );
}

#[test]
fn tfm_large_snapshot_roundtrips_bit_for_bit() {
    roundtrip_level(
        Box::new(HostTfmLevel::new(ModelKind::TfmLarge, 7, 13)),
        Box::new(HostTfmLevel::new(ModelKind::TfmLarge, 7, 131)),
    );
}

#[test]
fn calibrator_snapshot_roundtrips_bit_for_bit() {
    let mut c = HostCalibrator::new(2, 21);
    let lo: &[f32] = &[0.55, 0.45];
    let hi: &[f32] = &[0.97, 0.03];
    for _ in 0..50 {
        c.train(&[(lo, 1.0f32), (hi, 0.0f32)], 0.05);
    }
    let snap = Calibrator::snapshot(&c).expect("host calibrator must snapshot");
    let back =
        Snapshot::from_json(&codec::parse(&snap.to_json().to_string_compact()).unwrap())
            .unwrap();
    let mut fresh = HostCalibrator::new(2, 22);
    assert_ne!(fresh.score(lo), c.score(lo));
    fresh.restore(&back).unwrap();
    assert_eq!(fresh.score(lo), c.score(lo));
    assert_eq!(fresh.score(hi), c.score(hi));
}

#[test]
fn foreign_snapshots_are_rejected() {
    let lr2 = HostLrLevel::new(2).snapshot().unwrap();
    // wrong classes
    let mut lr7 = HostLrLevel::new(7);
    assert!(lr7.restore(&lr2).is_err());
    // wrong kind
    let mut tfm = HostTfmLevel::new(ModelKind::TfmBase, 2, 0);
    assert!(tfm.restore(&lr2).is_err());
    // wrong arch within the same classes
    let base = HostTfmLevel::new(ModelKind::TfmBase, 2, 0).snapshot().unwrap();
    let mut large = HostTfmLevel::new(ModelKind::TfmLarge, 2, 0);
    assert!(large.restore(&base).is_err());
    // model blob into a calibrator
    let mut c = HostCalibrator::new(2, 0);
    assert!(c.restore(&lr2).is_err());
    // truncated blob of the right kind/classes
    let mut cut = lr2.clone();
    cut.data.pop();
    let mut lr = HostLrLevel::new(2);
    assert!(lr.restore(&cut).is_err());
}

#[test]
fn snapshot_json_shape_is_stable() {
    let snap = HostLrLevel::new(2).snapshot().unwrap();
    let v = codec::parse(&snap.to_json().to_string_compact()).unwrap();
    assert_eq!(v.get("kind").unwrap().as_str(), Some("lr"));
    assert_eq!(v.get("classes").unwrap().as_usize(), Some(2));
    assert_eq!(
        v.get("data").unwrap().as_arr().unwrap().len(),
        snap.data.len()
    );
}
