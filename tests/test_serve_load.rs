//! Serve-layer load, supervision, and learner-parity tests (host
//! engine; no artifacts required): worker-death recovery under an
//! open-loop arrival process (warm respawn from the latest snapshot),
//! overload shedding with a bounded router, multi-shard/multi-replica
//! scale-out, and the Server ↔ Cascade parity invariants (per-level
//! DAgger β trajectories, training-batch counts) that pin the two
//! online learners together.

use std::sync::mpsc::channel;

use ocl::cascade::Cascade;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId, ServeConfig};
use ocl::data::Benchmark;
use ocl::serve::shard::{shard_of, ShardFront};
use ocl::serve::{load, Chaos, Request, Response, Server};
use ocl::sim::{Expert, ExpertProfile};

fn expert_for(b: &Benchmark, seed: u64) -> Expert {
    let mean_len =
        b.samples.iter().map(|s| s.len as f64).sum::<f64>() / b.samples.len() as f64;
    Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
        b.strata_fractions(),
        mean_len,
        seed,
    )
}

/// A ServeConfig that never sheds (parity / recovery runs).
fn unbounded() -> ServeConfig {
    ServeConfig::builder().max_pending(1 << 16).build().unwrap()
}

/// Blast the whole benchmark into the request channel with no pacing.
fn blast(b: &Benchmark) -> (std::sync::mpsc::Receiver<Request>, std::thread::JoinHandle<()>) {
    let (req_tx, req_rx) = channel();
    let samples = b.samples.clone();
    let h = std::thread::spawn(move || {
        for (i, s) in samples.iter().enumerate() {
            if req_tx
                .send(Request {
                    id: i as u64,
                    text: s.text.clone(),
                    truth: s.label,
                    sample: s.clone(),
                })
                .is_err()
            {
                break;
            }
        }
    });
    (req_rx, h)
}

fn assert_answered_exactly_once(responses: &[Response], n: usize) {
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "some request answered 0 or 2+ times");
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
}

#[test]
fn worker_death_mid_stream_recovers_and_meets_slo() {
    let n = 400;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 31, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 31;
        c
    };
    let mut server =
        Server::new(cfg, b.classes, expert_for(&b, 31), unbounded(), "artifacts")
            .unwrap();
    server.inject_chaos(Chaos { kill_level: 0, kill_replica: 0, after_requests: 50 });

    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    // Open-loop Poisson arrivals: the kill lands mid-stream while the
    // generator keeps submitting on its own clock.
    let submit =
        load::drive(b.samples.clone(), load::Arrival::Poisson { rate: 4000.0 }, 7, req_tx);
    let report = server.serve(req_rx, resp_tx).unwrap();
    assert_eq!(submit.join().unwrap(), n);

    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_eq!(responses.len(), n);
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.served + report.shed, n);
    assert_eq!(report.shed, 0, "unbounded run must not shed");
    assert!(
        report.restarts.iter().sum::<usize>() >= 1,
        "injected worker death must be detected and repaired: {:?}",
        report.restarts
    );
    assert_eq!(report.handled.iter().sum::<usize>(), report.served);
    // Latency SLO: generous bounds (shared CI machines), but the run
    // must stay sane through the respawn window — a supervisor stall
    // or requeue livelock would blow these by orders of magnitude.
    load::Slo { p50_ms: 500.0, p99_ms: 5_000.0 }
        .check(&report.latency_ms)
        .unwrap();
}

#[test]
fn overload_sheds_and_bounds_the_router() {
    let n = 1200;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 33, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 33;
        c
    };
    let serve_cfg = ServeConfig::builder().max_pending(16).build().unwrap();
    let server =
        Server::new(cfg, b.classes, expert_for(&b, 33), serve_cfg, "artifacts").unwrap();

    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = server.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();

    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_eq!(responses.len(), n, "shed requests are still answered");
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.served + report.shed, n);
    assert!(
        report.shed > 0,
        "arrival rate >> service rate must shed (served {}, shed {})",
        report.served,
        report.shed
    );
    assert!(
        report.peak_pending <= 16,
        "admission bound violated: peak {}",
        report.peak_pending
    );
    assert_eq!(
        responses.iter().filter(|r| r.shed).count(),
        report.shed,
        "shed responses must be marked as such"
    );
    // shed responses carry the virtual shed level, served ones do not
    for r in &responses {
        assert_eq!(r.shed, r.handled_by == report.handled.len());
    }
}

#[test]
fn worker_death_after_training_respawns_warm() {
    // The warm-respawn acceptance: by the time the kill lands (after
    // 120 admissions with β₁ = 1 early, training has certainly fired
    // and published), the supervisor must restore the replacement from
    // the latest snapshot — not reset it to fresh weights.
    let n = 400;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 37, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 37;
        c
    };
    let serve_cfg = ServeConfig::builder()
        .max_pending(1 << 16)
        .publish_every(1)
        .build()
        .unwrap();
    let mut server =
        Server::new(cfg, b.classes, expert_for(&b, 37), serve_cfg, "artifacts").unwrap();
    server.inject_chaos(Chaos { kill_level: 0, kill_replica: 0, after_requests: 120 });

    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = server.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert!(
        report.restarts[0] >= 1,
        "injected death must be detected: {:?}",
        report.restarts
    );
    assert!(
        report.snapshots[0] >= 1,
        "publish_every = 1 with training must have published: {:?}",
        report.snapshots
    );
    assert_eq!(
        report.warm_respawns, report.restarts,
        "every respawn after the first publication must restore the snapshot"
    );
    assert_eq!(report.restart_cap, serve_cfg.max_restarts);
}

#[test]
fn restart_cap_is_configurable_and_enforced() {
    // A zero budget turns the first injected death into a hard error —
    // the satellite contract that the 16/level constant became config.
    let n = 200;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 39, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 39;
        c
    };
    let serve_cfg = ServeConfig::builder()
        .max_pending(1 << 16)
        .max_restarts(0)
        .build()
        .unwrap();
    let mut server =
        Server::new(cfg, b.classes, expert_for(&b, 39), serve_cfg, "artifacts").unwrap();
    server.inject_chaos(Chaos { kill_level: 0, kill_replica: 0, after_requests: 20 });
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let err = server.serve(req_rx, resp_tx).unwrap_err();
    submit.join().unwrap();
    drop(resp_rx);
    assert!(
        err.to_string().contains("restarts"),
        "cap breach must name the budget: {err}"
    );
}

#[test]
fn two_shards_two_replicas_answer_exactly_once_and_sync_learning() {
    let n = 600;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 49, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 49;
        c
    };
    let serve_cfg = ServeConfig::builder()
        .max_pending(1 << 16)
        .shards(2)
        .replicas_per_level(2)
        .sync_interval(8)
        .build()
        .unwrap();
    let front =
        ShardFront::new(cfg, b.classes, expert_for(&b, 49), serve_cfg, "artifacts")
            .unwrap();
    assert_eq!(front.shards(), 2);
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = front.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.served() + report.shed(), n);
    assert_eq!(report.shed(), 0, "unbounded run must not shed");
    // traffic actually split across the shards
    for (s, r) in report.shards.iter().enumerate() {
        assert!(
            r.served + r.shed >= n / 8,
            "shard {s} starved: {} of {n}",
            r.served
        );
        // pool shape: 2 members per level, and the topology knobs echo
        for lvl in &r.replica_jobs {
            assert_eq!(lvl.len(), 2);
        }
    }
    // the dispatcher hash and the per-shard serve counts agree
    let mut want = vec![0usize; 2];
    for id in 0..n as u64 {
        want[shard_of(id, 2)] += 1;
    }
    let got: Vec<usize> = report.shards.iter().map(|r| r.served + r.shed).collect();
    assert_eq!(got, want);
    // cross-shard sync: every shard's every level trained, including
    // from annotations its own traffic never bought
    for (s, r) in report.shards.iter().enumerate() {
        assert!(
            r.train_batches.iter().all(|&t| t > 0),
            "shard {s} levels must all train under sync: {:?}",
            r.train_batches
        );
    }
    // snapshot machinery ran and staleness is reported
    assert!(
        report.shards.iter().any(|r| r.snapshots.iter().any(|&p| p > 0)),
        "snapshots must publish under training"
    );
    let _ = report.max_snapshot_lag(); // reported (0 is fine at drain)
    load::Slo { p50_ms: 2_000.0, p99_ms: 20_000.0 }.check_sharded(&report).unwrap();
}

#[test]
fn admission_budget_is_global_across_shards() {
    // ISSUE tentpole: `max_pending` used to be per-shard, so an
    // N-shard front could hold N× the configured population. The
    // shared gate must bound the *combined* in-system count.
    let n = 1200;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 57, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 57;
        c
    };
    let serve_cfg = ServeConfig::builder()
        .max_pending(16)
        .shards(2)
        .replicas_per_level(1)
        .sync_interval(0)
        .build()
        .unwrap();
    let front =
        ShardFront::new(cfg, b.classes, expert_for(&b, 57), serve_cfg, "artifacts")
            .unwrap();
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = front.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.served() + report.shed(), n);
    assert!(report.shed() > 0, "blast into a 16-slot budget must shed");
    // The shared gate must actually be the one admitting: if shards
    // regressed to private per-shard gates, the front gate would never
    // be touched and its peak would read 0 — this is what makes the
    // bound below falsifiable rather than true by construction.
    assert!(
        report.peak_pending > 0,
        "the front's shared gate must see the admissions"
    );
    assert!(
        report.peak_pending <= 16,
        "global budget violated: combined peak {} > 16",
        report.peak_pending
    );
    // The global peak also bounds what each shard ever held.
    for r in &report.shards {
        assert!(r.peak_pending <= 16, "local peak {} > global cap", r.peak_pending);
    }
}

#[test]
fn stream_end_annotations_reach_peers_with_zero_loss() {
    // ISSUE satellite: annotations staged below `sync_interval` at
    // stream end used to be dropped. With the drain-on-exit flush,
    // *every* annotation must reach every peer — pinned by making the
    // interval larger than the whole stream (so only the flush can
    // deliver them) and comparing each shard's training cadence
    // against the single-learner `Cascade` over the full stream: one
    // lost annotation shifts the count-based triggers.
    let n = 400;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 59, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 59;
        c.beta0 = 1.0;
        for l in &mut c.levels {
            l.beta_decay = 1.0; // β ≡ 1: every request is annotated
        }
        c
    };
    let serve_cfg = ServeConfig::builder()
        .max_pending(1 << 16)
        .shards(2)
        .replicas_per_level(1)
        // Larger than the stream: nothing reaches the interval
        // trigger, so peers only learn via the drain-on-exit flush.
        .sync_interval(100_000)
        .build()
        .unwrap();
    let front =
        ShardFront::new(cfg.clone(), b.classes, expert_for(&b, 59), serve_cfg, "artifacts")
            .unwrap();
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = front.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.llm_calls(), n as u64, "β ≡ 1: every request annotated once");

    // Single-learner oracle: the cascade over the same n samples.
    let mut casc =
        Cascade::new(cfg, b.classes, expert_for(&b, 59), None, n + 1).unwrap();
    for s in &b.samples {
        casc.process(s);
    }
    let counts = casc.train_counts();
    let model_chunks: Vec<u64> = counts.iter().map(|c| c.0).collect();
    let calib_chunks: Vec<u64> = counts.iter().map(|c| c.1).collect();
    for (s, r) in report.shards.iter().enumerate() {
        assert!(
            r.served < n,
            "shard {s} must not have served the whole stream itself"
        );
        assert_eq!(
            r.train_batches, model_chunks,
            "shard {s}: every annotation (local + flushed remote) must land — \
             a dropped end-of-stream annotation shifts these counts"
        );
        assert_eq!(
            r.calib_batches, calib_chunks,
            "shard {s}: calibration probes for flushed annotations must run too"
        );
    }
}

#[test]
fn beta_trajectories_match_cascade_exactly() {
    let n = 300;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 35, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 35;
        c
    };

    let server =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 35), unbounded(), "artifacts")
            .unwrap();
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = server.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    drop(resp_rx);
    assert_eq!(report.shed, 0);

    let mut casc = Cascade::new(cfg, b.classes, expert_for(&b, 35), None, n + 1).unwrap();
    for s in &b.samples {
        casc.process(s);
    }

    // One decay step per request, each level with its *own* factor:
    // the served β trajectory must be bit-for-bit the cascade's.
    assert_eq!(report.final_betas, casc.betas());
    assert!(report.final_betas[0] < 0.01, "β₀ should have decayed");
}

#[test]
fn deferral_gate_consults_the_deferred_levels_own_beta() {
    // Pin the gate half of the β-parity bugfix. Config: β₀ decays to 0
    // after the very first admission (levels[0].beta_decay = 0), while
    // level 1's β stays pinned at 1 (decay = 1). Level 1's threshold is
    // raised so that *if its model ever ran* it would certainly exit
    // there. With the per-level gate, every deferral out of level 0
    // jumps to the expert on level 1's own β = 1 before level 1 runs —
    // so level 1 must answer nothing. A regression to the old
    // betas[0]-only gating (no per-level jump at deferral) would route
    // those requests into level 1 and make handled[1] > 0.
    let n = 200;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 45, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 45;
        c.beta0 = 1.0;
        c.levels[0].beta_decay = 0.0;
        c.levels[1].beta_decay = 1.0;
        c.levels[1].calibration = 10.0; // level 1 always exits if it runs
        c
    };
    let server =
        Server::new(cfg, b.classes, expert_for(&b, 45), unbounded(), "artifacts").unwrap();
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = server.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.served, n);
    assert_eq!(
        report.handled[1], 0,
        "every deferral into level 1 must jump on level 1's own β = 1: {:?}",
        report.handled
    );
    assert_eq!(
        report.handled[0] + report.handled[2],
        n,
        "traffic splits between level-0 exits and the expert: {:?}",
        report.handled
    );
    assert!(report.handled[2] >= 1, "the expert must see the jumps");
    assert_eq!(report.llm_calls, report.handled[2] as u64);
}

#[test]
fn expert_outage_answers_without_training_or_fabricated_labels() {
    // Cascade parity: an expert outage must not fabricate label 0,
    // train on it, or count expert calls — the router answers from a
    // confidence-weighted mixture of level predictions instead
    // (Cascade::fallback_pred's serving analogue).
    let n = 250;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 43, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 43;
        c
    };
    let mut expert = expert_for(&b, 43);
    expert.set_available(false);
    let server =
        Server::new(cfg.clone(), b.classes, expert, unbounded(), "artifacts").unwrap();
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = server.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.served, n);
    assert_eq!(report.llm_calls, 0, "outage must not count expert calls");
    assert_eq!(
        report.handled[cfg.levels.len()],
        0,
        "the expert never answers during an outage"
    );
    assert_eq!(
        report.train_batches,
        vec![0u64; cfg.levels.len()],
        "no annotations → no model training"
    );
    assert_eq!(
        report.calib_batches,
        vec![0u64; cfg.levels.len()],
        "no annotations → no calibrator training"
    );
}

#[test]
fn forced_expert_training_batch_counts_match_cascade() {
    // β ≡ 1 (no decay): every request jumps to the expert in both
    // learners, so both see identical annotation streams and must fire
    // identical training cadences — the count parity the batch-drop
    // and calibrator-truncation bugfixes restore.
    let n = 240;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 41, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 41;
        c.beta0 = 1.0;
        for l in &mut c.levels {
            l.beta_decay = 1.0;
        }
        c
    };

    let server =
        Server::new(cfg.clone(), b.classes, expert_for(&b, 5), unbounded(), "artifacts")
            .unwrap();
    let (req_rx, submit) = blast(&b);
    let (resp_tx, resp_rx) = channel();
    let report = server.serve(req_rx, resp_tx).unwrap();
    submit.join().unwrap();
    let responses: Vec<Response> = resp_rx.iter().collect();
    assert_answered_exactly_once(&responses, n);
    assert_eq!(report.handled[cfg.levels.len()], n, "all requests must hit the expert");

    let mut casc = Cascade::new(cfg, b.classes, expert_for(&b, 5), None, n + 1).unwrap();
    for s in &b.samples {
        casc.process(s);
    }
    let counts = casc.train_counts();
    let model_chunks: Vec<u64> = counts.iter().map(|c| c.0).collect();
    let calib_chunks: Vec<u64> = counts.iter().map(|c| c.1).collect();
    assert_eq!(
        report.train_batches, model_chunks,
        "per-level model training chunk counts must match the cascade"
    );
    assert_eq!(
        report.calib_batches, calib_chunks,
        "per-level calibrator chunk counts must match the cascade \
         (walk-skipped levels are probed for calibration)"
    );
    assert!(
        report.train_batches.iter().all(|&t| t > 0),
        "training must actually have run: {:?}",
        report.train_batches
    );
    assert!(
        report.calib_batches.iter().all(|&t| t > 0),
        "calibrator training must actually have run: {:?}",
        report.calib_batches
    );
}

#[test]
fn pipelined_speculative_run_keeps_learner_trajectory_bit_identical() {
    // Tentpole parity pin: pipelining + speculation are inference-only
    // scheduling changes — gates alone decide what trains, so β
    // trajectories, per-level training cadences, per-level traffic
    // splits, and expert-call counts must be bit-for-bit those of the
    // sequential router *and* the offline cascade, no matter how reply
    // timing shuffles under the stage queues.
    //
    // The config is chosen to be timing-robust *and* maximally
    // adversarial for reordering: β pinned to 0 after the first
    // admission (no jump coins left to misalign) and every gate forced
    // open (calibration 0 → any positive score defers), so every
    // request walks the full cascade and nearly every level-k deferral
    // carries a speculative copy at level k+1. Speculation targets
    // level k+1's *successor* (never the expert), so the 4-level large
    // cascade gives it two levels of room.
    let n = 260;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 61, n);
    let cfg = {
        let mut c = CascadeConfig::large(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 61;
        c.beta0 = 1.0;
        for l in &mut c.levels {
            l.beta_decay = 0.0; // β = 0 after the first admission: no jumps
            l.calibration = 0.0; // untrained gates always defer
        }
        c
    };

    let run = |serve_cfg: ServeConfig| {
        let server = Server::new(
            cfg.clone(),
            b.classes,
            expert_for(&b, 61),
            serve_cfg,
            "artifacts",
        )
        .unwrap();
        let (req_rx, submit) = blast(&b);
        let (resp_tx, resp_rx) = channel();
        let report = server.serve(req_rx, resp_tx).unwrap();
        submit.join().unwrap();
        let responses: Vec<Response> = resp_rx.iter().collect();
        assert_answered_exactly_once(&responses, n);
        assert_eq!(report.shed, 0, "unbounded run must not shed");
        report
    };

    let sequential = run(unbounded());
    let pipelined = run(
        ServeConfig::builder()
            .max_pending(1 << 16)
            .pipeline(true)
            .spec_threshold(1e-6) // aggressive: any positive score speculates
            .stage_queue_depth(4) // small: the overflow fallback runs too
            .build()
            .unwrap(),
    );

    // The speculative machinery must actually have been exercised (and
    // must stay off in the default config).
    assert_eq!(
        sequential.spec_hits + sequential.spec_wasted,
        0,
        "speculation must be off by default"
    );
    assert!(
        pipelined.spec_hits > 0,
        "a forced-defer walk must confirm speculations: hits={} wasted={}",
        pipelined.spec_hits,
        pipelined.spec_wasted
    );
    assert!(
        pipelined.queue_depth.iter().any(|&d| d > 0),
        "stage queues must have been used: {:?}",
        pipelined.queue_depth
    );

    // Bit-identical learner trajectory across schedulers.
    let bits = |r: &ocl::serve::ServeReport| {
        r.final_betas.iter().map(|x| x.to_bits()).collect::<Vec<u64>>()
    };
    assert_eq!(bits(&sequential), bits(&pipelined), "β must not depend on scheduling");
    assert_eq!(sequential.train_batches, pipelined.train_batches);
    assert_eq!(sequential.calib_batches, pipelined.calib_batches);
    assert_eq!(sequential.handled, pipelined.handled, "same gate decisions everywhere");
    assert_eq!(sequential.llm_calls, pipelined.llm_calls);

    // And both match the single-learner cascade over the same stream.
    let mut casc =
        Cascade::new(cfg.clone(), b.classes, expert_for(&b, 61), None, n + 1).unwrap();
    for s in &b.samples {
        casc.process(s);
    }
    let counts = casc.train_counts();
    assert_eq!(
        pipelined.train_batches,
        counts.iter().map(|c| c.0).collect::<Vec<u64>>(),
        "per-level model training chunk counts must match the cascade"
    );
    assert_eq!(
        pipelined.calib_batches,
        counts.iter().map(|c| c.1).collect::<Vec<u64>>(),
        "per-level calibrator chunk counts must match the cascade"
    );
    assert_eq!(
        pipelined.final_betas,
        casc.betas(),
        "the served β trajectory must be bit-for-bit the cascade's"
    );
}
