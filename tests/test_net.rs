//! Wire-front tests: the `serve::net` protocol and the TCP serving
//! paths, asserted against *actual sockets and actual processes*.
//!
//! Three layers:
//! - property tests of the frame codec (round-trip, malformed-input
//!   rejection, reassembly across pathological read boundaries), with
//!   the `prop` reproducer-seed contract exercised on wire inputs;
//! - in-process servers behind real loopback TCP: the open-loop load
//!   harness and SLO assertions over sockets, and admission shedding
//!   with exactly-once accounting across the wire;
//! - a true crash test: a child `ocl serve --listen` process
//!   (`CARGO_BIN_EXE_ocl`) SIGKILLed mid-stream and resumed with
//!   `--resume strict`, asserting the resumed trajectory is
//!   bit-identical to an uninterrupted reference run.

use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use ocl::codec::Json;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId, ServeConfig};
use ocl::data::{Benchmark, Sample};
use ocl::models::Pipeline;
use ocl::prng::Rng;
use ocl::prop;
use ocl::serve::net::{self, encode, Client, Frame, FrameBuf, MAX_FRAME, WIRE_VERSION};
use ocl::serve::shard::ShardFront;
use ocl::serve::{load, Request, Response};
use ocl::sim::{Expert, ExpertProfile};
use ocl::util::Percentiles;

fn expert_for(b: &Benchmark, seed: u64) -> Expert {
    let mean_len =
        b.samples.iter().map(|s| s.len as f64).sum::<f64>() / b.samples.len() as f64;
    Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
        b.strata_fractions(),
        mean_len,
        seed,
    )
}

/// Never sheds, no cadence checkpoints.
fn unbounded() -> ServeConfig {
    ServeConfig::builder().max_pending(1 << 16).ckpt_every(0).build().unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ocl-net-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A loopback address that was free a moment ago (bind :0, read, drop).
fn free_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("bind :0");
    let a = l.local_addr().expect("local addr");
    drop(l);
    a.to_string()
}

// --- frame-codec property tests --------------------------------------------

/// Random frame over realistic content: samples from a generated
/// benchmark, featurized vectors from the real pipeline.
fn gen_frame(rng: &mut Rng, b: &Benchmark, pipe: &Pipeline) -> Frame {
    let sample = |rng: &mut Rng| b.samples[rng.below(b.samples.len())].clone();
    match rng.below(8) {
        0 => Frame::Hello { cursor: rng.next_u64() },
        1 => {
            let s = sample(rng);
            Frame::Request(Request {
                id: rng.next_u64(),
                text: s.text.clone(),
                truth: rng.below(4),
                sample: s,
            })
        }
        2 => Frame::Response(Response {
            id: rng.next_u64(),
            pred: rng.below(4),
            handled_by: rng.below(5),
            latency: Duration::from_nanos(rng.next_u64()),
            truth: rng.below(4),
            shed: false,
        }),
        3 => Frame::Shed {
            id: rng.next_u64(),
            truth: rng.below(4),
            handled_by: rng.below(5),
        },
        4 => {
            let k = rng.below(3);
            Frame::Sync {
                shard: rng.below(4),
                items: (0..k)
                    .map(|_| (pipe.featurize(&sample(rng).text), rng.below(4)))
                    .collect(),
            }
        }
        5 => Frame::Eos,
        6 => Frame::SyncEnd { shard: rng.below(8) },
        _ => Frame::Report(Json::obj(vec![
            ("served", Json::Num(rng.below(100_000) as f64)),
            ("accuracy", Json::Num(rng.f64())),
            ("resumed", Json::Bool(rng.coin(0.5))),
        ])),
    }
}

#[test]
fn frames_roundtrip_bit_exactly() {
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 17, 64);
    let pipe = Pipeline::default();
    prop::check(
        "frame-roundtrip",
        128,
        |rng| gen_frame(rng, &b, &pipe),
        |frame| {
            let bytes = encode(frame);
            let mut fb = FrameBuf::new();
            fb.push(&bytes);
            let decoded = match fb.next() {
                Ok(Some(f)) => f,
                _ => return false,
            };
            // Buffer fully drained, value identical (f64s bit-exact
            // via the codec's shortest-round-trip printing), and the
            // re-encoding is byte-identical — the wire form is
            // canonical, not merely equivalent.
            matches!(fb.next(), Ok(None))
                && decoded == *frame
                && encode(&decoded) == bytes
        },
    );
}

#[test]
fn reassembly_is_boundary_oblivious() {
    // The same frames decode identically whether the bytes arrive in
    // one read or one *byte* at a time — the pathological lower bound
    // for TCP segmentation.
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 19, 64);
    let pipe = Pipeline::default();
    prop::check(
        "frame-reassembly",
        32,
        |rng| (0..3).map(|_| gen_frame(rng, &b, &pipe)).collect::<Vec<_>>(),
        |frames| {
            let stream: Vec<u8> = frames.iter().flat_map(encode).collect();
            let mut whole = FrameBuf::new();
            whole.push(&stream);
            let mut trickle = FrameBuf::new();
            let mut got = Vec::new();
            for &byte in &stream {
                trickle.push(&[byte]);
                while let Ok(Some(f)) = trickle.next() {
                    got.push(f);
                }
            }
            let mut want = Vec::new();
            while let Ok(Some(f)) = whole.next() {
                want.push(f);
            }
            got == want && got == *frames
        },
    );
}

#[test]
fn corrupted_version_byte_is_always_rejected_and_seed_replays() {
    // Every generated frame with its version byte corrupted must be
    // rejected — and the prop harness's reproducer contract must hold
    // on wire inputs: the panic carries a seed that regenerates the
    // identical frame deterministically.
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 23, 64);
    let pipe = Pipeline::default();
    let gen_f = |rng: &mut Rng| gen_frame(rng, &b, &pipe);
    // Deliberately inverted property: "a corrupted frame decodes fine"
    // is falsified on the very first case.
    let bad_version_decodes = |frame: &Frame| {
        let mut bytes = encode(frame);
        bytes[0] = WIRE_VERSION.wrapping_add(1);
        let mut fb = FrameBuf::new();
        fb.push(&bytes);
        fb.next().is_ok()
    };
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        prop::check("bad-version-decodes", 64, gen_f, bad_version_decodes)
    }))
    .expect_err("corrupted version must be rejected for every frame");
    let msg = match err.downcast::<String>() {
        Ok(s) => *s,
        Err(_) => panic!("panic payload should be the prop message"),
    };
    let seed = prop::parse_reproducer_seed(&msg).expect("message carries a seed");
    let (a, held_a) = prop::recheck(seed, gen_f, bad_version_decodes);
    assert!(!held_a, "reproducer seed must re-fail");
    let (b2, held_b) = prop::recheck(seed, gen_f, bad_version_decodes);
    assert!(!held_b);
    assert_eq!(a, b2, "replay must regenerate the identical frame");
}

#[test]
fn malformed_frames_are_clean_wire_errors() {
    // Unknown tag.
    let mut fb = FrameBuf::new();
    fb.push(&[WIRE_VERSION, 0, 0, 0, 0, 0]);
    assert!(fb.next().is_err(), "tag 0 must be rejected");
    let mut fb = FrameBuf::new();
    fb.push(&[WIRE_VERSION, 9, 0, 0, 0, 0]);
    assert!(fb.next().is_err(), "tag 9 must be rejected");

    // Oversized length is rejected from the header alone — the
    // receiver never buffers a byte of the claimed payload.
    let mut fb = FrameBuf::new();
    let mut hdr = vec![WIRE_VERSION, 6];
    hdr.extend_from_slice(&((MAX_FRAME as u32) + 1).to_be_bytes());
    fb.push(&hdr);
    let err = fb.next().expect_err("oversized frame must be rejected");
    assert!(err.to_string().contains("cap"), "{err}");

    // Truncation is not an error — just "need more bytes".
    let bytes = encode(&Frame::Hello { cursor: 42 });
    let mut fb = FrameBuf::new();
    fb.push(&bytes[..bytes.len() - 1]);
    assert!(matches!(fb.next(), Ok(None)));

    // A well-formed header over a non-JSON payload is an error.
    let body = b"not json at all";
    let mut fb = FrameBuf::new();
    let mut raw = vec![WIRE_VERSION, 6];
    raw.extend_from_slice(&(body.len() as u32).to_be_bytes());
    raw.extend_from_slice(body);
    assert!(fb.next().is_ok(), "empty buffer first");
    fb.push(&raw);
    assert!(fb.next().is_err(), "non-JSON payload must be rejected");

    // Valid JSON that isn't the tag's schema is an error too.
    let body = b"{\"wrong\":1}";
    let mut fb = FrameBuf::new();
    let mut raw = vec![WIRE_VERSION, 1];
    raw.extend_from_slice(&(body.len() as u32).to_be_bytes());
    raw.extend_from_slice(body);
    fb.push(&raw);
    assert!(fb.next().is_err(), "hello without a cursor must be rejected");
}

// --- loopback serving ------------------------------------------------------

#[test]
fn loopback_load_harness_meets_slo_with_exactly_once_ids() {
    let n = 300;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 91, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 91;
        c
    };
    let front =
        ShardFront::new(cfg, b.classes, expert_for(&b, 91), unbounded(), "artifacts")
            .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || net::serve(front, listener));

    let client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    assert_eq!(client.cursor(), 0, "fresh server announces cursor 0");
    // The open-loop harness drives the socket exactly as it drives an
    // in-process channel — same Sender<Request> surface.
    let submit = load::drive_from(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 2000.0 },
        7,
        client.request_sender(),
        0,
    );
    assert_eq!(submit.join().unwrap(), n);
    let (responses, wire_report) = client.finish().unwrap();
    let report = server.join().unwrap().unwrap();

    // Exactly-once: every id answered exactly once, none invented.
    assert_eq!(responses.len(), n);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate response ids over the wire");
    assert_eq!(ids.first(), Some(&0));
    assert_eq!(ids.last(), Some(&((n - 1) as u64)));
    assert!(responses.iter().all(|r| !r.shed), "unbounded gate must not shed");
    assert_eq!(report.served() + report.shed(), n);

    // SLO asserted where it matters: client-observed, far side of the
    // socket. Bounds are generous — this is a correctness smoke, CI's
    // net-smoke owns the tight ones.
    let mut lat = Percentiles::new();
    for r in &responses {
        lat.push(r.latency.as_secs_f64() * 1000.0);
    }
    load::Slo { p50_ms: 5_000.0, p99_ms: 20_000.0 }.check(&lat).unwrap();

    // The report frame is the server's own report, bit-exactly.
    let wire_report = wire_report.expect("final report frame");
    assert_eq!(
        wire_report.to_string_compact(),
        report.to_json().to_string_compact(),
        "wire report must round-trip the server report exactly"
    );
}

#[test]
fn socket_backpressure_sheds_immediately_and_respects_the_global_gate() {
    let n = 600;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 77, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 77;
        c
    };
    let levels = cfg.levels.len();
    // Two shards behind ONE 16-deep global admission gate: the bound
    // is deployment-wide, not per-shard.
    let serve_cfg = ServeConfig::builder()
        .max_pending(16)
        .ckpt_every(0)
        .shards(2)
        .replicas_per_level(1)
        .sync_interval(0)
        .build()
        .unwrap();
    let front =
        ShardFront::new(cfg, b.classes, expert_for(&b, 77), serve_cfg, "artifacts")
            .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || net::serve(front, listener));

    let client = Client::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    // Unpaced blast straight into the socket: saturates far past
    // max_pending, so the gate must refuse.
    let tx = client.request_sender();
    for (i, s) in b.samples.iter().enumerate() {
        tx.send(Request {
            id: i as u64,
            text: s.text.clone(),
            truth: s.label,
            sample: s.clone(),
        })
        .expect("socket writer alive");
    }
    drop(tx);
    let (responses, _) = client.finish().unwrap();
    let report = server.join().unwrap().unwrap();

    // Exactly-once accounting across served + shed, over the wire.
    assert_eq!(responses.len(), n, "every request answered exactly once");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n);
    let shed = responses.iter().filter(|r| r.shed).count();
    assert!(shed > 0, "a 16-deep gate under a {n}-request blast must shed");
    assert!(shed < n, "the gate must still serve what it admits");
    assert_eq!(report.shed(), shed, "wire shed frames match the server's count");
    assert_eq!(report.served() + report.shed(), n);
    assert!(
        report.peak_pending <= 16,
        "global admission gate violated: peak_pending {}",
        report.peak_pending
    );
    for r in responses.iter().filter(|r| r.shed) {
        assert_eq!(r.latency, Duration::ZERO, "shed refusals are immediate");
        assert_eq!(r.handled_by, levels + 1, "shed attribution slot");
    }
}

/// The multi-process `--front` topology has no cross-process global
/// admission gate (a known ROADMAP follow-up): each shard *process*
/// brings its own budget. Pin that semantics down — under an unpaced
/// blast through a real front (`run_front`) over real sockets, every
/// shard process sheds through its own gate and no process's
/// `peak_pending` ever exceeds its local `max_pending`.
#[test]
fn front_topology_admission_gates_are_per_process() {
    let n = 320;
    let seed = 83u64;
    let cap = 4usize;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, seed, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = seed;
        c
    };
    let serve_cfg =
        ServeConfig::builder().max_pending(cap).ckpt_every(0).build().unwrap();

    // Two shard "processes" (thread-hosted, but over real TCP — the
    // exact code path `ocl serve --listen --shard-id k` runs).
    let mut shard_addrs = Vec::new();
    let mut shard_handles = Vec::new();
    for k in 0..2usize {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        shard_addrs.push(listener.local_addr().unwrap().to_string());
        let (srv, cursor) = net::build_shard_server(
            cfg.clone(),
            b.classes,
            expert_for(&b, seed),
            serve_cfg.clone(),
            "artifacts",
            net::ShardSlot { id: k, of: 2 },
            None,
        )
        .unwrap();
        shard_handles
            .push(std::thread::spawn(move || net::serve_shard(srv, cursor, k, listener)));
    }
    let front_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let front_addr = front_listener.local_addr().unwrap().to_string();
    let peers = shard_addrs.clone();
    let front = std::thread::spawn(move || net::run_front(&peers, front_listener));

    let client = Client::connect_retry(&front_addr, Duration::from_secs(10)).unwrap();
    let tx = client.request_sender();
    for (i, s) in b.samples.iter().enumerate() {
        tx.send(Request {
            id: i as u64,
            text: s.text.clone(),
            truth: s.label,
            sample: s.clone(),
        })
        .expect("front writer alive");
    }
    drop(tx);
    let (responses, _) = client.finish().unwrap();
    let merged = front.join().unwrap().unwrap();
    let reports: Vec<_> =
        shard_handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();

    assert_eq!(responses.len(), n, "exactly-once across the two-hop wire");
    let total: usize = reports.iter().map(|r| r.served + r.shed).sum();
    assert_eq!(total, n, "hash dispatch covered every request");
    for (k, r) in reports.iter().enumerate() {
        assert!(r.served > 0, "shard {k} served nothing — dispatch broken");
        assert!(
            r.shed > 0,
            "shard {k} never shed: a {cap}-deep per-process gate under an \
             unpaced blast must refuse"
        );
        assert!(
            r.peak_pending <= cap,
            "shard {k} admission gate violated: peak_pending {} > {cap}",
            r.peak_pending
        );
    }
    // The client-visible shed set is exactly the union of the
    // per-process gates' refusals, and the front's merged report
    // agrees with the shard-side counters.
    let shed_wire = responses.iter().filter(|r| r.shed).count();
    assert_eq!(shed_wire, reports.iter().map(|r| r.shed).sum::<usize>());
    assert_eq!(
        merged.get("served").and_then(Json::as_usize).unwrap(),
        reports.iter().map(|r| r.served).sum::<usize>()
    );
    assert_eq!(
        merged.get("shed").and_then(Json::as_usize).unwrap(),
        shed_wire
    );
}

// --- multi-process crash test ----------------------------------------------

/// One shard process of a 2-shard `--front` deployment, durable over
/// the shared checkpoint directory.
fn spawn_shard(addr: &str, k: usize, dir: &std::path::Path, resume: &str) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_ocl"));
    let dir = dir.to_string_lossy().to_string();
    let ks = k.to_string();
    cmd.args([
        "serve",
        "--listen",
        addr,
        "--benchmark",
        "imdb",
        "--expert",
        "gpt35",
        "--seed",
        "35",
        "--scale",
        "0.02",
        "--shards",
        "2",
        "--shard-id",
        ks.as_str(),
        "--ckpt-dir",
        dir.as_str(),
        "--ckpt-every",
        "8",
        "--resume",
        resume,
    ]);
    cmd.stdout(Stdio::null()).stderr(Stdio::null());
    cmd.spawn().expect("spawn ocl serve shard")
}

/// Rolling restart (DESIGN.md §14): a 2-shard `--front` topology over
/// real sockets keeps serving while shard 1 is SIGKILLed mid-stream
/// and strict-resumed *on the same address*. The front buffers the
/// dead shard's traffic, reconnects, replays the unanswered gap over
/// the new connection, and the response registry dedups the overlap —
/// so the client sees every id exactly once and the merged accounting
/// still covers the whole stream.
#[test]
fn rolling_restart_of_one_shard_loses_nothing_while_the_peer_serves() {
    let n = 360;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 35, n);
    let dir = tmpdir("rolling");

    let addr0 = free_addr();
    let addr1 = free_addr();
    let mut shard0 = spawn_shard(&addr0, 0, &dir, "off");
    let mut shard1 = spawn_shard(&addr1, 1, &dir, "off");

    let front_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let front_addr = front_listener.local_addr().unwrap().to_string();
    let peers = vec![addr0.clone(), addr1.clone()];
    let front = std::thread::spawn(move || net::run_front(&peers, front_listener));

    let client = Client::connect_retry(&front_addr, Duration::from_secs(60)).unwrap();
    assert_eq!(client.cursor(), 0, "fresh deployment announces cursor 0");
    // Paced arrivals so the kill lands mid-submission.
    let submit = load::drive_from(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 150.0 },
        7,
        client.request_sender(),
        0,
    );

    // Wait for a committed manifest (both shards deposited), then
    // SIGKILL shard 1 — no drain, no goodbye.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let committed = std::fs::read_dir(&dir).ok().and_then(|rd| {
            rd.flatten()
                .find(|e| e.file_name().to_string_lossy().starts_with("manifest-"))
        });
        if committed.is_some() {
            break;
        }
        assert!(Instant::now() < deadline, "no manifest within 60s");
        std::thread::sleep(Duration::from_millis(10));
    }
    shard1.kill().expect("SIGKILL shard 1");
    shard1.wait().expect("reap shard 1");

    // Rolling replacement: same address, strict resume from the shared
    // checkpoint directory. The front reconnects and replays the gap.
    let mut shard1b = spawn_shard(&addr1, 1, &dir, "strict");

    assert_eq!(submit.join().unwrap(), n, "the client never noticed the restart");
    let (responses, wire_report) = client.finish().unwrap();
    let merged = front.join().unwrap().expect("front must merge both shard reports");
    assert!(shard0.wait().unwrap().success(), "shard 0 exits cleanly");
    assert!(shard1b.wait().unwrap().success(), "restarted shard 1 exits cleanly");

    // Zero lost, zero duplicated: every id answered exactly once.
    assert_eq!(responses.len(), n, "a response for every request");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate ids leaked through the restart");
    assert_eq!(ids.first(), Some(&0));
    assert_eq!(ids.last(), Some(&((n - 1) as u64)));

    let served = merged.get("served").and_then(Json::as_usize).unwrap();
    let shed = merged.get("shed").and_then(Json::as_usize).unwrap();
    assert_eq!(served + shed, n, "merged accounting covers the whole stream");
    assert!(
        merged.get("reconnects").and_then(Json::as_usize).unwrap() >= 1,
        "the front must have re-attached the restarted shard"
    );
    // The restarted shard continued from its checkpoint — and said so —
    // while the untouched peer neither resumed nor stopped serving.
    let per_shard = merged.get("per_shard").and_then(Json::as_arr).unwrap();
    assert_eq!(per_shard.len(), 2);
    assert_eq!(per_shard[1].get("resumed").and_then(Json::as_bool), Some(true));
    assert_eq!(per_shard[0].get("resumed").and_then(Json::as_bool), Some(false));
    assert!(per_shard[0].get("served").and_then(Json::as_usize).unwrap() > 0);
    // The client's final report frame is the merged front report.
    assert_eq!(
        wire_report.expect("front report frame").to_string_compact(),
        merged.to_string_compact(),
        "wire report must round-trip the merged front report exactly"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

fn spawn_serve(addr: &str, ckpt: Option<(&std::path::Path, &str)>) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_ocl"));
    cmd.args([
        "serve",
        "--listen",
        addr,
        "--benchmark",
        "imdb",
        "--expert",
        "gpt35",
        "--seed",
        "35",
        "--scale",
        "0.02",
        "--shards",
        "1",
    ]);
    if let Some((dir, resume)) = ckpt {
        let dir = dir.to_string_lossy().to_string();
        cmd.args(["--ckpt-dir", &dir, "--ckpt-every", "8", "--resume", resume]);
    }
    cmd.stdout(Stdio::null()).stderr(Stdio::null());
    cmd.spawn().expect("spawn ocl serve")
}

fn betas_bits(report: &Json) -> Vec<u64> {
    report
        .get("per_shard")
        .and_then(Json::as_arr)
        .expect("per_shard")[0]
        .get("final_betas")
        .and_then(Json::as_arr)
        .expect("final_betas")
        .iter()
        .map(|v| v.as_f64().expect("beta").to_bits())
        .collect()
}

#[test]
fn sigkilled_tcp_server_resumes_bit_identically() {
    // The deployed-surface version of the PR 4 parity contract: the
    // "kill" is a real SIGKILL of a real `ocl serve --listen` process
    // mid-stream — no staged drop, no graceful drain — and the resumed
    // deployment must land on served_total == n with final β values
    // bit-identical to an uninterrupted reference process.
    let n = 200;
    // Same generator seed as the servers' `--seed 35 --scale 0.02`
    // stream: build_sized is prefix-consistent, so these are exactly
    // the first n samples the servers' own harnesses would build.
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 35, n);

    // Uninterrupted reference: its report arrives over the wire.
    let addr = free_addr();
    let mut child = spawn_serve(&addr, None);
    let client = Client::connect_retry(&addr, Duration::from_secs(60)).unwrap();
    assert_eq!(client.cursor(), 0);
    let submit = load::drive_from(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 2000.0 },
        7,
        client.request_sender(),
        0,
    );
    assert_eq!(submit.join().unwrap(), n);
    let (ref_responses, ref_report) = client.finish().unwrap();
    assert!(child.wait().unwrap().success(), "reference server exits cleanly");
    assert_eq!(ref_responses.len(), n);
    let ref_report = ref_report.expect("reference report frame");
    assert_eq!(ref_report.get("served").and_then(Json::as_usize), Some(n));

    // Interrupted run: durable checkpoints on, paced arrivals so the
    // kill lands mid-submission, SIGKILL as soon as a manifest commits.
    let dir = tmpdir("crash");
    let addr2 = free_addr();
    let mut child2 = spawn_serve(&addr2, Some((&dir, "off")));
    let client2 = Client::connect_retry(&addr2, Duration::from_secs(60)).unwrap();
    assert_eq!(client2.cursor(), 0, "no checkpoint yet: fresh cursor");
    let submit2 = load::drive_from(
        b.samples.clone(),
        load::Arrival::Poisson { rate: 150.0 },
        7,
        client2.request_sender(),
        0,
    );
    let deadline = Instant::now() + Duration::from_secs(60);
    let manifest = loop {
        let found = std::fs::read_dir(&dir).ok().and_then(|rd| {
            rd.flatten().find(|e| {
                e.file_name().to_string_lossy().starts_with("manifest-")
            })
        });
        if let Some(f) = found {
            break f;
        }
        assert!(Instant::now() < deadline, "no manifest within 60s");
        std::thread::sleep(Duration::from_millis(10));
    };
    drop(manifest);
    child2.kill().expect("SIGKILL the serving process");
    child2.wait().expect("reap");
    let _ = submit2.join(); // drive stops once the socket writer dies
    let (_partial, dead_report) = client2.finish().unwrap();
    assert!(
        dead_report.is_none(),
        "a SIGKILLed server cannot have sent a final report"
    );

    // Resume strictly from the shared checkpoint directory; the Hello
    // cursor tells the client where to resubmit from (at-least-once:
    // everything past the last manifest is resubmitted).
    let addr3 = free_addr();
    let mut child3 = spawn_serve(&addr3, Some((&dir, "strict")));
    let client3 = Client::connect_retry(&addr3, Duration::from_secs(60)).unwrap();
    let cursor = client3.cursor() as usize;
    assert!(cursor > 0, "strict resume must announce checkpointed progress");
    assert!(cursor <= n);
    let tail: Vec<Sample> = b.samples[cursor..].to_vec();
    let submit3 = load::drive_from(
        tail,
        load::Arrival::Poisson { rate: 2000.0 },
        9,
        client3.request_sender(),
        cursor as u64,
    );
    assert_eq!(submit3.join().unwrap(), n - cursor);
    let (tail_responses, resumed_report) = client3.finish().unwrap();
    assert!(child3.wait().unwrap().success(), "resumed server exits cleanly");

    // The tail is answered exactly once, with the original stream ids.
    assert_eq!(tail_responses.len(), n - cursor);
    let mut ids: Vec<u64> = tail_responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n - cursor);
    if let (Some(first), Some(last)) = (ids.first(), ids.last()) {
        assert_eq!(*first, cursor as u64);
        assert_eq!(*last, (n - 1) as u64);
    }

    let resumed_report = resumed_report.expect("resumed report frame");
    assert_eq!(
        resumed_report.get("resumed").and_then(Json::as_bool),
        Some(true),
        "resumed run must say so"
    );
    assert_eq!(
        resumed_report.get("served").and_then(Json::as_usize),
        Some(n),
        "cumulative served_total continues the killed run"
    );
    let want = betas_bits(&ref_report);
    let got = betas_bits(&resumed_report);
    assert!(!want.is_empty());
    assert_eq!(
        got, want,
        "final β values must be bit-identical to the uninterrupted reference"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
