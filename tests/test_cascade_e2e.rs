//! Coordinator integration + property tests (host engine; no
//! artifacts required): routing invariants, cost-accounting
//! identities, budget behaviour, baseline orderings, and failure
//! injection — the L3 invariants DESIGN.md §8 calls out.

use ocl::cascade::{Cascade, DeferralRule};
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId};
use ocl::data::{Benchmark, StreamOrder};
use ocl::eval::Harness;
use ocl::policy::CostParams;
use ocl::prng::Rng;
use ocl::prop;
use ocl::sim::{Expert, ExpertProfile};

fn build(bench: BenchmarkId, n: usize, seed: u64) -> (Cascade, Benchmark) {
    let b = Benchmark::build_sized(bench, seed, n);
    let mean_len = b.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, bench),
        b.strata_fractions(),
        mean_len,
        seed ^ 0xE,
    );
    let mut cfg = CascadeConfig::small(bench, ExpertId::Gpt35);
    cfg.seed = seed;
    let c = Cascade::new(cfg, b.classes, expert, None, n + 1).unwrap();
    (c, b)
}

#[test]
fn prop_every_query_handled_exactly_once() {
    prop::check_seeded("routing-totality", 8, |rng| {
        let n = 100 + rng.below(200);
        let (mut c, b) = build(BenchmarkId::Imdb, n, rng.next_u64());
        c.set_threshold_scale(0.3 + rng.f64());
        if rng.coin(0.5) {
            c.set_budget(Some(rng.below(n) as u64));
        }
        for s in &b.samples {
            let out = c.process(s);
            // the handling level is always valid
            if out.handled_by > 2 {
                return false;
            }
        }
        c.metrics.finalize();
        // every sample recorded exactly once, level fractions sum to 1
        let fr: f64 = c.metrics.handled_fractions().iter().sum();
        c.metrics.total() == n && (fr - 1.0).abs() < 1e-9
    });
}

#[test]
fn prop_budget_never_exceeded() {
    prop::check_seeded("budget-hard-cap", 8, |rng| {
        let n = 150 + rng.below(150);
        let budget = rng.below(n / 2) as u64;
        let (mut c, b) = build(BenchmarkId::HateSpeech, n, rng.next_u64());
        c.set_budget_paced(budget, n);
        c.run_stream(&b.stream());
        c.llm_calls() <= budget
    });
}

#[test]
fn prop_flops_accounting_is_additive_and_positive() {
    prop::check_seeded("flops-additive", 5, |rng| {
        let n = 120;
        let (mut c, b) = build(BenchmarkId::Imdb, n, rng.next_u64());
        let mut sum = 0.0;
        for s in &b.samples {
            let out = c.process(s);
            if out.flops <= 0.0 {
                return false;
            }
            sum += out.flops;
        }
        (sum - c.metrics.flops()).abs() < 1e-6 * sum.max(1.0)
    });
}

#[test]
fn prop_episode_cost_decomposition_matches_j() {
    // J(π,T) computed from per-episode costs must equal the tracker's
    // total — the Eq. 1 decomposition identity.
    prop::check_seeded("j-decomposition", 6, |rng| {
        let params = CostParams {
            mu: rng.f64() * 0.01,
            defer_costs: vec![1.0, 100.0 + rng.f64() * 2000.0],
        };
        let mut tracker =
            ocl::policy::RegretTracker::new(params.clone(), 3, usize::MAX / 2);
        let mut manual = 0.0;
        for _ in 0..200 {
            let exit = rng.below(3);
            let loss = if rng.coin(0.3) { 1.0 } else { 0.0 };
            manual += params.episode_cost(exit, loss);
            tracker.record(exit, loss, &[1.0, 0.5, 0.0]);
        }
        (tracker.j_learned() - manual).abs() < 1e-9
    });
}

#[test]
fn budget_zero_means_no_expert_and_stream_still_served() {
    let (mut c, b) = build(BenchmarkId::Imdb, 300, 77);
    c.set_budget(Some(0));
    c.run_stream(&b.stream());
    assert_eq!(c.llm_calls(), 0);
    assert_eq!(c.metrics.total(), 300);
}

#[test]
fn determinism_same_seed_same_run() {
    let run = || {
        let (mut c, b) = build(BenchmarkId::Isear, 400, 123);
        c.set_threshold_scale(0.7);
        c.run_stream(&b.stream());
        (
            c.metrics.accuracy(),
            c.llm_calls(),
            c.metrics.handled_fractions(),
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn mid_stream_expert_outage_recovers() {
    let (mut c, b) = build(BenchmarkId::Imdb, 900, 55);
    c.set_threshold_scale(0.7);
    let stream = b.stream();
    for s in &stream[..300] {
        c.process(s);
    }
    c.expert_mut().set_available(false);
    for s in &stream[300..600] {
        c.process(s);
    }
    let calls_during_outage = c.llm_calls();
    c.expert_mut().set_available(true);
    for s in &stream[600..] {
        c.process(s);
    }
    c.metrics.finalize();
    assert_eq!(c.metrics.total(), 900);
    assert!(c.llm_calls() >= calls_during_outage);
    assert!(c.metrics.accuracy() > 0.5);
}

#[test]
fn ocl_beats_online_ensemble_at_matched_budget() {
    // The paper's architectural ablation (Table 1 / §5.1): adding the
    // learned deferral policy must beat the ensemble that lacks it, at
    // the same annotation budget and on the identical test half.
    let h = Harness::new(0.08, 3);
    let budget = h.scaled_budget(BenchmarkId::Imdb, 5200);
    let oc = h
        .run_ocl_split(BenchmarkId::Imdb, ExpertId::Gpt35, Some(budget), false, StreamOrder::Natural)
        .unwrap();
    let oe = h
        .run_oel_split(BenchmarkId::Imdb, ExpertId::Gpt35, budget, StreamOrder::Natural)
        .unwrap();
    assert!(
        oc.accuracy > oe.accuracy - 0.03,
        "ocl {} should not trail oel {} at budget {budget}",
        oc.accuracy,
        oe.accuracy
    );
}

#[test]
fn larger_budgets_do_not_hurt_accuracy_much() {
    // Accuracy should be (weakly) increasing in the budget.
    let h = Harness::new(0.06, 9);
    let mut last = 0.0;
    for frac in [0.1, 0.3, 0.6] {
        let t = h.stream_len(BenchmarkId::Imdb);
        let budget = ((t as f64) * frac) as u64;
        let (r, _) = h
            .run_ocl(BenchmarkId::Imdb, ExpertId::Gpt35, Some(budget), false, StreamOrder::Natural)
            .unwrap();
        assert!(
            r.accuracy > last - 0.05,
            "budget {frac}: acc {} dropped from {last}",
            r.accuracy
        );
        last = r.accuracy;
    }
}

#[test]
fn deferral_rules_all_terminate_and_route() {
    for rule in [
        DeferralRule::Calibrated,
        DeferralRule::MaxProb(0.9),
        DeferralRule::Entropy(0.3),
    ] {
        let (mut c, b) = build(BenchmarkId::Fever, 250, 31);
        c.set_deferral_rule(rule);
        c.run_stream(&b.stream());
        assert_eq!(c.metrics.total(), 250);
    }
}

#[test]
fn large_cascade_runs_and_uses_four_levels() {
    let b = Benchmark::build_sized(BenchmarkId::Isear, 41, 600);
    let mean_len = b.samples.iter().map(|s| s.len as f64).sum::<f64>() / 600.0;
    let expert = Expert::new(
        ExpertProfile::for_pair(ExpertId::Llama70b, BenchmarkId::Isear),
        b.strata_fractions(),
        mean_len,
        41,
    );
    let cfg = CascadeConfig::large(BenchmarkId::Isear, ExpertId::Llama70b);
    let mut c = Cascade::new(cfg, 7, expert, None, 601).unwrap();
    c.set_threshold_scale(0.7);
    c.run_stream(&b.stream());
    assert_eq!(c.metrics.handled_fractions().len(), 4);
    assert_eq!(c.metrics.total(), 600);
}

#[test]
fn shift_orderings_preserve_the_multiset_of_samples() {
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 13, 500);
    let mut rng = Rng::new(5);
    for order in [
        StreamOrder::Natural,
        StreamOrder::Shuffled,
        StreamOrder::LengthAscending,
        StreamOrder::CategoryHoldout(rng.below(10)),
    ] {
        let s = b.stream_ordered(order, 5);
        let mut ids: Vec<usize> = s.iter().map(|x| x.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..500).collect::<Vec<_>>(), "{order:?}");
    }
}
