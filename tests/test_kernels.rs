//! Bit-identity property tests for the batched host-model kernels.
//!
//! The tentpole contract of the batched inference path: for every host
//! model (`HostTfm`, `HostLr`, `HostMlp`) and every batch size —
//! including sizes that are not a multiple of the dense-matmul tile
//! width — `predict_batch*` must equal the per-sample `predict`
//! reference **bit-for-bit**. Shapes, batch sizes, and inputs (salted
//! with `±0.0` to probe the sparse/dense split) are randomized through
//! `ocl::prop`, so every failure panics with a reproducer seed; the
//! companion test at the bottom pins that the seed actually replays.

use ocl::hostmodel::tensor as t;
use ocl::hostmodel::{HostLr, HostMlp, HostTfm, TfmArch, TfmScratch};
use ocl::prng::Rng;
use ocl::prop;

/// Value generator that salts in exact `+0.0` / `-0.0` entries: the
/// dense kernels drop the sparse `av == 0.0` skip, so zeros (of both
/// signs) are exactly where a bit-level divergence would hide.
fn salted(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        _ => rng.f32() * 2.0 - 1.0,
    }
}

#[derive(Debug)]
struct MatmulCase {
    m: usize,
    k: usize,
    n: usize,
    a: Vec<f32>,
    b: Vec<f32>,
}

fn gen_matmul(rng: &mut Rng) -> MatmulCase {
    // n sweeps both sides of the 16-wide dense tile (remainder-only,
    // remainder + full tiles, exact multiples).
    let m = 1 + rng.below(6);
    let k = 1 + rng.below(48);
    let n = 1 + rng.below(40);
    MatmulCase {
        m,
        k,
        n,
        a: (0..m * k).map(|_| salted(rng)).collect(),
        b: (0..k * n).map(|_| salted(rng)).collect(),
    }
}

#[test]
fn dense_matmul_matches_sparse_bitwise_on_random_shapes() {
    prop::check("matmul-dense-bitwise", 128, gen_matmul, |c| {
        let mut sparse = vec![0.0f32; c.m * c.n];
        // garbage pre-fill: matmul_dense must overwrite every element
        let mut dense = vec![7.5f32; c.m * c.n];
        t::matmul(&c.a, &c.b, &mut sparse, c.m, c.k, c.n);
        t::matmul_dense(&c.a, &c.b, &mut dense, c.m, c.k, c.n);
        sparse
            .iter()
            .zip(&dense)
            .all(|(s, d)| s.to_bits() == d.to_bits())
    });
}

#[derive(Debug)]
struct TfmCase {
    seed: u64,
    large: bool,
    classes: usize,
    /// Two batch sizes run back-to-back through ONE scratch, so the
    /// grow-never-shrink buffer reuse is exercised in both directions.
    b1: usize,
    b2: usize,
}

fn gen_tfm(rng: &mut Rng) -> TfmCase {
    TfmCase {
        seed: rng.next_u64(),
        large: rng.coin(0.25),
        classes: 2 + rng.below(5),
        b1: 1 + rng.below(9),
        b2: 1 + rng.below(9),
    }
}

fn tfm_docs(rng: &mut Rng, l: usize, vocab: usize, b: usize) -> (Vec<Vec<i32>>, Vec<Vec<f32>>) {
    let ids = (0..b)
        .map(|_| (0..l).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let masks = (0..b)
        .map(|_| {
            let live = 1 + rng.below(l);
            (0..l).map(|i| if i < live { 1.0 } else { 0.0 }).collect()
        })
        .collect();
    (ids, masks)
}

fn tfm_case_holds(c: &TfmCase) -> bool {
    let arch = if c.large { TfmArch::Large } else { TfmArch::Base };
    let (vocab, l, _d, _h, _lay, _f) = arch.dims();
    let m = HostTfm::new(arch, c.classes, c.seed);
    let mut rng = Rng::new(c.seed ^ 0xD0C5);
    let mut scratch = TfmScratch::new();
    for &b in &[c.b1, c.b2] {
        let (ids, masks) = tfm_docs(&mut rng, l, vocab, b);
        let idr: Vec<&[i32]> = ids.iter().map(|v| v.as_slice()).collect();
        let mr: Vec<&[f32]> = masks.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; b * c.classes];
        m.predict_batch_into(&idr, &mr, &mut scratch, &mut out);
        for (bi, (id, mask)) in ids.iter().zip(&masks).enumerate() {
            let want = m.predict(id, mask);
            let got = &out[bi * c.classes..(bi + 1) * c.classes];
            if !want.iter().zip(got).all(|(w, g)| w.to_bits() == g.to_bits()) {
                return false;
            }
        }
    }
    true
}

#[test]
fn tfm_batched_matches_per_sample_bitwise() {
    prop::check("tfm-batched-bitwise", 10, gen_tfm, tfm_case_holds);
}

#[derive(Debug)]
struct LrCase {
    seed: u64,
    dim: usize,
    classes: usize,
    b: usize,
}

fn gen_lr(rng: &mut Rng) -> LrCase {
    LrCase {
        seed: rng.next_u64(),
        dim: 1 + rng.below(96),
        classes: 1 + rng.below(8),
        b: 1 + rng.below(19),
    }
}

fn lr_case_holds(c: &LrCase) -> bool {
    let mut rng = Rng::new(c.seed ^ 0x1812);
    let mut m = HostLr::new(c.dim, c.classes);
    // a couple of training steps so the weights are nonzero
    let xs: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..c.dim).map(|_| salted(&mut rng)).collect())
        .collect();
    let ys: Vec<usize> = (0..8).map(|_| rng.below(c.classes)).collect();
    let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    m.train_batch(&xr, &ys, 0.3);
    let qs: Vec<Vec<f32>> = (0..c.b)
        .map(|_| (0..c.dim).map(|_| salted(&mut rng)).collect())
        .collect();
    let qr: Vec<&[f32]> = qs.iter().map(|v| v.as_slice()).collect();
    let mut out = vec![0.0f32; c.b * c.classes];
    m.predict_batch_into(&qr, &mut out);
    qs.iter().enumerate().all(|(bi, q)| {
        let want = m.predict(q);
        let got = &out[bi * c.classes..(bi + 1) * c.classes];
        want.iter().zip(got).all(|(w, g)| w.to_bits() == g.to_bits())
    })
}

#[test]
fn lr_batched_matches_per_sample_bitwise() {
    prop::check("lr-batched-bitwise", 64, gen_lr, lr_case_holds);
}

#[derive(Debug)]
struct MlpCase {
    seed: u64,
    classes: usize,
    b: usize,
}

fn gen_mlp(rng: &mut Rng) -> MlpCase {
    MlpCase { seed: rng.next_u64(), classes: 1 + rng.below(9), b: 1 + rng.below(17) }
}

fn mlp_case_holds(c: &MlpCase) -> bool {
    let mut rng = Rng::new(c.seed ^ 0xCA11B);
    let m = HostMlp::new(c.classes, c.seed);
    let ps: Vec<Vec<f32>> = (0..c.b)
        .map(|_| {
            let raw: Vec<f32> = (0..c.classes).map(|_| rng.f32() + 1e-3).collect();
            let s: f32 = raw.iter().sum();
            raw.iter().map(|v| v / s).collect()
        })
        .collect();
    let pr: Vec<&[f32]> = ps.iter().map(|v| v.as_slice()).collect();
    let mut feat = Vec::new();
    let mut out = vec![0.0f32; c.b];
    m.predict_batch_into(&pr, &mut feat, &mut out);
    pr.iter()
        .zip(&out)
        .all(|(p, got)| got.to_bits() == m.predict(p).to_bits())
}

#[test]
fn mlp_batched_matches_per_sample_bitwise() {
    prop::check("mlp-batched-bitwise", 64, gen_mlp, mlp_case_holds);
}

#[test]
fn falsified_kernel_property_reports_a_replayable_seed() {
    // The reproducer contract on kernel inputs: deliberately invert the
    // LR property ("batched DIFFERS from per-sample") so it falsifies
    // on the first case, then replay the reported seed and check it
    // regenerates the identical case with the identical verdict.
    let inverted = |c: &LrCase| !lr_case_holds(c);
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        prop::check("lr-batched-differs", 8, gen_lr, inverted)
    }))
    .expect_err("bit-identity must hold, so the inverted property fails");
    let msg = match err.downcast::<String>() {
        Ok(s) => *s,
        Err(_) => panic!("panic payload should be the prop message"),
    };
    let seed = prop::parse_reproducer_seed(&msg).expect("message carries a seed");
    let (a, held_a) = prop::recheck(seed, gen_lr, inverted);
    assert!(!held_a, "reproducer seed must re-fail the inverted property");
    let (b, held_b) = prop::recheck(seed, gen_lr, inverted);
    assert!(!held_b);
    assert_eq!(
        (a.seed, a.dim, a.classes, a.b),
        (b.seed, b.dim, b.classes, b.b),
        "replay must regenerate the identical case"
    );
}
