//! Steady-state zero-allocation proof for the batched host kernels.
//!
//! A counting `#[global_allocator]` wraps `System` and tallies every
//! `alloc`/`realloc`/`alloc_zeroed`; after a warm-up call grows the
//! scratch buffers to their high-water size, repeated batched forwards
//! must perform **zero** heap allocations. This file is its own
//! integration-test binary (a global allocator is program-wide) and
//! keeps everything in one `#[test]` so no concurrent test thread can
//! pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

use ocl::hostmodel::{HostLr, HostMlp, HostTfm, TfmArch, TfmScratch};
use ocl::prng::Rng;

#[test]
fn batched_hot_paths_do_not_allocate_in_steady_state() {
    let mut rng = Rng::new(0xA110C);
    let classes = 3;
    let steps = 10;

    // --- HostTfm::predict_batch_into --------------------------------
    let tfm = HostTfm::new(TfmArch::Base, classes, 5);
    let (vocab, l, _d, _h, _lay, _f) = TfmArch::Base.dims();
    let b = 8;
    let ids: Vec<Vec<i32>> = (0..b)
        .map(|_| (0..l).map(|_| rng.below(vocab) as i32).collect())
        .collect();
    let masks: Vec<Vec<f32>> = (0..b)
        .map(|_| (0..l).map(|i| if i < l / 2 { 1.0 } else { 0.0 }).collect())
        .collect();
    let idr: Vec<&[i32]> = ids.iter().map(|v| v.as_slice()).collect();
    let mr: Vec<&[f32]> = masks.iter().map(|v| v.as_slice()).collect();
    let mut scratch = TfmScratch::new();
    let mut out = vec![0.0f32; b * classes];
    // warm-up: first call grows every scratch buffer to high-water
    tfm.predict_batch_into(&idr, &mr, &mut scratch, &mut out);
    let before = allocs();
    for _ in 0..steps {
        tfm.predict_batch_into(&idr, &mr, &mut scratch, &mut out);
    }
    let tfm_allocs = allocs() - before;
    assert_eq!(
        tfm_allocs, 0,
        "HostTfm::predict_batch_into allocated {tfm_allocs} times over {steps} steady-state calls"
    );

    // --- HostLr::predict_batch_into ---------------------------------
    let dim = 256;
    let lr = HostLr::new(dim, classes);
    let xs: Vec<Vec<f32>> = (0..b)
        .map(|_| {
            (0..dim)
                .map(|_| if rng.below(4) == 0 { rng.f32() } else { 0.0 })
                .collect()
        })
        .collect();
    let xr: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    let mut lr_out = vec![0.0f32; b * classes];
    lr.predict_batch_into(&xr, &mut lr_out);
    let before = allocs();
    for _ in 0..steps {
        lr.predict_batch_into(&xr, &mut lr_out);
    }
    let lr_allocs = allocs() - before;
    assert_eq!(
        lr_allocs, 0,
        "HostLr::predict_batch_into allocated {lr_allocs} times over {steps} steady-state calls"
    );

    // --- HostMlp::predict_scratch / predict_batch_into --------------
    let mlp = HostMlp::new(classes, 9);
    let probs: Vec<Vec<f32>> = (0..b)
        .map(|_| {
            let raw: Vec<f32> = (0..classes).map(|_| rng.f32() + 1e-3).collect();
            let s: f32 = raw.iter().sum();
            raw.iter().map(|v| v / s).collect()
        })
        .collect();
    let pr: Vec<&[f32]> = probs.iter().map(|v| v.as_slice()).collect();
    let mut feat = Vec::new();
    let mut mlp_out = vec![0.0f32; b];
    // warm-up: first call grows the shared feature buffer
    mlp.predict_batch_into(&pr, &mut feat, &mut mlp_out);
    let before = allocs();
    for _ in 0..steps {
        mlp.predict_batch_into(&pr, &mut feat, &mut mlp_out);
        for p in &pr {
            mlp.predict_scratch(p, &mut feat);
        }
    }
    let mlp_allocs = allocs() - before;
    assert_eq!(
        mlp_allocs, 0,
        "HostMlp scratch paths allocated {mlp_allocs} times over {steps} steady-state calls"
    );
}
