//! Model-checked exploration of the serve layer's concurrency
//! protocol cores (DESIGN.md §11), plus meta-tests proving the
//! checker catches planted bugs, plus real-thread stress over the
//! production `AdmissionGate`.
//!
//! Two profiles:
//!
//! * plain `cargo test` — bounded exploration (a generous step budget
//!   that still covers the full space for the default model sizes);
//! * `RUSTFLAGS="--cfg loom" cargo test --test test_loom --release` —
//!   exhaustive: larger model sizes, unbudgeted search, and every run
//!   must report `complete == true` (no truncation). This is the CI
//!   `loom` job.

use ocl::mc::models::{BarrierSpec, GateSpec, ScaleSpec, SlotSpec};
use ocl::mc::{Explorer, Violation};
use ocl::serve::barrier::ExportOutcome::{AuthorityDead, TimedOut, Written};
use ocl::serve::scale::ScalePolicy;
use ocl::serve::AdmissionGate;

/// Exhaustive under `--cfg loom`; generously bounded otherwise.
fn explorer() -> Explorer {
    if cfg!(loom) {
        Explorer::exhaustive()
    } else {
        Explorer::bounded(2_000_000)
    }
}

/// Under the exhaustive profile a run must cover the whole space;
/// under the bounded profile truncation is tolerated (but with the
/// default sizes the budget covers everything anyway).
fn assert_covered(name: &str, result: Result<ocl::mc::Exploration, Violation>) {
    let x = result.unwrap_or_else(|v| panic!("{name}: {v}"));
    if cfg!(loom) {
        assert!(x.complete, "{name}: exhaustive profile truncated at {} steps", x.steps);
    }
    assert!(x.states > 0, "{name}: explored nothing");
}

// ---------------------------------------------------------------------------
// Admission gate: exactly-once permits, no lost permit, shed accounting
// ---------------------------------------------------------------------------

#[test]
fn gate_oversubscribed_holds_permit_accounting() {
    let clients = if cfg!(loom) { 4 } else { 3 };
    let spec = GateSpec { clients, cap: 2, blind_store: false };
    assert_covered("gate 4c/2cap", explorer().explore(&spec));
}

#[test]
fn gate_undersubscribed_never_sheds() {
    let spec = GateSpec { clients: 2, cap: 3, blind_store: false };
    assert_covered("gate 2c/3cap", explorer().explore(&spec));
}

#[test]
fn gate_cap_one_serializes() {
    let clients = if cfg!(loom) { 4 } else { 3 };
    let spec = GateSpec { clients, cap: 1, blind_store: false };
    assert_covered("gate Nc/1cap", explorer().explore(&spec));
}

/// Meta-test: replacing the CAS with a blind store must be caught —
/// either as broken permit accounting mid-run or as leak/underflow at
/// the end. A checker that passes this gate variant checks nothing.
#[test]
fn gate_meta_blind_store_is_caught() {
    let spec = GateSpec { clients: 3, cap: 2, blind_store: true };
    let v = Explorer::exhaustive()
        .explore(&spec)
        .expect_err("the blind-store gate must violate permit accounting");
    match v {
        Violation::Invariant { msg, trace } => {
            assert!(
                msg.contains("permit") || msg.contains("over-admission"),
                "unexpected failure: {msg}"
            );
            assert!(!trace.is_empty(), "a reproducing schedule must be reported");
        }
        Violation::Final { msg, .. } => {
            assert!(msg.contains("permit") || msg.contains("leaked"), "{msg}");
        }
        Violation::Deadlock { trace } => panic!("expected accounting failure, got deadlock {trace:?}"),
    }
}

// ---------------------------------------------------------------------------
// Snapshot slot: publish/install ordering
// ---------------------------------------------------------------------------

#[test]
fn slot_readers_never_install_stale_snapshots() {
    let (pubs, readers) = if cfg!(loom) { (3, 2) } else { (2, 2) };
    let spec = SlotSpec { pubs, readers, seq_first: false };
    assert_covered("slot publish/install", explorer().explore(&spec));
}

#[test]
fn slot_single_reader_single_pub() {
    let spec = SlotSpec { pubs: 1, readers: 1, seq_first: false };
    assert_covered("slot 1p/1r", explorer().explore(&spec));
}

/// Meta-test: releasing the sequence number before the payload lands
/// (the store-order bug the real `SnapshotSlot::publish` is written
/// to avoid) must produce a stale install the checker reports.
#[test]
fn slot_meta_seq_first_ordering_is_caught() {
    let spec = SlotSpec { pubs: 1, readers: 1, seq_first: true };
    let v = Explorer::exhaustive()
        .explore(&spec)
        .expect_err("seq-before-payload must let a reader install stale state");
    match v {
        Violation::Invariant { msg, trace } => {
            assert!(msg.contains("stale install"), "unexpected failure: {msg}");
            assert!(!trace.is_empty());
        }
        other => panic!("expected a stale-install invariant violation, got {other}"),
    }
}

// ---------------------------------------------------------------------------
// Checkpoint barrier: pause → drain → export → resume
// ---------------------------------------------------------------------------

#[test]
fn barrier_clean_write_reopens_admission() {
    let requests = if cfg!(loom) { 5 } else { 4 };
    let spec = BarrierSpec { requests, every: 2, outcomes: vec![Written, Written] };
    assert_covered("barrier written", explorer().explore(&spec));
}

#[test]
fn barrier_slow_authority_timeout_reopens_admission() {
    // The PR 6 liveness arm: an alive-but-wedged authority aborts the
    // attempt; admission must re-open and the cadence reset.
    let spec = BarrierSpec { requests: 4, every: 2, outcomes: vec![TimedOut, Written] };
    assert_covered("barrier timeout", explorer().explore(&spec));
}

#[test]
fn barrier_dead_authority_retries_under_the_same_arm() {
    let spec =
        BarrierSpec { requests: 4, every: 2, outcomes: vec![AuthorityDead, Written, Written] };
    assert_covered("barrier respawn-retry", explorer().explore(&spec));
}

#[test]
fn barrier_double_death_then_write() {
    let spec = BarrierSpec {
        requests: 3,
        every: 3,
        outcomes: vec![AuthorityDead, AuthorityDead, Written],
    };
    assert_covered("barrier double respawn", explorer().explore(&spec));
}

/// Meta-test: a script whose dead authority is never resolved strands
/// the barrier armed — the checker must flag the wedged admission
/// (this is exactly the failure mode the PR 6 export timeout exists
/// to prevent in production).
#[test]
fn barrier_meta_unresolved_death_wedges_admission() {
    let spec = BarrierSpec { requests: 2, every: 1, outcomes: vec![AuthorityDead] };
    let v = Explorer::exhaustive()
        .explore(&spec)
        .expect_err("an unresolved dead authority must wedge the stream");
    match v {
        Violation::Deadlock { trace } => assert!(!trace.is_empty()),
        Violation::Final { msg, .. } => assert!(msg.contains("wedged"), "{msg}"),
        Violation::Invariant { msg, .. } => panic!("unexpected invariant failure: {msg}"),
    }
}

// ---------------------------------------------------------------------------
// Autoscaler: bounds, authority pinning, busy-victim refusal
// ---------------------------------------------------------------------------

/// Twitchy hysteresis (streaks of 1, no cooldown) so every explored
/// schedule exercises real scale events, not holds.
fn scale_policy(min: usize, max: usize) -> ScalePolicy {
    ScalePolicy {
        min_replicas: min,
        max_replicas: max,
        up_depth: 1,
        down_depth: 0,
        up_after: 1,
        down_after: 1,
        cooldown: 0,
    }
}

#[test]
fn scale_stays_inside_bounds_and_keeps_the_authority() {
    let (jobs, sweeps) = if cfg!(loom) { (2, 5) } else { (2, 4) };
    let spec =
        ScaleSpec { jobs, sweeps, policy: scale_policy(1, 2), remove_authority: false };
    assert_covered("scale 1..2", explorer().explore(&spec));
}

#[test]
fn scale_with_slack_ceiling_never_strands_jobs() {
    let spec =
        ScaleSpec { jobs: 1, sweeps: 6, policy: scale_policy(1, 3), remove_authority: false };
    assert_covered("scale 1..3", explorer().explore(&spec));
}

/// Meta-test: a scale-down victim rule that picks the *first* idle
/// replica — instead of the highest-index replica only — can remove
/// worker 0 (e.g. grow under load, the job drains on worker 0, then
/// an idle sweep shrinks). The checker must report the authority
/// removal with a reproducing schedule.
#[test]
fn scale_meta_authority_removal_is_caught() {
    let spec =
        ScaleSpec { jobs: 1, sweeps: 6, policy: scale_policy(1, 2), remove_authority: true };
    let v = Explorer::exhaustive()
        .explore(&spec)
        .expect_err("first-idle victim selection must eventually remove worker 0");
    match v {
        Violation::Invariant { msg, trace } => {
            assert!(msg.contains("authority"), "unexpected failure: {msg}");
            assert!(!trace.is_empty(), "a reproducing schedule must be reported");
        }
        other => panic!("expected an authority-removal violation, got {other}"),
    }
}

// ---------------------------------------------------------------------------
// Real threads against the production gate (sanity beyond the model;
// also the surface the ThreadSanitizer CI job hammers)
// ---------------------------------------------------------------------------

#[test]
fn real_admission_gate_under_thread_stress() {
    use ocl::sync::atomic::{AtomicUsize, Ordering};
    use ocl::sync::Arc;

    let cap = 8usize;
    let threads = 16usize;
    let per_thread = if cfg!(loom) { 500 } else { 200 };

    let gate = Arc::new(AdmissionGate::new(cap));
    let admitted = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));

    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let gate = Arc::clone(&gate);
            let admitted = Arc::clone(&admitted);
            let shed = Arc::clone(&shed);
            ocl::sync::thread::spawn(move || {
                for _ in 0..per_thread {
                    if gate.try_admit() {
                        admitted.fetch_add(1, Ordering::Relaxed);
                        let seen = gate.current();
                        assert!(seen >= 1 && seen <= cap, "in-system {seen} out of range");
                        std::hint::spin_loop();
                        gate.release();
                    } else {
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("stress thread panicked");
    }

    let admitted = admitted.load(Ordering::Relaxed);
    let shed = shed.load(Ordering::Relaxed);
    assert_eq!(admitted + shed, threads * per_thread, "every attempt resolved");
    assert_eq!(gate.current(), 0, "all permits returned");
    assert!(gate.peak() <= cap, "peak {} exceeded cap {cap}", gate.peak());
    assert!(admitted >= threads, "gate admitted implausibly little");
}
