//! Smoke test mirroring `examples/quickstart.rs`'s core loop at small
//! N, so drift between the example's API usage and the library breaks
//! `cargo test` instead of rotting silently. (`cargo test` also
//! *compiles* every example; this additionally executes the flow and
//! asserts the run's headline invariants.)

use ocl::cascade::Cascade;
use ocl::config::{BenchmarkId, CascadeConfig, ExpertId};
use ocl::data::Benchmark;
use ocl::sim::{Expert, ExpertProfile};

/// The quickstart flow: build benchmark + expert + cascade, stream
/// every sample, read the metrics. Kept structurally identical to
/// examples/quickstart.rs (same constructors, same knobs) at n=600.
#[test]
fn quickstart_core_loop_runs_and_learns() {
    let bench = BenchmarkId::Imdb;
    let expert_id = ExpertId::Gpt35;
    let n = 600;

    let benchmark = Benchmark::build_sized(bench, 42, n);
    let mean_len =
        benchmark.samples.iter().map(|s| s.len as f64).sum::<f64>() / n as f64;
    let expert = Expert::new(
        ExpertProfile::for_pair(expert_id, bench),
        benchmark.strata_fractions(),
        mean_len,
        42,
    );

    let cfg = CascadeConfig::small(bench, expert_id);
    let mut cascade =
        Cascade::new(cfg, benchmark.classes, expert, None, 200).expect("cascade");
    cascade.set_threshold_scale(0.7);

    for s in benchmark.stream() {
        cascade.process(s);
    }
    let m = &mut cascade.metrics;
    m.finalize();

    // Every query answered exactly once.
    assert_eq!(m.total(), n);
    let handled: f64 = m.handled_fractions().iter().sum();
    assert!((handled - 1.0).abs() < 1e-9);
    // The run actually learned something: accuracy beats coin-flip …
    assert!(m.accuracy() > 0.55, "accuracy {}", m.accuracy());
    // … and the cheap levels took real traffic off the expert, which
    // is the quickstart's headline claim ("cost savings").
    assert!(
        (m.llm_calls() as usize) < n,
        "expert answered everything: {} calls",
        m.llm_calls()
    );
    let savings = 1.0 - m.llm_calls() as f64 / n as f64;
    assert!(savings > 0.05, "savings {savings}");
    // Snapshots were taken at the example's cadence.
    assert!(!m.series.is_empty());
}

/// The quickstart's printed fractions index levels 0/1/2 — pin the
/// small-cascade level count so the example's formatting stays valid.
#[test]
fn quickstart_level_layout_is_stable() {
    let cfg = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
    assert_eq!(cfg.levels.len(), 2, "small cascade = LR + BERT-base + expert");
    assert_eq!(cfg.n_levels(), 3);
}
