//! Elasticity test suite (DESIGN.md §14): N→M checkpoint resharding
//! pinned against the uninterrupted oracle, property tests of the
//! merge rules over random (N, M, seed) topologies, the committed v1
//! manifest fixture, and the `ocl reshard` guard rails.
//!
//! The tentpole contract: a 2-shard run checkpointed at quiescence,
//! resharded to 3 / to 1 / chained 3→2, then resumed with an empty
//! stream tail must land on the *exact* state the uninterrupted run
//! finished with — bit-identical β vectors and train/calib chunk
//! counts on every shard (authority-seeded from old shard 0), and
//! conserved serve totals. Rolling restarts over real sockets live in
//! `test_net.rs`; the autoscaler model checks live in `test_loom.rs`.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;

use ocl::config::{BenchmarkId, CascadeConfig, ExpertId, ServeConfig};
use ocl::data::Benchmark;
use ocl::models::{Pipeline, Snapshot};
use ocl::prng::Rng;
use ocl::prop;
use ocl::serve::ckpt::{self, CkptOptions, CkptSink, LevelState, ResumeMode, ShardState};
use ocl::serve::reshard::{self, reshard_states};
use ocl::serve::shard::{ShardFront, ShardReport};
use ocl::serve::{Request, Response, ServeReport};
use ocl::sim::{Expert, ExpertProfile};
use ocl::sync::Arc;

fn expert_for(b: &Benchmark, seed: u64) -> Expert {
    let mean_len =
        b.samples.iter().map(|s| s.len as f64).sum::<f64>() / b.samples.len() as f64;
    Expert::new(
        ExpertProfile::for_pair(ExpertId::Gpt35, BenchmarkId::Imdb),
        b.strata_fractions(),
        mean_len,
        seed,
    )
}

/// Never sheds, no cadence checkpoints, `m` shards, no sync broadcast
/// (a pure-restore resume must not absorb staged annotations, or the
/// oracle comparison would race the broadcast).
fn sharded(m: usize) -> ServeConfig {
    ServeConfig::builder()
        .max_pending(1 << 16)
        .ckpt_every(0)
        .shards(m)
        .build()
        .unwrap()
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ocl-elastic-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Serve samples `lo..hi` (original stream ids) through `front`,
/// returning the merged report and the responses.
fn run_front(
    front: ShardFront,
    b: &Benchmark,
    lo: usize,
    hi: usize,
) -> (ShardReport, Vec<Response>) {
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    let samples: Vec<_> = b.samples[lo..hi].to_vec();
    let submit = std::thread::spawn(move || {
        for (k, s) in samples.iter().enumerate() {
            if req_tx
                .send(Request {
                    id: (lo + k) as u64,
                    text: s.text.clone(),
                    truth: s.label,
                    sample: s.clone(),
                })
                .is_err()
            {
                break;
            }
        }
    });
    let report = front.serve(req_rx, resp_tx).expect("front serve");
    submit.join().unwrap();
    (report, resp_rx.iter().collect())
}

/// Element-wise handled totals across shards.
fn handled_sum(r: &ShardReport) -> Vec<usize> {
    let k = r.shards.iter().map(|s| s.handled.len()).max().unwrap_or(0);
    (0..k)
        .map(|i| r.shards.iter().map(|s| *s.handled.get(i).unwrap_or(&0)).sum())
        .collect()
}

fn beta_bits(r: &ServeReport) -> Vec<u64> {
    r.final_betas.iter().map(|x| x.to_bits()).collect()
}

/// Strict-resume an M-shard front from `dir`, serve an already-empty
/// stream tail (pure restore), and pin the result against the
/// uninterrupted oracle run.
fn resume_and_check(
    cfg: &CascadeConfig,
    b: &Benchmark,
    seed: u64,
    dir: &Path,
    m: usize,
    oracle: &ShardReport,
) {
    let n = oracle.served();
    let front = ShardFront::with_ckpt(
        cfg.clone(),
        b.classes,
        expert_for(b, seed),
        sharded(m),
        "artifacts",
        Some(CkptOptions {
            dir: dir.to_string_lossy().into_owned(),
            resume: Some(ResumeMode::Strict),
        }),
    )
    .expect("resharded manifest must restore under strict resume");
    assert_eq!(front.shards(), m);
    let (report, responses) = run_front(front, b, n, n);
    assert!(report.resumed(), "{m}-shard resume must say so");
    assert!(responses.is_empty(), "pure restore must serve nothing new");
    assert_eq!(report.served(), n, "served_total conserved across reshard to {m}");
    assert_eq!(report.shed(), oracle.shed(), "shed conserved across reshard to {m}");
    assert_eq!(
        report.llm_calls(),
        oracle.llm_calls(),
        "expert-call totals conserved across reshard to {m}"
    );
    assert_eq!(
        handled_sum(&report),
        handled_sum(oracle),
        "handled mix conserved across reshard to {m}"
    );
    // Authority seeding: every new shard continues old shard 0's
    // learner trajectory bit-for-bit.
    for (k, s) in report.shards.iter().enumerate() {
        assert_eq!(
            beta_bits(s),
            beta_bits(&oracle.shards[0]),
            "reshard to {m}, shard {k}: β must be bit-identical to the oracle authority"
        );
        assert_eq!(
            s.train_batches, oracle.shards[0].train_batches,
            "reshard to {m}, shard {k}: train chunk counts must match the authority"
        );
        assert_eq!(
            s.calib_batches, oracle.shards[0].calib_batches,
            "reshard to {m}, shard {k}: calib chunk counts must match the authority"
        );
    }
}

#[test]
fn reshard_and_resume_matches_the_uninterrupted_oracle() {
    // The oracle: an uninterrupted 2-shard run over the whole stream,
    // checkpointed at the graceful-shutdown quiescent point.
    let n = 240;
    let b = Benchmark::build_sized(BenchmarkId::Imdb, 83, n);
    let cfg = {
        let mut c = CascadeConfig::small(BenchmarkId::Imdb, ExpertId::Gpt35);
        c.seed = 83;
        c
    };
    let dir_a = tmpdir("reshard-src");
    let front = ShardFront::with_ckpt(
        cfg.clone(),
        b.classes,
        expert_for(&b, 83),
        sharded(2),
        "artifacts",
        Some(CkptOptions { dir: dir_a.to_string_lossy().into_owned(), resume: None }),
    )
    .unwrap();
    let (oracle, responses) = run_front(front, &b, 0, n);
    assert_eq!(oracle.served(), n);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "oracle serves exactly once");
    assert!(oracle.ckpts() >= 1, "graceful shutdown must checkpoint");

    let src_states = ckpt::load_latest(&dir_a, ResumeMode::Strict, 2).unwrap().unwrap();
    let min_cursor = src_states.iter().map(|s| s.cursor).min().unwrap();

    // 2→3 and 2→1, each resumed and pinned against the oracle.
    for m in [3usize, 1] {
        let dst = tmpdir(&format!("reshard-to{m}"));
        let summary = reshard::reshard(&dir_a, &dst, m).unwrap();
        assert_eq!((summary.from_shards, summary.to_shards), (2, m));
        assert_eq!(summary.served_total, n, "summary conserves served_total");
        assert_eq!(summary.cursor, min_cursor, "summary cursor is the min over shards");
        resume_and_check(&cfg, &b, 83, &dst, m, &oracle);
        let _ = fs::remove_dir_all(&dst);
    }

    // 3→2 chains through an intermediate topology: the authority
    // trajectory survives two reshards.
    let dst3 = tmpdir("reshard-chain3");
    let dst2 = tmpdir("reshard-chain2");
    reshard::reshard(&dir_a, &dst3, 3).unwrap();
    let summary = reshard::reshard(&dst3, &dst2, 2).unwrap();
    assert_eq!((summary.from_shards, summary.to_shards), (3, 2));
    assert_eq!(summary.served_total, n);
    resume_and_check(&cfg, &b, 83, &dst2, 2, &oracle);
    let _ = fs::remove_dir_all(&dir_a);
    let _ = fs::remove_dir_all(&dst3);
    let _ = fs::remove_dir_all(&dst2);
}

// --- property tests over random (N, M, seed) topologies --------------------

/// Random but structurally valid shard state: 2 levels, random
/// counters, random replay/calib/sync cache contents.
fn rand_state(rng: &mut Rng, pl: &Pipeline, shard: usize, n_levels: usize) -> ShardState {
    let feat = |rng: &mut Rng| {
        Arc::new(pl.featurize(&format!(
            "kw{}x{:03} kw0x{:03}",
            rng.below(3),
            rng.below(100),
            rng.below(100)
        )))
    };
    let snap = |kind: &str, base: usize| Snapshot {
        kind: kind.into(),
        classes: 2,
        data: (0..4).map(|i| (base + i) as f32 * 0.25).collect(),
    };
    let served = 10 + rng.below(200);
    let levels = (0..n_levels)
        .map(|l| {
            let cache = (0..rng.below(4))
                .map(|_| {
                    let y = rng.below(2);
                    (feat(rng), y)
                })
                .collect();
            let calib_cache = (0..rng.below(3))
                .map(|_| {
                    let p = vec![rng.below(4) as f32 * 0.25, 0.1];
                    (p, rng.below(2) as f32)
                })
                .collect();
            LevelState {
                model: snap(if l == 0 { "lr" } else { "tfm_base" }, shard + l),
                calib: snap("mlp", shard + l + 1),
                train_chunks: rng.below(20) as u64,
                calib_chunks: rng.below(20) as u64,
                train_sends: rng.below(5) as u64,
                pending: rng.below(8),
                calib_pending: rng.below(8),
                cache,
                calib_cache,
            }
        })
        .collect();
    let sync_staged = (0..rng.below(3))
        .map(|_| {
            let y = rng.below(2);
            (feat(rng), y)
        })
        .collect();
    ShardState {
        shard,
        cursor: 10 + rng.below(100) as u64,
        rng_s: [1 + shard as u64, 2, 3, 4 + rng.below(9) as u64],
        rng_cached: None,
        betas: (0..n_levels).map(|l| 0.9 - l as f64 * 0.05 - shard as f64 * 0.1).collect(),
        threshold_scale: 1.0,
        probe_seq: rng.below(10) as u64,
        sync_staged,
        served,
        shed: rng.below(5),
        correct: served / 2,
        llm_calls: rng.below(50) as u64,
        handled: (0..n_levels + 1).map(|_| rng.below(50)).collect(),
        levels,
    }
}

/// The merge-rule contract for one (old topology, M) pair.
fn merge_holds(old: &[ShardState], m: usize) -> bool {
    let new = reshard_states(old, m);
    if new.len() != m {
        return false;
    }
    let min_cursor = old.iter().map(|s| s.cursor).min().unwrap();
    let auth = &old[0];
    for (k, s) in new.iter().enumerate() {
        // Labeling + global cursor + authority-seeded learner state.
        if s.shard != k || s.cursor != min_cursor {
            return false;
        }
        if s.betas != auth.betas || s.rng_s != auth.rng_s || s.probe_seq != auth.probe_seq
        {
            return false;
        }
        for (l, al) in s.levels.iter().zip(&auth.levels) {
            if l.model != al.model
                || l.calib != al.calib
                || l.train_chunks != al.train_chunks
                || l.calib_chunks != al.calib_chunks
                || l.pending != al.pending
            {
                return false;
            }
        }
        // Counters conserve onto new shard 0 only.
        if k > 0 && (s.served != 0 || s.llm_calls != 0 || s.handled.iter().any(|&h| h > 0))
        {
            return false;
        }
    }
    // Conservation of every total the reports aggregate: served, shed,
    // correct, expert calls, handled, staged sync annotations, replay
    // cache entries, calibration cache entries.
    let tot = |xs: &[ShardState]| {
        (
            xs.iter().map(|s| s.served).sum::<usize>(),
            xs.iter().map(|s| s.shed).sum::<usize>(),
            xs.iter().map(|s| s.correct).sum::<usize>(),
            xs.iter().map(|s| s.llm_calls).sum::<u64>(),
            xs.iter().map(|s| s.handled.iter().sum::<usize>()).sum::<usize>(),
            xs.iter().map(|s| s.sync_staged.len()).sum::<usize>(),
            xs.iter().flat_map(|s| &s.levels).map(|l| l.cache.len()).sum::<usize>(),
            xs.iter().flat_map(|s| &s.levels).map(|l| l.calib_cache.len()).sum::<usize>(),
        )
    };
    if tot(old) != tot(&new) {
        return false;
    }
    // Determinism: same input, same output.
    reshard_states(old, m) == new
}

#[test]
fn prop_reshard_merge_rules_hold_for_random_topologies() {
    let pl = Pipeline::default();
    prop::check_seeded("reshard-merge", 16, |rng| {
        let n = 1 + rng.below(3);
        let m = 1 + rng.below(5);
        let n_levels = 1 + rng.below(2);
        let old: Vec<ShardState> =
            (0..n).map(|s| rand_state(rng, &pl, s, n_levels)).collect();
        merge_holds(&old, m)
    });
}

/// Sorted `(file name, bytes)` listing of a checkpoint directory.
fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| {
            (e.file_name().to_string_lossy().into_owned(), fs::read(e.path()).unwrap())
        })
        .collect();
    out.sort();
    out
}

#[test]
fn prop_reshard_on_disk_is_deterministic_and_strict_loadable() {
    let pl = Pipeline::default();
    prop::check_seeded("reshard-disk", 4, |rng| {
        let n = 1 + rng.below(3);
        let m = 1 + rng.below(4);
        let old: Vec<ShardState> = (0..n).map(|s| rand_state(rng, &pl, s, 2)).collect();
        let src = tmpdir("prop-src");
        let sink = CkptSink::create(&src, n).unwrap();
        for s in &old {
            sink.deposit(s.shard, s).unwrap();
        }
        let d1 = tmpdir("prop-dst1");
        let d2 = tmpdir("prop-dst2");
        let s1 = reshard::reshard(&src, &d1, m).unwrap();
        let s2 = reshard::reshard(&src, &d2, m).unwrap();
        // Resharding the same manifest twice is byte-identical, and the
        // output is itself a strict-restorable v2 checkpoint equal to
        // the pure in-memory merge.
        let ok = s1 == s2
            && dir_bytes(&d1) == dir_bytes(&d2)
            && ckpt::load_latest(&d1, ResumeMode::Strict, m).unwrap().unwrap()
                == reshard_states(&old, m);
        for d in [&src, &d1, &d2] {
            let _ = fs::remove_dir_all(d);
        }
        ok
    });
}

// --- committed v1 fixture + guard rails ------------------------------------

#[test]
fn committed_v1_fixture_restores_under_strict_resume() {
    // A byte-frozen checkpoint directory as a v1 build wrote it (no
    // `epochs` array in the manifest): strict resume must restore it,
    // and `ocl reshard` must accept it directly — the v1→v2 migration
    // path is "reshard (or just resume) the old directory".
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/../tests/fixtures/ckpt_v1");
    assert_eq!(ckpt::latest_manifest_shards(fixture).unwrap(), 1);
    let states = ckpt::load_latest(fixture, ResumeMode::Strict, 1)
        .expect("v1 fixture must strict-load")
        .expect("fixture holds a manifest");
    assert_eq!(states.len(), 1);
    let s = &states[0];
    assert_eq!(s.shard, 0);
    assert_eq!(s.cursor, 100);
    assert_eq!(s.served, 100);
    assert_eq!(s.betas, vec![0.5, 0.25]);
    assert_eq!(s.rng_s, [1, 2, 3, 4]);
    assert_eq!(s.levels.len(), 2);
    assert_eq!(s.levels[0].train_chunks, 12);
    assert_eq!(s.levels[0].calib_cache.len(), 1);

    let dst = tmpdir("v1-reshard");
    let summary = reshard::reshard(fixture, &dst, 2).unwrap();
    assert_eq!((summary.from_shards, summary.to_shards), (1, 2));
    assert_eq!(summary.served_total, 100);
    assert_eq!(summary.cursor, 100);
    let restored = ckpt::load_latest(&dst, ResumeMode::Strict, 2).unwrap().unwrap();
    assert_eq!(restored[0].betas, s.betas, "authority β survives the migration");
    assert_eq!(restored[1].betas, s.betas);
    let _ = fs::remove_dir_all(&dst);
}

#[test]
fn reshard_rejects_degenerate_requests() {
    // Zero target shard count (checked before touching the source).
    let empty = tmpdir("guard-empty");
    let err = reshard::reshard(&empty, tmpdir("guard-z"), 0).unwrap_err();
    assert!(err.to_string().contains("target shard count"), "{err}");

    // Source without a manifest.
    fs::create_dir_all(&empty).unwrap();
    let err = reshard::reshard(&empty, tmpdir("guard-n"), 1).unwrap_err();
    assert!(err.to_string().contains("manifest"), "{err}");

    // Occupied destination: resharding into a live checkpoint
    // directory would interleave two topologies.
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/../tests/fixtures/ckpt_v1");
    let dst = tmpdir("guard-occupied");
    reshard::reshard(fixture, &dst, 2).unwrap();
    let err = reshard::reshard(fixture, &dst, 3).unwrap_err();
    assert!(err.to_string().contains("already holds"), "{err}");
    let _ = fs::remove_dir_all(&empty);
    let _ = fs::remove_dir_all(&dst);
}
